//! Figure 1 — overview comparison.
//! Left: decode latency of Llama2-7B (bs=1, input 1K) per engine on the
//! NVIDIA A100 and AMD RX7900XTX. Right: first-token latency vs
//! each-token latency scatter for all engines.

use fdpp::baselines::{EngineKind, EngineModel};
use fdpp::bench_support::{banner, fmt_speedup, fmt_time, row};
use fdpp::config::paper_model;
use fdpp::hwmodel::{a100, rx7900xtx};

fn main() {
    let model = paper_model("llama2-7b").unwrap();
    banner(
        "Figure 1 (left)",
        "Llama2-7B decode latency, bs=1, input len 1K — per-token",
    );
    for gpu in [a100(), rx7900xtx()] {
        println!("\n[{}]", gpu.name);
        let hf =
            EngineModel::new(EngineKind::HuggingFace).decode_token_time(&model, &gpu, 1, 1024);
        row("engine", &["latency".into(), "speedup vs HF".into()]);
        for kind in EngineKind::all() {
            let t = EngineModel::new(kind).decode_token_time(&model, &gpu, 1, 1024);
            row(kind.as_str(), &[fmt_time(t), fmt_speedup(hf / t)]);
        }
    }

    banner(
        "Figure 1 (right)",
        "first-token latency vs each-token latency (A100, bs=1, 1K prompt)",
    );
    let gpu = a100();
    row(
        "engine",
        &["first token".into(), "each token".into()],
    );
    for kind in EngineKind::all() {
        let e = EngineModel::new(kind);
        let first = e.prefill_time(&model, &gpu, 1, 1024);
        let each = e.decode_token_time(&model, &gpu, 1, 1024);
        row(kind.as_str(), &[fmt_time(first), fmt_time(each)]);
    }
    println!(
        "\npaper: FlashDecoding++ sits in the lower-left corner of the scatter\n(best of both); verify the last row dominates."
    );
}
