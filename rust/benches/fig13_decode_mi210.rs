//! Figure 13 — decode-phase speedup on AMD MI210 (same protocol as
//! Figure 12 on the datacenter AMD part).

use fdpp::baselines::{EngineKind, EngineModel};
use fdpp::bench_support::{banner, geomean};
use fdpp::config::paper_models;
use fdpp::hwmodel::mi210;

fn main() {
    banner("Figure 13", "decode speedup vs HuggingFace on AMD MI210");
    let gpu = mi210();
    let grid = [(1usize, 128usize), (1, 512), (1, 1024), (1, 2048), (8, 1024), (32, 512)];
    let mut pp = vec![];
    for model in paper_models() {
        println!("\n[{}]", model.name);
        print!("{:<18}", "engine \\ (bs,len)");
        let g: Vec<_> = grid.iter().filter(|&&(_, l)| l <= model.context).collect();
        for (b, l) in &g {
            print!("{:>12}", format!("({b},{l})"));
        }
        println!();
        let hf = EngineModel::new(EngineKind::HuggingFace);
        for kind in [EngineKind::HuggingFace, EngineKind::FlashDecodingPP] {
            print!("{:<18}", kind.as_str());
            let e = EngineModel::new(kind);
            for &&(b, l) in &g {
                let sp = hf.decode_token_time(&model, &gpu, b, l)
                    / e.decode_token_time(&model, &gpu, b, l);
                print!("{sp:>11.2}x");
                if kind == EngineKind::FlashDecodingPP {
                    pp.push(sp);
                }
            }
            println!();
        }
    }
    println!(
        "\nFlashDecoding++ vs HF on MI210: max {:.2}x, geomean {:.2}x   (paper: up to 2.18x on AMD)",
        pp.iter().cloned().fold(0.0f64, f64::max),
        geomean(&pp)
    );
}
