//! Figure 5 — statistical distribution of softmax inputs x_i.
//! Real measurement on the tiny model via the prefill_scores artifact
//! (histogram + range), plus the paper's published per-model ranges and
//! the enable/disable decision each implies (the OPT-6.7B rule).

use fdpp::runtime::{literal_i32, to_vec_f32, Runtime};
use fdpp::softmaxstats::{derive_policy, paper_figure5_ranges, SoftmaxInputStats};
use fdpp::bench_support::banner;
use fdpp::util::rng::Rng;

fn main() {
    banner("Figure 5", "distribution of softmax inputs x_i");

    // Real measurement path (tiny model on CPU PJRT).
    match Runtime::load("artifacts") {
        Ok(mut rt) => {
            let vocab = rt.manifest.model.vocab_size;
            let seq = 64usize;
            let mut rng = Rng::seed_from_u64(5);
            let mut stats = SoftmaxInputStats::new();
            let mut hist = [0u64; 13]; // buckets of width 2 over [-13, 13)
            for _ in 0..4 {
                let toks: Vec<i32> =
                    (0..seq).map(|_| rng.gen_range(0, vocab - 1) as i32).collect();
                let toks = literal_i32(&toks, &[1, seq]).unwrap();
                let outs = rt
                    .execute(&format!("prefill_scores_s{seq}"), &[&toks])
                    .unwrap();
                let scores = to_vec_f32(&outs[3]).unwrap();
                let (lyr, heads) = (rt.manifest.model.n_layers, rt.manifest.model.n_heads);
                for l in 0..lyr {
                    for h in 0..heads {
                        for i in 0..seq {
                            for j in 0..=i {
                                let x = scores[((l * heads + h) * seq + i) * seq + j] as f64;
                                stats.push(x);
                                let b = (((x + 13.0) / 2.0) as isize).clamp(0, 12) as usize;
                                hist[b] += 1;
                            }
                        }
                    }
                }
            }
            println!(
                "tiny model (measured): n={} range [{:.2}, {:.2}] mean {:.3} std {:.3}",
                stats.count, stats.min, stats.max, stats.mean, stats.std()
            );
            let total: u64 = hist.iter().sum();
            for (i, &c) in hist.iter().enumerate() {
                let lo = -13.0 + 2.0 * i as f64;
                let bar = "#".repeat((c * 60 / total.max(1)) as usize);
                println!("  [{:>6.1},{:>6.1})  {bar}", lo, lo + 2.0);
            }
            let p = derive_policy(&stats);
            println!(
                "policy: enabled={} phi={:.3} expected recompute {:.2e}\n",
                p.enabled, p.phi, p.expected_recompute_rate
            );
        }
        Err(e) => println!("(artifacts unavailable: {e}; skipping real measurement)\n"),
    }

    println!("paper-reported ranges (read off Figure 5) and the §3 decision:");
    for (name, lo, hi) in paper_figure5_ranges() {
        let mut s = SoftmaxInputStats::new();
        for i in 0..1024 {
            s.push(lo + (hi - lo) * i as f64 / 1023.0);
        }
        let p = derive_policy(&s);
        println!(
            "  {:<14} [{:>6.1}, {:>5.1}]  -> asynchronized softmax {}",
            name,
            lo,
            hi,
            if p.enabled { "ENABLED" } else { "DISABLED" }
        );
    }
    println!("\npaper: enabled for Llama2/ChatGLM2, disabled for OPT-6.7B.");
}
