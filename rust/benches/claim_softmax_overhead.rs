//! §2.3/§3 claim — the synchronized partial-softmax update costs 18.8%
//! of the attention computation (Llama2-7B, 1K input, A100).
//!
//! Two backends:
//!  (a) analytic A100 model across kv lengths (the calibrated point plus
//!      the trend), and
//!  (b) real CPU: decode_b1 vs decode_b1_sync artifacts — the same model
//!      step where only the softmax scheme differs.

use std::time::Instant;

use fdpp::bench_support::{banner, fmt_time, time_median};
use fdpp::hwmodel::{a100, attention_decode_time, SoftmaxScheme};
use fdpp::runtime::{literal_f32, literal_i32, Runtime};

fn main() {
    banner(
        "§2.3 claim",
        "synchronized partial-softmax update overhead in attention",
    );
    let gpu = a100();
    println!("[analytic A100, Llama2-7B geometry (32 heads, d=128, bs=1)]");
    println!("{:>8} {:>12} {:>12} {:>10}", "kv_len", "sync", "async", "overhead");
    for kv in [256usize, 512, 1024, 2048, 4096, 8192] {
        let t_s = attention_decode_time(&gpu, 1, 32, 128, kv, SoftmaxScheme::SyncPartial, 2);
        let t_a = attention_decode_time(&gpu, 1, 32, 128, kv, SoftmaxScheme::AsyncUnified, 2);
        println!(
            "{:>8} {:>12} {:>12} {:>9.1}%",
            kv,
            fmt_time(t_s),
            fmt_time(t_a),
            (t_s - t_a) / t_s * 100.0
        );
    }
    println!("paper calibration point: 18.8% at kv=1024.\n");

    // Real CPU: full decode step, async vs sync artifacts.
    match Runtime::load("artifacts") {
        Ok(mut rt) => {
            let m = rt.manifest.model.clone();
            let b = 1usize;
            let cache_elems = m.n_layers * b * m.n_heads * m.max_seq * m.head_dim;
            let kc = literal_f32(&vec![0.01f32; cache_elems],
                &[m.n_layers, b, m.n_heads, m.max_seq, m.head_dim]).unwrap();
            let vc = literal_f32(&vec![0.01f32; cache_elems],
                &[m.n_layers, b, m.n_heads, m.max_seq, m.head_dim]).unwrap();
            let toks = literal_i32(&[5], &[1]).unwrap();
            let pos = literal_i32(&[(m.max_seq - 1) as i32], &[1]).unwrap();
            println!("[real CPU PJRT, tiny model, decode bs=1, kv={} (full cache)]", m.max_seq);
            let mut times = vec![];
            for entry in ["decode_b1", "decode_b1_sync", "decode_b1_jnpattn"] {
                rt.ensure_compiled(entry).unwrap();
                rt.execute(entry, &[&toks, &pos, &kc, &vc]).unwrap(); // warmup
                let t = time_median(9, || {
                    rt.execute(entry, &[&toks, &pos, &kc, &vc]).unwrap();
                });
                println!("  {entry:<22} {}", fmt_time(t));
                times.push(t);
            }
            println!(
                "  async vs sync step delta: {:+.1}% (CPU-interpret timings are NOT a\n  GPU proxy — the async kernel runs both tracks for jit-able fallback;\n  on real hardware the sync track is the relaunched fallback only)",
                (times[1] - times[0]) / times[1] * 100.0
            );
            let _ = Instant::now();
        }
        Err(e) => println!("(artifacts unavailable: {e})"),
    }
}
