//! Prefix-cache bench: shared-prefix workload (N tenant system prompts,
//! Zipf-distributed reuse) through the simulation engine, cache on vs
//! off. Reports hit rate, prefill tokens saved, and verifies that every
//! request's output is byte-identical to the no-cache run on the same
//! seed — reuse must be a pure optimization.
//!
//! The driver is generic over [`InferenceEngine`]: the exact same loop
//! serves the sim twin here and the real engine elsewhere, and requests
//! flow through the typed `GenRequest` surface (tenant ids included, so
//! the per-tenant counters below come from the engine, not the bench).
//!
//! Acceptance target (ISSUE 1): >= 50% prefill-token reduction at
//! 8 tenants with Zipf(1.0) reuse.

use std::time::Instant;

use fdpp::api::{GenRequest, InferenceEngine, SubmissionHandle};
use fdpp::bench_support::banner;
use fdpp::config::EngineConfig;
use fdpp::simengine::{SimEngine, SimSpec};
use fdpp::workload::{shared_prefix_trace, SharedPrefixSpec, TraceRequest};

fn cfg(prefix_cache: bool) -> EngineConfig {
    EngineConfig {
        kv_block_tokens: 16,
        kv_total_blocks: 512,
        max_new_tokens: 16,
        prefix_cache,
        ..EngineConfig::default()
    }
}

struct RunResult {
    outputs: Vec<Vec<u32>>,
    prefill_computed: u64,
    tokens_reused: u64,
    hit_rate: f64,
    evicted: u64,
    tenant_cached: Vec<(String, u64)>,
    wall_s: f64,
}

/// Drive a full trace through any engine via the unified API.
fn run_engine<E: InferenceEngine>(
    engine: &mut E,
    trace: &[TraceRequest],
) -> fdpp::Result<RunResult> {
    let t0 = Instant::now();
    let mut handles: Vec<SubmissionHandle> = Vec::with_capacity(trace.len());
    for r in trace {
        let req = GenRequest::text(r.prompt.as_str())
            .tenant(r.tenant.as_str())
            .max_new_tokens(r.max_new_tokens);
        handles.push(engine.submit(req)?);
    }
    engine.run_to_completion()?;
    let wall_s = t0.elapsed().as_secs_f64();
    let outputs = handles.iter().map(|h| h.drain().0).collect();
    let m = engine.metrics();
    Ok(RunResult {
        outputs,
        prefill_computed: m.prefill_tokens_computed,
        tokens_reused: m.prefix_tokens_reused,
        hit_rate: m.prefix_hit_rate(),
        evicted: m.prefix_blocks_evicted,
        tenant_cached: m
            .tenants
            .iter()
            .map(|(k, t)| (k.clone(), t.cached_prompt_tokens))
            .collect(),
        wall_s,
    })
}

fn run(trace: &[TraceRequest], prefix_cache: bool) -> fdpp::Result<RunResult> {
    let mut engine = SimEngine::new(cfg(prefix_cache), SimSpec::default())?;
    run_engine(&mut engine, trace)
}

fn main() -> fdpp::Result<()> {
    banner(
        "prefix reuse",
        "radix-tree prefix cache on the shared-prefix workload (sim engine)",
    );
    let spec = SharedPrefixSpec {
        n_tenants: 8,
        zipf_s: 1.0,
        seed: 7,
        ..SharedPrefixSpec::default()
    };
    let trace = shared_prefix_trace(&spec);
    println!(
        "{} requests, {} tenants, Zipf({}), {}-char system prompts\n",
        trace.len(),
        spec.n_tenants,
        spec.zipf_s,
        spec.system_prompt_len
    );

    let cold = run(&trace, false)?;
    let warm = run(&trace, true)?;

    // Correctness first: reuse must not change a single token.
    let mut mismatches = 0usize;
    for (i, (a, b)) in warm.outputs.iter().zip(&cold.outputs).enumerate() {
        if a != b {
            mismatches += 1;
            if mismatches <= 3 {
                println!("MISMATCH request {i}: cached {a:?} != cold {b:?}");
            }
        }
    }
    assert_eq!(
        mismatches, 0,
        "cached outputs must be byte-identical to the no-cache path"
    );
    println!("outputs: byte-identical across all {} requests", trace.len());

    let total_prompt_tokens = cold.prefill_computed as f64;
    let reduction = 1.0 - warm.prefill_computed as f64 / total_prompt_tokens;
    println!();
    println!("{:<34} {:>12} {:>12}", "", "cache off", "cache on");
    println!(
        "{:<34} {:>12} {:>12}",
        "prefill tokens computed", cold.prefill_computed, warm.prefill_computed
    );
    println!(
        "{:<34} {:>12} {:>12}",
        "prefix tokens reused", cold.tokens_reused, warm.tokens_reused
    );
    println!(
        "{:<34} {:>11.1}% {:>11.1}%",
        "lookup hit rate",
        cold.hit_rate * 100.0,
        warm.hit_rate * 100.0
    );
    println!(
        "{:<34} {:>12} {:>12}",
        "cached blocks evicted", cold.evicted, warm.evicted
    );
    println!(
        "{:<34} {:>11.2}s {:>11.2}s",
        "wall time", cold.wall_s, warm.wall_s
    );
    println!("\nper-tenant cached prompt tokens (cache on):");
    for (tenant, cached) in &warm.tenant_cached {
        println!("  {tenant:<16} {cached:>8}");
    }
    println!();
    println!(
        "prefill-token reduction: {:.1}% (target >= 50%)",
        reduction * 100.0
    );
    assert!(
        reduction >= 0.5,
        "prefill-token reduction {reduction:.3} below the 50% target"
    );
    println!("PASS");
    Ok(())
}
