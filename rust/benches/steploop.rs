//! Step-loop economics: the pinned decode-heavy workload drained
//! across the chunk×batch grid (chunk∈{1,2,4,8} × batch∈{1,4,8}),
//! reporting virtual-time throughput, per-step orchestration overhead
//! share, and allocations per generated token (`BENCH_steploop.json`).
//!
//! The bench binary installs a counting global allocator, so the
//! allocations-per-token column is measured, not modeled. Runs
//! [`fdpp::bench_support::steploop_report`] twice at the pinned seed,
//! asserts the two reports are byte-identical (virtual clock, seeded
//! workload, deterministic allocation sequence — regressions show up
//! as a *changed* report, never as noise), asserts the overhead share
//! strictly decreases as the chunk grows and that chunk 4 clears chunk
//! 1's tokens/s by ≥20% at every batch size, prints the grid, and
//! writes `BENCH_steploop.json` to the working directory.
//!
//!   cargo bench --bench steploop

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fdpp::bench_support::{banner, row, steploop_report, STEPLOOP_SEED};
use fdpp::util::json::Json;

/// Counts every heap allocation (including reallocations) made through
/// the global allocator; frees are not counted — the report cares
/// about allocation *pressure* per token, and a steady-state step that
/// allocates nothing also frees nothing.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const CHUNKS: [f64; 4] = [1.0, 2.0, 4.0, 8.0];
const BATCHES: [f64; 3] = [1.0, 4.0, 8.0];

fn main() {
    banner(
        "BENCH_steploop",
        "chunked decode steps: orchestration overhead and allocation pressure",
    );
    let counter = || ALLOCS.load(Ordering::Relaxed);
    let report = steploop_report(STEPLOOP_SEED, Some(&counter)).expect("harness runs");
    let again = steploop_report(STEPLOOP_SEED, Some(&counter)).expect("harness runs");
    let text = report.to_string();
    assert_eq!(
        text,
        again.to_string(),
        "step-loop report must be byte-identical across runs of the same seed"
    );

    let cells = report
        .get("grid")
        .and_then(Json::as_arr)
        .expect("report carries the grid");
    let num = |chunk: f64, batch: f64, key: &str| {
        cells
            .iter()
            .find(|c| {
                c.get("chunk").and_then(Json::as_f64) == Some(chunk)
                    && c.get("batch").and_then(Json::as_f64) == Some(batch)
            })
            .and_then(|c| c.get(key))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("report missing grid[chunk={chunk},batch={batch}].{key}"))
    };

    row(
        "chunk \\ batch",
        &BATCHES.iter().map(|b| format!("{b:.0}")).collect::<Vec<_>>(),
    );
    for &c in &CHUNKS {
        let vals: Vec<String> = BATCHES
            .iter()
            .map(|&b| {
                let tps = num(c, b, "tokens_per_sec");
                let ov = num(c, b, "overhead_share");
                format!("{tps:.0}/{:.0}%", ov * 100.0)
            })
            .collect();
        row(&format!("chunk={c:.0} tok/s / ovh%"), &vals);
    }
    let apt: Vec<String> = BATCHES
        .iter()
        .map(|&b| format!("{:.2}", num(8.0, b, "allocs_per_token")))
        .collect();
    row("allocs/token (chunk=8)", &apt);

    for &batch in &BATCHES {
        let (o1, o2, o4, o8) = (
            num(1.0, batch, "overhead_share"),
            num(2.0, batch, "overhead_share"),
            num(4.0, batch, "overhead_share"),
            num(8.0, batch, "overhead_share"),
        );
        assert!(
            o1 > o2 && o2 > o4 && o4 > o8,
            "overhead share at batch {batch} must strictly decrease in chunk: \
             {o1:.3} {o2:.3} {o4:.3} {o8:.3}"
        );
        let (tps1, tps4) = (
            num(1.0, batch, "tokens_per_sec"),
            num(4.0, batch, "tokens_per_sec"),
        );
        assert!(
            tps4 >= 1.2 * tps1,
            "chunk-4 tokens/s {tps4:.0} must clear chunk-1 {tps1:.0} by >=20% at batch {batch}"
        );
    }

    std::fs::write("BENCH_steploop.json", format!("{text}\n")).expect("write BENCH_steploop.json");
    println!("\nwrote BENCH_steploop.json ({} bytes)", text.len() + 1);
}
