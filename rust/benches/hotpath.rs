//! L3 hot-path profile (perf pass, EXPERIMENTS.md §Perf): breaks one
//! decode step into its host-side components so the optimization loop
//! can see where non-PJRT time goes.
//!
//! Components measured:
//!   - literal creation for tokens/positions
//!   - dense KV gather (paged store -> batch tensor, composition change)
//!   - dense KV literal creation
//!   - PJRT execute (decode_b{B})
//!   - logits host readback + sampling

use fdpp::bench_support::banner;
use fdpp::kvcache::{KvCache, KvGeometry};
use fdpp::runtime::{literal_f32, literal_i32, to_vec_f32, Runtime};
use fdpp::sampling::{argmax, Sampler, SamplingParams};
use fdpp::util::bench::{bench, black_box};
use fdpp::util::rng::Rng;

fn main() -> fdpp::Result<()> {
    banner("hotpath", "decode-step component breakdown (real CPU PJRT)");
    let mut rt = Runtime::load("artifacts")?;
    let m = rt.manifest.model.clone();
    let geo = KvGeometry {
        n_layers: m.n_layers,
        n_heads: m.n_heads,
        head_dim: m.head_dim,
        block_tokens: 16,
        max_seq: m.max_seq,
    };

    for &b in &[1usize, 4, 8] {
        println!("\n-- bucket B={b} --");
        let entry = format!("decode_b{b}");
        rt.ensure_compiled(&entry)?;

        // Populate a paged store with b sequences of ~64 tokens.
        let mut kv = KvCache::new(geo, 256);
        let mut rng = Rng::seed_from_u64(3);
        let prefill_elems = geo.n_layers * geo.n_heads * 64 * geo.head_dim;
        for id in 0..b as u64 {
            kv.alloc_seq(id, 64).unwrap();
            let k: Vec<f32> = (0..prefill_elems).map(|_| rng.gen_f32(-0.5, 0.5)).collect();
            let v: Vec<f32> = (0..prefill_elems).map(|_| rng.gen_f32(-0.5, 0.5)).collect();
            kv.write_prefill(id, &k, &v, 64, 64).unwrap();
        }
        let ids: Vec<Option<u64>> = (0..b as u64).map(Some).collect();

        let toks: Vec<i32> = (0..b as i32).collect();
        let pos = vec![64i32; b];
        bench("literal_small (tokens+pos)", 3, 200, || {
            black_box(literal_i32(&toks, &[b]).unwrap());
            black_box(literal_i32(&pos, &[b]).unwrap());
        });

        let n = geo.dense_elems(b);
        let mut kd = vec![0.0f32; n];
        let mut vd = vec![0.0f32; n];
        bench("kv_gather_dense", 2, 20, || {
            kv.gather_dense(&ids, b, &mut kd, &mut vd).unwrap();
        });
        let shape = [geo.n_layers, b, geo.n_heads, geo.max_seq, geo.head_dim];
        bench("kv_literal_create", 2, 20, || {
            black_box(literal_f32(&kd, &shape).unwrap());
        });

        let toks_l = literal_i32(&toks, &[b])?;
        let pos_l = literal_i32(&pos, &[b])?;
        let kc = literal_f32(&kd, &shape)?;
        let vc = literal_f32(&vd, &shape)?;
        // Execute + readback (the irreducible PJRT part).
        let mut outs = rt.execute(&entry, &[&toks_l, &pos_l, &kc, &vc])?;
        let exec = bench("pjrt_execute (decode step)", 2, 10, || {
            outs = rt.execute(&entry, &[&toks_l, &pos_l, &kc, &vc]).unwrap();
        });

        let logits = to_vec_f32(&outs[0])?;
        let vocab = m.vocab_size;
        let mut sampler = Sampler::new(0);
        bench("logits_readback+sample", 3, 200, || {
            let l = to_vec_f32(&outs[0]).unwrap();
            for i in 0..b {
                black_box(sampler.sample(
                    &l[i * vocab..(i + 1) * vocab],
                    SamplingParams::default(),
                ));
            }
        });
        black_box(argmax(&logits[..vocab]));
        println!(
            "   => PJRT execute dominates; host components must stay <10% of {:.3} ms",
            exec.median_s * 1e3
        );
    }
    Ok(())
}
