//! Figure 7 — normalized flat GEMM performance vs N and B_N (M=8,
//! K=4096, A100). Reproduces the two regimes: small N is
//! parallelism-bound (best at small B_N, N/B_N ~ const), large N is
//! memory-bound (bigger B_N + double buffering wins).

use fdpp::bench_support::banner;
use fdpp::gemm::{bn_candidates, choose_tiling, parallelism};
use fdpp::hwmodel::{a100, flat_gemm_time_forced_bn};

fn main() {
    banner(
        "Figure 7",
        "normalized flat GEMM perf, M=8, K=4096, A100 (rows: N; cols: B_N)",
    );
    let gpu = a100();
    let ns = [1024usize, 2048, 4096, 8192, 16384, 32768];
    let bns = bn_candidates();

    print!("{:>8}", "N\\B_N");
    for bn in &bns {
        print!("{bn:>8}");
    }
    println!("{:>10}{:>8}", "best B_N", "N/B_N*");
    for &n in &ns {
        let times: Vec<f64> = bns
            .iter()
            .map(|&bn| flat_gemm_time_forced_bn(&gpu, 8, n, 4096, bn, 2))
            .collect();
        let tmin = times.iter().cloned().fold(f64::INFINITY, f64::min);
        print!("{n:>8}");
        for t in &times {
            print!("{:>8.2}", tmin / t); // normalized perf (1.00 = best)
        }
        let best = bns[times
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0];
        println!("{best:>10}{:>8}", parallelism(n, best));
    }

    println!("\nheuristic tile chooser (what the §4 kernel actually picks):");
    for &n in &ns {
        let t = choose_tiling(n, 4096, gpu.sms);
        println!(
            "  N={n:<6} -> B_N={:<4} double_buffer={}  (N/B_N = {})",
            t.b_n,
            t.double_buffer,
            parallelism(n, t.b_n)
        );
    }
    println!("\npaper: best N/B_N stays near a constant tied to the 108 SMs for small N;\nlarger tiles + double buffering win once N is large (memory-bound).");
}
