//! Figure 12 — decode-phase speedup on AMD RX7900XTX.
//! The AMD comparison set is HuggingFace vs FlashDecoding++ (the paper's
//! AMD figures compare against HF, the strongest baseline that runs on
//! ROCm for all four models).

use fdpp::baselines::{EngineKind, EngineModel};
use fdpp::bench_support::{banner, geomean};
use fdpp::config::paper_models;
use fdpp::hwmodel::rx7900xtx;

fn main() {
    banner("Figure 12", "decode speedup vs HuggingFace on AMD RX7900XTX");
    let gpu = rx7900xtx();
    let grid = [(1usize, 128usize), (1, 512), (1, 1024), (8, 512), (32, 256)];
    let mut pp = vec![];
    for model in paper_models() {
        println!("\n[{}]", model.name);
        print!("{:<18}", "engine \\ (bs,len)");
        let g: Vec<_> = grid.iter().filter(|&&(_, l)| l <= model.context).collect();
        for (b, l) in &g {
            print!("{:>12}", format!("({b},{l})"));
        }
        println!();
        let hf = EngineModel::new(EngineKind::HuggingFace);
        for kind in [EngineKind::HuggingFace, EngineKind::FlashDecodingPP] {
            print!("{:<18}", kind.as_str());
            let e = EngineModel::new(kind);
            for &&(b, l) in &g {
                let sp = hf.decode_token_time(&model, &gpu, b, l)
                    / e.decode_token_time(&model, &gpu, b, l);
                print!("{sp:>11.2}x");
                if kind == EngineKind::FlashDecodingPP {
                    pp.push(sp);
                }
            }
            println!();
        }
    }
    println!(
        "\nFlashDecoding++ vs HF on RX7900XTX: max {:.2}x, geomean {:.2}x   (paper: up to 2.18x on AMD)",
        pp.iter().cloned().fold(0.0f64, f64::max),
        geomean(&pp)
    );
}
