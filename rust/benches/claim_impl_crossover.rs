//! §5 claims — the two measured points motivating the heuristic
//! dataflow:
//!   (1) at batch 1, cuBLAS Tensor-Core GEMM reaches only 82.15% of
//!       FastGEMV's performance (Llama2-7B linear layer, A100);
//!   (2) at batch 4, CUDA-core GEMV reaches only 49.75% of Tensor Core.
//! Plus the full ImplA/B/C latency curves vs M, analytic and real-CPU.

use fdpp::bench_support::{banner, fmt_time, time_median};
use fdpp::dataflow::profile::micro_entry_name;
use fdpp::dataflow::ImplKind;
use fdpp::hwmodel::{a100, gemm_time};
use fdpp::runtime::{literal_f32, Runtime};
use fdpp::util::rng::Rng;

fn main() {
    banner("§5 claims", "ImplA/B/C crossover points");
    let gpu = a100();
    let (n, k) = (4096usize, 4096usize); // O projection of Llama2-7B

    let t_a1 = gemm_time(&gpu, ImplKind::A, 1, n, k, 2);
    let t_c1 = gemm_time(&gpu, ImplKind::C, 1, n, k, 2);
    println!(
        "claim 1: cuBLAS-TC perf / FastGEMV perf at M=1 = {:.2}%   (paper: 82.15%)",
        t_a1 / t_c1 * 100.0
    );
    let t_a4 = gemm_time(&gpu, ImplKind::A, 4, n, k, 2);
    let t_b4 = gemm_time(&gpu, ImplKind::B, 4, n, k, 2);
    println!(
        "claim 2: CUDA-core perf / Tensor-Core perf at M=4 = {:.2}%  (paper: 49.75%)",
        t_b4 / t_a4 * 100.0
    );

    println!("\n[analytic A100 latency vs M, op=[{n},{k}]]");
    println!("{:>6} {:>12} {:>12} {:>12} {:>8}", "M", "ImplA", "ImplB", "ImplC", "best");
    for m in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        let ta = gemm_time(&gpu, ImplKind::A, m, n, k, 2);
        let tb = gemm_time(&gpu, ImplKind::B, m, n, k, 2);
        let tc = gemm_time(&gpu, ImplKind::C, m, n, k, 2);
        let best = if ta <= tb && ta <= tc {
            "A"
        } else if tb <= tc {
            "B"
        } else {
            "C"
        };
        println!(
            "{m:>6} {:>12} {:>12} {:>12} {best:>8}",
            fmt_time(ta),
            fmt_time(tb),
            fmt_time(tc)
        );
    }

    // Real CPU microkernels.
    match Runtime::load("artifacts") {
        Ok(mut rt) => {
            println!("\n[real CPU PJRT, tiny-model micro op=qkv_proj [768,256]]");
            println!("{:>6} {:>12} {:>12} {:>12}", "M", "gemv(A)", "flat(B)", "conv(C)");
            let (nn, kk) = (768usize, 256usize);
            let mut rng = Rng::seed_from_u64(1);
            for m in [1usize, 4, 8, 32, 64] {
                let x: Vec<f32> = (0..m * kk).map(|_| rng.gen_f32(-1.0, 1.0)).collect();
                let w: Vec<f32> = (0..kk * nn).map(|_| rng.gen_f32(-1.0, 1.0)).collect();
                let x = literal_f32(&x, &[m, kk]).unwrap();
                let w = literal_f32(&w, &[kk, nn]).unwrap();
                print!("{m:>6}");
                for ik in [ImplKind::A, ImplKind::B, ImplKind::C] {
                    let name = micro_entry_name(ik, m, "qkv_proj");
                    rt.ensure_compiled(&name).unwrap();
                    rt.execute(&name, &[&x, &w]).unwrap();
                    let t = time_median(7, || {
                        rt.execute(&name, &[&x, &w]).unwrap();
                    });
                    print!(" {:>12}", fmt_time(t));
                }
                println!();
            }
        }
        Err(e) => println!("\n(artifacts unavailable: {e})"),
    }
}
