//! Figure 10 — decode-phase speedup on NVIDIA GPUs (A100, RTX3090).
//! Grid: 4 models x (batch size, input length) vs 7 engines; bars are
//! speedup over HuggingFace. Blank bars (n/a) where an engine does not
//! support a model (OpenPPL on OPT/ChatGLM2) — same as the paper.
//! Ends with the abstract's aggregate claims.

use fdpp::baselines::{EngineKind, EngineModel};
use fdpp::bench_support::{banner, geomean};
use fdpp::config::paper_models;
use fdpp::hwmodel::{a100, rtx3090, GpuProfile};

fn grid_for(model_ctx: usize) -> Vec<(usize, usize)> {
    // (batch, input len) pairs, bounded by the model's context.
    [(1, 128), (1, 512), (1, 1024), (1, 8192), (8, 1024), (32, 512), (64, 256)]
        .into_iter()
        .filter(|&(_, l)| l <= model_ctx)
        .collect()
}

fn run_gpu(gpu: &GpuProfile) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let engines = EngineKind::all();
    let mut vs_hf_pp = vec![];
    let mut vs_fd_pp = vec![];
    let mut per_engine_speedups: Vec<Vec<f64>> = vec![vec![]; engines.len()];

    for model in paper_models() {
        println!("\n[{} on {}]", model.name, gpu.name);
        print!("{:<18}", "engine \\ (bs,len)");
        let grid = grid_for(model.context);
        for (b, l) in &grid {
            print!("{:>12}", format!("({b},{l})"));
        }
        println!();
        let hf = EngineModel::new(EngineKind::HuggingFace);
        for (ei, kind) in engines.iter().enumerate() {
            print!("{:<18}", kind.as_str());
            if !kind.supports(&model) {
                for _ in &grid {
                    print!("{:>12}", "-");
                }
                println!();
                continue;
            }
            let e = EngineModel::new(*kind);
            for &(b, l) in &grid {
                let sp = hf.decode_token_time(&model, gpu, b, l)
                    / e.decode_token_time(&model, gpu, b, l);
                print!("{sp:>11.2}x");
                per_engine_speedups[ei].push(sp);
                if *kind == EngineKind::FlashDecodingPP {
                    vs_hf_pp.push(sp);
                    let fd = EngineModel::new(EngineKind::FlashDecoding)
                        .decode_token_time(&model, gpu, b, l);
                    vs_fd_pp.push(fd / e.decode_token_time(&model, gpu, b, l));
                }
            }
            println!();
        }
    }
    let max_hf = vs_hf_pp.iter().cloned().fold(0.0, f64::max);
    (vs_hf_pp, vs_fd_pp, vec![max_hf])
}

fn main() {
    banner(
        "Figure 10",
        "decode speedup vs HuggingFace on NVIDIA GPUs (rows: engines)",
    );
    let mut all_hf = vec![];
    let mut all_fd = vec![];
    for gpu in [a100(), rtx3090()] {
        let (hf, fd, _) = run_gpu(&gpu);
        all_hf.extend(hf);
        all_fd.extend(fd);
    }
    banner("Figure 10 aggregate", "abstract claims (NVIDIA)");
    println!(
        "FlashDecoding++ vs HuggingFace : max {:.2}x, geomean {:.2}x   (paper: up to 4.86x)",
        all_hf.iter().cloned().fold(0.0f64, f64::max),
        geomean(&all_hf)
    );
    println!(
        "FlashDecoding++ vs FlashDecoding: geomean {:.2}x              (paper: avg 1.37x on A100)",
        geomean(&all_fd)
    );
}
