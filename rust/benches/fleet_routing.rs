//! Fleet routing comparison: the pinned Zipf shared-prefix workload
//! replayed under round-robin, least-loaded, and cache-aware routing
//! on identical 4-replica sim fleets (`BENCH_fleet.json`).
//!
//! Runs [`fdpp::bench_support::fleet_routing_report`] twice at the
//! pinned seed, asserts the two reports are byte-identical (virtual
//! time, seeded workload — regressions show up as a *changed* report,
//! never as noise), asserts cache-aware routing achieves a strictly
//! higher engine-side prefix-hit rate than both baselines, prints a
//! per-policy table, and writes `BENCH_fleet.json` to the working
//! directory.
//!
//!   cargo bench --bench fleet_routing

use fdpp::bench_support::{banner, fleet_routing_report, row, FLEET_ROUTING_SEED};
use fdpp::util::json::Json;

const POLICIES: [&str; 3] = ["round_robin", "least_loaded", "cache_aware"];

fn main() {
    banner(
        "BENCH_fleet",
        "cache-aware fleet routing vs baselines (4 sim replicas, Zipf prefixes)",
    );
    let report = fleet_routing_report(FLEET_ROUTING_SEED).expect("harness runs");
    let again = fleet_routing_report(FLEET_ROUTING_SEED).expect("harness runs");
    let text = report.to_string();
    assert_eq!(
        text,
        again.to_string(),
        "fleet routing report must be byte-identical across runs of the same seed"
    );

    let num = |policy: &str, key: &str| {
        report
            .get(policy)
            .and_then(|p| p.get(key))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("report missing {policy}.{key}"))
    };
    row(
        "policy",
        &POLICIES.iter().map(|p| p.to_string()).collect::<Vec<_>>(),
    );
    for (label, key) in [
        ("prefix hit rate", "prefix_hit_rate"),
        ("prefix hits", "prefix_hits"),
        ("prefill tokens computed", "prefill_tokens_computed"),
        ("prefix tokens reused", "prefix_tokens_reused"),
        ("steps to drain", "steps"),
        ("tokens generated", "tokens_generated"),
    ] {
        let vals: Vec<String> = POLICIES
            .iter()
            .map(|p| {
                let v = num(p, key);
                if key == "prefix_hit_rate" {
                    format!("{v:.3}")
                } else {
                    format!("{v:.0}")
                }
            })
            .collect();
        row(label, &vals);
    }

    let hit = |p: &str| num(p, "prefix_hit_rate");
    let (rr, ll, ca) = (hit("round_robin"), hit("least_loaded"), hit("cache_aware"));
    assert!(
        ca > ll && ca > rr,
        "cache-aware hit rate {ca:.3} must strictly beat least-loaded {ll:.3} \
         and round-robin {rr:.3}"
    );

    std::fs::write("BENCH_fleet.json", format!("{text}\n")).expect("write BENCH_fleet.json");
    println!("\nwrote BENCH_fleet.json ({} bytes)", text.len() + 1);
}
