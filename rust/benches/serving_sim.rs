//! Serving-level comparison under load (extension of Figures 10-13):
//! the queueing simulator composes kernel-level engine models with each
//! engine's continuous-batching behaviour — throughput and first-token
//! latency vs arrival rate.

use fdpp::baselines::sim::{simulate, SimConfig};
use fdpp::baselines::EngineKind;
use fdpp::bench_support::banner;
use fdpp::config::paper_model;
use fdpp::hwmodel::a100;

fn main() {
    banner(
        "serving sim",
        "Llama2-7B on A100 — throughput / first-token latency vs load",
    );
    let model = paper_model("llama2-7b").unwrap();
    let gpu = a100();
    for rate in [0.5f64, 2.0, 8.0, 32.0] {
        println!("\n[arrival rate {rate} req/s, 128 requests, prompt 512, output 64]");
        println!(
            "{:<18} {:>12} {:>14} {:>14} {:>10}",
            "engine", "tok/s", "first p50-ish", "first p95", "mean batch"
        );
        for kind in EngineKind::all() {
            let cfg = SimConfig {
                engine: kind,
                max_batch: SimConfig::default_max_batch(kind),
                rate,
                n_requests: 128,
                prompt_len: 512,
                output_len: 64,
                seed: 9,
            };
            let r = simulate(&cfg, &model, &gpu);
            println!(
                "{:<18} {:>12.1} {:>13.0}ms {:>13.0}ms {:>10.1}",
                kind.as_str(),
                r.throughput_tok_s,
                r.mean_first_token_s * 1e3,
                r.p95_first_token_s * 1e3,
                r.mean_batch
            );
        }
    }
    println!("\npaper-level takeaway: kernel wins (C1-C3) compose with continuous\nbatching; HF's unbatched loop collapses under load while FD++ holds\nthe lowest latency at every rate.");
}
