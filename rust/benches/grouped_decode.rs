//! Prefix-shared grouped decode: the pinned Zipf shared-prefix
//! workload drained twice on the deterministic sim engine — grouping
//! off, then on — reporting output fingerprints and attention-reuse
//! accounting (`BENCH_grouped_decode.json`).
//!
//! Runs [`fdpp::bench_support::grouped_decode_report`] twice at the
//! pinned seed, asserts the two reports are byte-identical (virtual
//! clock, seeded workload — regressions show up as a *changed*
//! report, never as noise), asserts the two arms produce identical
//! output fingerprints (grouping reuses compute, it never changes a
//! token), asserts the grouped arm saves at least 30% of the decode
//! attention FLOPs, prints the comparison, and writes
//! `BENCH_grouped_decode.json` to the working directory.
//!
//!   cargo bench --bench grouped_decode

use fdpp::bench_support::{banner, grouped_decode_report, row, GROUPED_DECODE_SEED};
use fdpp::util::json::Json;

fn main() {
    banner(
        "BENCH_grouped_decode",
        "prefix-shared grouped decode: identical outputs, fewer attention FLOPs",
    );
    let report = grouped_decode_report(GROUPED_DECODE_SEED).expect("harness runs");
    let again = grouped_decode_report(GROUPED_DECODE_SEED).expect("harness runs");
    let text = report.to_string();
    assert_eq!(
        text,
        again.to_string(),
        "grouped decode report must be byte-identical across runs of the same seed"
    );

    let arm = |key: &str, field: &str| {
        report
            .get(key)
            .and_then(|j| j.get(field))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("report missing {key}.{field}"))
    };
    row("", &["ungrouped".into(), "grouped".into()]);
    for field in [
        "steps",
        "tokens_generated",
        "groups_formed",
        "attn_positions_total",
        "attn_positions_saved",
        "attn_flops_saved",
    ] {
        row(
            field,
            &[
                format!("{:.0}", arm("ungrouped", field)),
                format!("{:.0}", arm("grouped", field)),
            ],
        );
    }

    assert_eq!(
        report.get("fingerprints_match").and_then(Json::as_bool),
        Some(true),
        "grouped decode must be byte-identical to the per-sequence path"
    );
    let reduction = report
        .get("attn_flop_reduction")
        .and_then(Json::as_f64)
        .expect("report carries attn_flop_reduction");
    row("attn_flop_reduction", &[format!("{:.1}%", reduction * 100.0)]);
    assert!(
        reduction >= 0.30,
        "grouped decode must save at least 30% of decode attention FLOPs \
         on the shared-prefix workload, got {reduction:.3}"
    );

    std::fs::write("BENCH_grouped_decode.json", format!("{text}\n"))
        .expect("write BENCH_grouped_decode.json");
    println!("\nwrote BENCH_grouped_decode.json ({} bytes)", text.len() + 1);
}
