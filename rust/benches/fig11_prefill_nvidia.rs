//! Figure 11 — prefill-phase (first token) speedup on NVIDIA GPUs.
//! Grid: models x input length vs engines; speedup over HuggingFace.

use fdpp::baselines::{EngineKind, EngineModel};
use fdpp::bench_support::{banner, geomean};
use fdpp::config::paper_models;
use fdpp::hwmodel::{a100, rtx3090};

fn main() {
    banner("Figure 11", "prefill (first token) speedup vs HuggingFace, NVIDIA");
    let lens = [128usize, 512, 1024, 4096, 8192];
    let mut pp_speedups = vec![];
    for gpu in [a100(), rtx3090()] {
        for model in paper_models() {
            println!("\n[{} on {}]", model.name, gpu.name);
            print!("{:<18}", "engine \\ len");
            let grid: Vec<usize> = lens.iter().copied().filter(|&l| l <= model.context).collect();
            for l in &grid {
                print!("{l:>10}");
            }
            println!();
            let hf = EngineModel::new(EngineKind::HuggingFace);
            for kind in EngineKind::all() {
                print!("{:<18}", kind.as_str());
                if !kind.supports(&model) {
                    for _ in &grid {
                        print!("{:>10}", "-");
                    }
                    println!();
                    continue;
                }
                let e = EngineModel::new(kind);
                for &l in &grid {
                    let sp =
                        hf.prefill_time(&model, &gpu, 1, l) / e.prefill_time(&model, &gpu, 1, l);
                    print!("{sp:>9.2}x");
                    if kind == EngineKind::FlashDecodingPP {
                        pp_speedups.push(sp);
                    }
                }
                println!();
            }
        }
    }
    println!(
        "\nFlashDecoding++ prefill vs HF: max {:.2}x, geomean {:.2}x",
        pp_speedups.iter().cloned().fold(0.0f64, f64::max),
        geomean(&pp_speedups)
    );
    println!("paper: prefill gains are modest relative to decode (Fig. 11) — the\nprefill GEMMs are conventional for every engine; wins come from fused\nattention and lower dispatch overhead.");
}
