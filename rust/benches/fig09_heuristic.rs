//! Figure 9 — heuristic dataflow: inflection points M1/M2 per [N, K]
//! shape and the resulting lookup table.
//!
//! Two backends:
//!  (a) the analytic A100 model over Llama2-7B's four shapes (the
//!      paper's Figure 9(c) example), and
//!  (b) the real-CPU profile over the tiny model's microkernel artifacts
//!      (the same decision flow the `fdpp profile-dataflow` command runs).

use fdpp::bench_support::banner;
use fdpp::config::paper_model;
use fdpp::dataflow::profile::build_lookup_table;
use fdpp::dataflow::{default_m_sweep, find_inflections, ImplKind};
use fdpp::hwmodel::{a100, gemm_time};
use fdpp::runtime::Runtime;

fn main() {
    banner("Figure 9", "heuristic dataflow inflection points");

    // (a) analytic backend, Llama2-7B on A100 (paper's example).
    let model = paper_model("llama2-7b").unwrap();
    let gpu = a100();
    let ms = default_m_sweep();
    println!("[analytic A100, Llama2-7B — Figure 9(c)]");
    println!("{:<24} {:>6} {:>6}", "op [N,K]", "M1", "M2");
    for (op, n, k) in model.linear_shapes() {
        let mut profiler =
            |ik: ImplKind, m: usize| -> fdpp::Result<f64> { Ok(gemm_time(&gpu, ik, m, n, k, 2)) };
        let inf = find_inflections(op, n, k, &ms, &mut profiler).unwrap();
        println!(
            "{:<24} {:>6} {:>6}",
            format!("{op} [{n},{k}]"),
            inf.m1,
            inf.m2
        );
    }
    println!(
        "\npaper: FastGEMV below M1 (batch 1-4), flat GEMM in [M1, M2) (decode\nbatches / short prefill), CUTLASS-style above M2 (long prefill)."
    );

    // (b) real CPU microkernels.
    match Runtime::load("artifacts") {
        Ok(mut rt) => {
            println!("\n[real CPU PJRT, tiny-model microkernels]");
            match build_lookup_table(&mut rt, 3) {
                Ok(table) => {
                    println!("{:<24} {:>6} {:>6}", "op [N,K]", "M1", "M2");
                    for e in &table.entries {
                        println!(
                            "{:<24} {:>6} {:>6}",
                            format!("{} [{},{}]", e.op, e.n, e.k),
                            e.m1,
                            e.m2
                        );
                    }
                    println!("(CPU crossovers differ from the A100's — that's the point of\nprofiling per hardware; the decision-flow machinery is identical.)");
                }
                Err(e) => println!("micro profile failed: {e}"),
            }
        }
        Err(e) => println!("\n(artifacts unavailable: {e}; skipping real-CPU backend)"),
    }
}
