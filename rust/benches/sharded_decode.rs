//! Sharded decode tradeoff: the pinned seeded workload drained on
//! `EngineCore<ShardedBackend<SimBackend>>` across the M×batch grid
//! (M∈{1,2,4,8} × batch∈{1,8,32}), reporting modeled decode tokens/s
//! and collective overhead per cell (`BENCH_sharded.json`).
//!
//! Runs [`fdpp::bench_support::sharded_decode_report`] twice at the
//! pinned seed, asserts the two reports are byte-identical (virtual
//! clock, seeded workload, fixed-order f64 accumulation — regressions
//! show up as a *changed* report, never as noise), asserts collective
//! overhead is zero at M=1 and strictly increasing in M at batch 1,
//! prints the grid, and writes `BENCH_sharded.json` to the working
//! directory.
//!
//!   cargo bench --bench sharded_decode

use fdpp::bench_support::{banner, row, sharded_decode_report, SHARDED_DECODE_SEED};
use fdpp::util::json::Json;

const SHARDS: [f64; 4] = [1.0, 2.0, 4.0, 8.0];
const BATCHES: [f64; 3] = [1.0, 8.0, 32.0];

fn main() {
    banner(
        "BENCH_sharded",
        "simulated tensor-parallel decode: tokens/s and collective overhead",
    );
    let report = sharded_decode_report(SHARDED_DECODE_SEED).expect("harness runs");
    let again = sharded_decode_report(SHARDED_DECODE_SEED).expect("harness runs");
    let text = report.to_string();
    assert_eq!(
        text,
        again.to_string(),
        "sharded decode report must be byte-identical across runs of the same seed"
    );

    let cells = report
        .get("grid")
        .and_then(Json::as_arr)
        .expect("report carries the grid");
    let num = |shards: f64, batch: f64, key: &str| {
        cells
            .iter()
            .find(|c| {
                c.get("shards").and_then(Json::as_f64) == Some(shards)
                    && c.get("batch").and_then(Json::as_f64) == Some(batch)
            })
            .and_then(|c| c.get(key))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("report missing grid[M={shards},batch={batch}].{key}"))
    };

    row(
        "M \\ batch",
        &BATCHES.iter().map(|b| format!("{b:.0}")).collect::<Vec<_>>(),
    );
    for &m in &SHARDS {
        let vals: Vec<String> = BATCHES
            .iter()
            .map(|&b| {
                let tps = num(m, b, "modeled_decode_tokens_per_sec");
                let ov = num(m, b, "collective_overhead");
                format!("{tps:.0}/{:.0}%", ov * 100.0)
            })
            .collect();
        row(&format!("M={m:.0} tok/s / coll%"), &vals);
    }

    let overhead = |m: f64| num(m, 1.0, "collective_overhead");
    assert_eq!(overhead(1.0), 0.0, "M=1 must run no collectives");
    let (o2, o4, o8) = (overhead(2.0), overhead(4.0), overhead(8.0));
    assert!(
        o2 > 0.0 && o4 > o2 && o8 > o4,
        "collective overhead at batch 1 must be strictly increasing in M: \
         {o2:.3} {o4:.3} {o8:.3}"
    );

    std::fs::write("BENCH_sharded.json", format!("{text}\n")).expect("write BENCH_sharded.json");
    println!("\nwrote BENCH_sharded.json ({} bytes)", text.len() + 1);
}
