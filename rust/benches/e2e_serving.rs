//! End-to-end serving bench on the real tiny model (CPU PJRT): offline
//! batch throughput across decode-bucket configurations, plus the
//! async-vs-sync softmax engine comparison. This is the bench-formatted
//! twin of examples/serve_workload.rs.

use std::time::Instant;

use fdpp::api::{GenRequest, InferenceEngine};
use fdpp::bench_support::banner;
use fdpp::config::EngineConfig;
use fdpp::engine::Engine;
use fdpp::runtime::Runtime;
use fdpp::workload::{generate, WorkloadSpec};

fn run(label: &str, cfg: EngineConfig, n_requests: usize) -> fdpp::Result<()> {
    let rt = Runtime::load("artifacts")?;
    let mut engine = Engine::new(rt, cfg)?;
    engine.warmup()?;
    let trace = generate(&WorkloadSpec {
        rate: 1e9, // offline: all requests available at t=0
        n_requests,
        prompt_len: (8, 40),
        max_new_tokens: (8, 24),
        seed: 7,
    });
    let t0 = Instant::now();
    let mut handles = vec![];
    for r in &trace {
        let req = GenRequest::text(r.prompt.as_str()).max_new_tokens(r.max_new_tokens);
        handles.push(engine.submit(req)?);
    }
    engine.run_to_completion()?;
    let wall = t0.elapsed();
    let m = &engine.metrics;
    println!(
        "{label:<44} {:>6} tok  {:>9.1} tok/s  p50tok {:>9.2?}  overhead {:>8.2?}  rebuilds {:>3}",
        m.tokens_generated,
        m.tokens_generated as f64 / wall.as_secs_f64(),
        m.per_token.percentile(0.5),
        m.step_overhead.mean(),
        m.kv_rebuilds,
    );
    Ok(())
}

fn main() -> fdpp::Result<()> {
    banner(
        "E2E serving",
        "real tiny model on CPU PJRT — offline batch, 12 requests",
    );
    // Bucket ablation: bigger decode buckets amortize per-step overhead.
    for buckets in [vec![1], vec![1, 2], vec![1, 2, 4], vec![1, 2, 4, 8]] {
        let label = format!("async softmax, buckets {buckets:?}");
        let max_running = *buckets.last().unwrap();
        run(
            &label,
            EngineConfig {
                decode_buckets: buckets,
                max_running,
                ..EngineConfig::default()
            },
            12,
        )?;
    }
    // Async vs sync engine (C1 on/off), same trace, bucket sets matched
    // to the available sync artifacts.
    run(
        "async softmax (C1 on),  buckets [1,8]",
        EngineConfig {
            decode_buckets: vec![1, 8],
            async_softmax: true,
            ..EngineConfig::default()
        },
        12,
    )?;
    run(
        "sync softmax  (C1 off), buckets [1,8]",
        EngineConfig {
            decode_buckets: vec![1, 8],
            async_softmax: false,
            ..EngineConfig::default()
        },
        12,
    )?;
    println!("\n(CPU-interpret kernel timings are not a GPU proxy; the async/sync\ncomparison validates plumbing and accounting, the analytic benches\nreproduce the paper's GPU ratios.)");
    Ok(())
}
