//! Perf-trajectory harness: the pinned, seeded serving workload whose
//! report CI tracks across commits (`BENCH_serving.json`).
//!
//! Runs [`fdpp::bench_support::perf_trajectory_report`] twice at the
//! pinned seed, asserts the two reports are byte-identical (the whole
//! point of measuring in virtual time — a perf regression shows up as a
//! *changed trajectory*, never as run-to-run noise), prints the report
//! as a table, and writes `BENCH_serving.json` to the working
//! directory.
//!
//!   cargo bench --bench perf_trajectory

use fdpp::bench_support::{banner, perf_trajectory_report, row, PERF_TRAJECTORY_SEED};
use fdpp::util::json::Json;

fn main() {
    banner(
        "BENCH_serving",
        "pinned serving perf trajectory (sim engine, virtual time)",
    );
    let report = perf_trajectory_report(PERF_TRAJECTORY_SEED).expect("harness runs");
    let again = perf_trajectory_report(PERF_TRAJECTORY_SEED).expect("harness runs");
    let text = report.to_string();
    assert_eq!(
        text,
        again.to_string(),
        "perf trajectory must be byte-identical across runs of the same seed"
    );

    let num = |key: &str| {
        report
            .get(key)
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("report missing key {key}"))
    };
    row("seed", &[format!("{}", num("seed"))]);
    row("requests", &[format!("{}", num("requests"))]);
    row("tokens generated", &[format!("{}", num("tokens_generated"))]);
    row("virtual time", &[format!("{:.0}ms", num("virtual_ms"))]);
    row("tokens/s (virtual)", &[format!("{:.1}", num("tokens_per_sec"))]);
    row("steps/s (virtual)", &[format!("{:.1}", num("steps_per_sec"))]);
    row(
        "ttft p50 / p99",
        &[
            format!("{}us", num("ttft_p50_us")),
            format!("{}us", num("ttft_p99_us")),
        ],
    );
    row(
        "inter-token p50 / p99",
        &[
            format!("{}us", num("inter_token_p50_us")),
            format!("{}us", num("inter_token_p99_us")),
        ],
    );
    row("prefix hit rate", &[format!("{:.3}", num("prefix_hit_rate"))]);
    let overhead = report.field("step_overhead").expect("step_overhead object");
    row("step overhead (us sums)", &[overhead.to_string()]);

    std::fs::write("BENCH_serving.json", format!("{text}\n")).expect("write BENCH_serving.json");
    println!("\nwrote BENCH_serving.json ({} bytes)", text.len() + 1);
}
