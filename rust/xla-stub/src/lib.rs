//! API-compatible stub of the `xla-rs` PJRT bindings.
//!
//! The fdpp crate talks to PJRT through a narrow surface: literals,
//! a CPU client, HLO-text compilation, and executable dispatch. This
//! stub reproduces that surface so the whole workspace builds and the
//! non-PJRT layers (KV cache, prefix cache, scheduler, batcher, server
//! plumbing, analytic models, simulation engine) run and test on a bare
//! checkout with no xla_extension install.
//!
//! Host-side literal operations (construction, reshape, readback) are
//! real. Anything that would need the PJRT runtime — client creation,
//! compilation, execution, .npy weight loading — returns `Error` with a
//! "stub" message; callers already treat runtime-load failure as "skip
//! the artifact path". Swapping this path dependency for a real xla-rs
//! checkout restores the PJRT path without source changes.

use std::fmt;
use std::path::Path;

/// Stub error: carries only a message.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error::new(format!(
        "{what} unavailable: fdpp was built against the in-repo xla stub \
         (no PJRT). Point Cargo at a real xla-rs checkout and run \
         `make artifacts` to enable the runtime path."
    ))
}

/// Element types the fdpp hot path moves across the boundary.
/// Public only because `NativeType` mentions it; not part of the API.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side literal: typed buffer + dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

/// Sealed-ish conversion trait for native element types.
pub trait NativeType: Copy {
    fn wrap(data: &[Self]) -> Buf;
    fn unwrap(buf: &Buf) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Buf {
        Buf::F32(data.to_vec())
    }
    fn unwrap(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Buf {
        Buf::I32(data.to_vec())
    }
    fn unwrap(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            buf: T::wrap(data),
        }
    }

    fn element_count(&self) -> usize {
        match &self.buf {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
        }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape: {} elements into shape {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal {
            buf: self.buf.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Read the buffer back as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.buf).ok_or_else(|| Error::new("to_vec: element type mismatch"))
    }

    /// Decompose a tuple literal. The stub never produces tuples (no
    /// execution), so reaching this is a stub-path bug.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_err("tuple decomposition"))
    }
}

/// Raw-bytes loading (xla-rs exposes .npy reading through this trait).
pub trait FromRawBytes: Sized {
    fn read_npy<P: AsRef<Path>>(path: P, ctx: &()) -> Result<Self>;
}

impl FromRawBytes for Literal {
    fn read_npy<P: AsRef<Path>>(path: P, _ctx: &()) -> Result<Self> {
        Err(Error::new(format!(
            "read_npy {}: weight loading requires the real xla-rs build",
            path.as_ref().display()
        )))
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(Error::new(format!(
            "HLO parse {path}: requires the real xla-rs build"
        )))
    }
}

/// Computation wrapper (opaque in the stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device-side buffer handle. Never constructed by the stub.
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("device readback"))
    }
}

/// Compiled executable handle. Never constructed by the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PJRT execution"))
    }
}

/// PJRT client. `cpu()` fails in the stub, which makes `Runtime::load`
/// fail with a clear message; everything artifact-dependent skips.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(stub_err("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PJRT compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn literal_type_mismatch() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn runtime_paths_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
