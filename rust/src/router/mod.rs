//! Request intake and sequence lifecycle.
//!
//! A [`crate::api::GenRequest`] enters through an engine's `submit`,
//! becomes a [`Sequence`] with a state machine (Queued -> Decoding ->
//! Paused -> Finished), and streams [`crate::api::GenEvent`]s back over
//! a *bounded* [`crate::api::EventSender`] channel. The engine thread is the single
//! owner of sequence state; the async server side only holds the
//! receiver endpoints.
//!
//! The router's queue is priority-aware: `peek_next`/`pop_next` select
//! the highest-priority sequence, FIFO within a priority level, so both
//! engines admit in the same order the scheduler's admission outlook
//! was computed for. [`Router::depths_by_priority`] exposes the
//! instantaneous per-priority queue depths for the stats snapshot.
//!
//! This module also owns the [`RequestRegistry`]: the *cross-connection*
//! index of in-flight requests. The engine-side [`Router`] is
//! single-owner state on the engine thread, while the registry is
//! shared (thread-safe) across every server connection, mapping the
//! global ids minted at submit to engine request ids — the mechanism
//! behind cancel-from-any-connection and the admin
//! `{"admin": {"cancel_tenant": ...}}` verb (docs/PROTOCOL.md).

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::Mutex;
use std::time::Duration;

use crate::api::{
    event_channel_with_wakeup, EmitResult, EventSender, FinishReason, GenRequest, Prompt,
    RequestId, SubmissionHandle, Usage, Wakeup,
};
use crate::error::{Error, Result};
use crate::sampling::SamplingParams;
use crate::tokenizer::ByteTokenizer;
use crate::util::rng::Rng;

/// Sequence lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    Queued,
    Decoding,
    /// Parked by stream backpressure: the sequence holds its KV blocks
    /// but no decode lane; it rejoins the batch when its client drains.
    Paused,
    Finished(FinishReason),
}

/// Engine-side sequence record.
#[derive(Debug)]
pub struct Sequence {
    pub id: RequestId,
    pub tenant: String,
    pub priority: i32,
    pub state: SeqState,
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// Stop sequences as token ids (no BOS); generation finishes with
    /// `FinishReason::Stop` when `generated` ends with any of them.
    pub stop: Vec<Vec<u32>>,
    /// Bounded event stream to the client (see [`crate::api`] flow
    /// control).
    pub stream: EventSender,
    /// Engine-clock timestamps ([`crate::util::clock::Clock`]): plain
    /// `Duration`s since the engine clock's epoch, so the sim path is
    /// fully deterministic under a manual clock.
    pub arrived: Duration,
    pub first_token_at: Option<Duration>,
    /// When the sequence was last parked by stream backpressure
    /// (engine-clock time); `None` while not paused. Drives the
    /// `stream_idle_timeout` demotion of long-parked requests.
    pub paused_at: Option<Duration>,
    /// Current context length (prompt + generated) stored in KV.
    pub kv_len: usize,
    /// Prompt tokens attached from the prefix cache at admission.
    pub cached_prompt_tokens: usize,
    /// Whether the sequence was ever admitted (prefill ran). Cancelled
    /// while queued => false, and its usage reports zero prefill work.
    pub admitted: bool,
    /// Whether this request has already been counted as a dedup hit
    /// (its admission deferred at least once behind an identical
    /// in-flight prompt), so the metric counts requests, not retries.
    pub dedup_waited: bool,
}

impl Sequence {
    /// Build a queued sequence from a typed request (shared by both
    /// engine implementations; `stop` is pre-encoded by the caller's
    /// tokenizer and `max_new_tokens` pre-clamped to the engine cap).
    pub fn queued(
        id: RequestId,
        req: &GenRequest,
        prompt_tokens: Vec<u32>,
        stop: Vec<Vec<u32>>,
        max_new_tokens: usize,
        stream: EventSender,
    ) -> Self {
        Sequence {
            id,
            tenant: if req.tenant.is_empty() {
                "default".to_string()
            } else {
                req.tenant.clone()
            },
            priority: req.priority,
            state: SeqState::Queued,
            prompt: prompt_tokens,
            // Reserved up front so steady-state decode pushes never
            // reallocate (the zero-alloc-per-token invariant).
            generated: Vec::with_capacity(max_new_tokens),
            max_new_tokens,
            params: req.params,
            stop,
            stream,
            arrived: Duration::ZERO,
            first_token_at: None,
            paused_at: None,
            kv_len: 0,
            cached_prompt_tokens: 0,
            admitted: false,
            dedup_waited: false,
        }
    }

    pub fn last_token(&self) -> u32 {
        *self
            .generated
            .last()
            .unwrap_or_else(|| self.prompt.last().expect("non-empty prompt"))
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, SeqState::Finished(_))
    }

    /// True when the generated tail matches any stop sequence.
    pub fn hit_stop(&self) -> bool {
        self.stop
            .iter()
            .any(|s| !s.is_empty() && self.generated.ends_with(s))
    }

    /// Per-request token accounting (reported on finish). Until the
    /// sequence is admitted no prefill work has happened, so both
    /// cached and prefilled counts stay zero; after admission they
    /// partition `prompt_tokens`.
    pub fn usage(&self) -> Usage {
        Usage {
            prompt_tokens: self.prompt.len(),
            cached_prompt_tokens: self.cached_prompt_tokens,
            prefill_tokens: if self.admitted {
                self.prompt.len() - self.cached_prompt_tokens
            } else {
                0
            },
            generated_tokens: self.generated.len(),
        }
    }

    /// Push one generated token to the client's bounded stream. Never
    /// blocks: callers decode only sequences whose stream had credit at
    /// the start of the step, so `Full` cannot occur mid-step; `Closed`
    /// means the client hung up and the engine should reclaim.
    pub fn emit_token(&self, token: u32) -> EmitResult {
        self.stream.try_token(token)
    }

    /// Record the terminal event (always deliverable; dedicated slot).
    pub fn emit_finish(&self, reason: FinishReason, usage: Usage) {
        self.stream.finish(reason, usage);
    }
}

/// Tokenize a request's prompt (shared submit front half; both engines
/// run their own capacity checks on the result before enqueueing).
pub fn encode_prompt(tokenizer: &ByteTokenizer, prompt: &Prompt) -> Result<Vec<u32>> {
    let toks = match prompt {
        Prompt::Text(t) => tokenizer.encode(t),
        Prompt::Tokens(t) => t.clone(),
    };
    if toks.is_empty() {
        return Err(Error::Request("empty prompt".into()));
    }
    Ok(toks)
}

/// Engine-side submit parameters shared by every implementation: the
/// configured budget cap and stream capacity, the engine clock's
/// current time (stamped as the sequence's arrival), and the optional
/// engine-loop [`Wakeup`] each new stream notifies on drain.
#[derive(Debug)]
pub struct SubmitContext<'a> {
    pub max_new_cap: usize,
    pub stream_capacity: usize,
    pub now: Duration,
    pub wakeup: Option<&'a Wakeup>,
}

/// Shared submit back half: validate the budget, encode stop sequences,
/// clamp to the engine cap, create the bounded event stream, and
/// enqueue — identical for every engine so the sim twin cannot drift
/// from the real one.
pub fn enqueue_request(
    router: &mut Router,
    tokenizer: &ByteTokenizer,
    req: &GenRequest,
    prompt_tokens: Vec<u32>,
    ctx: &SubmitContext,
) -> Result<SubmissionHandle> {
    if req.max_new_tokens == 0 {
        return Err(Error::Request("max_new_tokens must be at least 1".into()));
    }
    let stop: Vec<Vec<u32>> = req.stop.iter().map(|s| tokenizer.encode_raw(s)).collect();
    let (tx, rx) = event_channel_with_wakeup(ctx.stream_capacity, ctx.wakeup.cloned());
    let id = router.allocate_id();
    let max_new = req.max_new_tokens.min(ctx.max_new_cap);
    let mut seq = Sequence::queued(id, req, prompt_tokens, stop, max_new, tx);
    seq.arrived = ctx.now;
    router.enqueue(seq);
    Ok(SubmissionHandle { id, events: rx })
}

/// Priority-aware intake queue owned by the engine.
#[derive(Debug, Default)]
pub struct Router {
    next_id: RequestId,
    queue: VecDeque<Sequence>,
}

impl Router {
    pub fn new() -> Self {
        Router {
            next_id: 1,
            queue: VecDeque::new(),
        }
    }

    /// Allocate the next request id (monotone).
    pub fn allocate_id(&mut self) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Re-base the id counter so several engines can mint ids from
    /// disjoint ranges (the fleet layer gives replica `k` the base
    /// `k << 48`). Must be called before the first allocation: ids are
    /// monotone and already-handed-out ids must never repeat.
    pub fn set_id_base(&mut self, base: RequestId) {
        debug_assert_eq!(self.next_id, 1, "id base must be set before any allocation");
        self.next_id = base + 1;
    }

    /// Add a queued sequence to the intake queue.
    pub fn enqueue(&mut self, seq: Sequence) {
        self.queue.push_back(seq);
    }

    /// Index of the sequence `pop_next` would take: highest priority,
    /// earliest arrival within a level.
    fn next_index(&self) -> Option<usize> {
        let mut best: Option<(usize, i32)> = None;
        for (i, s) in self.queue.iter().enumerate() {
            if best.map(|(_, p)| s.priority > p).unwrap_or(true) {
                best = Some((i, s.priority));
            }
        }
        best.map(|(i, _)| i)
    }

    /// The sequence the next prefill would admit (admission outlook must
    /// peek the same sequence `pop_next` will return).
    pub fn peek_next(&self) -> Option<&Sequence> {
        self.next_index().and_then(|i| self.queue.get(i))
    }

    pub fn pop_next(&mut self) -> Option<Sequence> {
        self.next_index().and_then(|i| self.queue.remove(i))
    }

    /// Requeue at the front (admission backoff under KV pressure).
    pub fn requeue_front(&mut self, seq: Sequence) {
        self.queue.push_front(seq);
    }

    /// Remove a queued sequence by id (cancellation before admission).
    pub fn take(&mut self, id: RequestId) -> Option<Sequence> {
        let idx = self.queue.iter().position(|s| s.id == id)?;
        self.queue.remove(idx)
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Instantaneous queue depth per priority level, ascending by
    /// priority (the stats snapshot's `queue_depths`).
    pub fn depths_by_priority(&self) -> Vec<(i32, usize)> {
        let mut depths: BTreeMap<i32, usize> = BTreeMap::new();
        for s in &self.queue {
            *depths.entry(s.priority).or_default() += 1;
        }
        depths.into_iter().collect()
    }
}

// ---------------------------------------------------------------------
// Cross-connection request registry
// ---------------------------------------------------------------------

/// One registered in-flight request.
#[derive(Debug, Clone)]
pub struct RegisteredRequest {
    pub engine_id: RequestId,
    pub tenant: String,
    pub priority: i32,
}

/// Thread-safe index of every in-flight request a server front-end has
/// submitted, keyed by the *global id* minted at submit. Connection
/// handlers share one registry, so a request can be cancelled from any
/// connection — including in bulk, per tenant, via the admin verb — not
/// just the one that submitted it. Entries are removed when the
/// request's terminal event is delivered, so `depth` is the number of
/// requests currently in flight server-wide.
///
/// Global ids look like `"g7-3f9c2a1d08b4e657"`: a monotone counter
/// plus a 64-bit suffix from a per-process randomly seeded stream, so
/// ids are not enumerable — one client cannot cancel another's request
/// by guessing (not cryptographic; the admin verb itself still belongs
/// on a trusted network, like the rest of the unauthenticated
/// protocol).
#[derive(Debug, Default)]
pub struct RequestRegistry {
    inner: Mutex<RegistryInner>,
}

#[derive(Debug)]
struct RegistryInner {
    next: u64,
    ids: Rng,
    entries: HashMap<String, RegisteredRequest>,
}

impl Default for RegistryInner {
    fn default() -> Self {
        RegistryInner {
            next: 0,
            ids: Rng::seed_from_u64(registry_seed()),
            entries: HashMap::new(),
        }
    }
}

/// Per-process unpredictable seed for global-id suffixes, derived from
/// std's randomly keyed SipHash state (OS entropy, no extra deps).
fn registry_seed() -> u64 {
    use std::hash::{BuildHasher, Hasher};
    let mut h = std::collections::hash_map::RandomState::new().build_hasher();
    h.write_u64(std::process::id() as u64);
    h.finish()
}

impl RequestRegistry {
    pub fn new() -> Self {
        RequestRegistry::default()
    }

    /// Mint a global id for a freshly submitted request. The empty
    /// tenant normalizes to `"default"`, matching [`Sequence::queued`].
    pub fn register(&self, engine_id: RequestId, tenant: &str, priority: i32) -> String {
        let mut g = self.inner.lock().unwrap();
        g.next += 1;
        let gid = format!("g{}-{:016x}", g.next, g.ids.next_u64());
        let tenant = if tenant.is_empty() { "default" } else { tenant };
        g.entries.insert(
            gid.clone(),
            RegisteredRequest {
                engine_id,
                tenant: tenant.to_string(),
                priority,
            },
        );
        gid
    }

    /// Engine id for a live global id (from any connection).
    pub fn resolve(&self, global_id: &str) -> Option<RequestId> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(global_id)
            .map(|e| e.engine_id)
    }

    /// Drop a finished request's entry; `false` if it was already gone.
    pub fn remove(&self, global_id: &str) -> bool {
        self.inner.lock().unwrap().entries.remove(global_id).is_some()
    }

    /// Engine ids of every live request for a tenant (the admin
    /// bulk-cancel set). Entries stay registered until their terminal
    /// event flows, exactly like single cancels.
    pub fn tenant_ids(&self, tenant: &str) -> Vec<RequestId> {
        let tenant = if tenant.is_empty() { "default" } else { tenant };
        let g = self.inner.lock().unwrap();
        let mut ids: Vec<RequestId> = g
            .entries
            .values()
            .filter(|e| e.tenant == tenant)
            .map(|e| e.engine_id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Requests currently in flight server-wide.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{event_channel, EventReceiver};

    fn mk_seq(r: &mut Router, prompt: Vec<u32>, priority: i32) -> (RequestId, EventReceiver) {
        let (tx, rx) = event_channel(16);
        let req = GenRequest::tokens(prompt.clone()).priority(priority);
        let id = r.allocate_id();
        r.enqueue(Sequence::queued(id, &req, prompt, Vec::new(), 4, tx));
        (id, rx)
    }

    #[test]
    fn submit_assigns_monotone_ids_fifo_within_priority() {
        let mut r = Router::new();
        let (a, _rx1) = mk_seq(&mut r, vec![1], 0);
        let (b, _rx2) = mk_seq(&mut r, vec![2], 0);
        assert!(b > a);
        assert_eq!(r.queued(), 2);
        assert_eq!(r.peek_next().unwrap().id, a);
        assert_eq!(r.pop_next().unwrap().id, a, "FIFO");
    }

    #[test]
    fn higher_priority_pops_first() {
        let mut r = Router::new();
        let (low, _r1) = mk_seq(&mut r, vec![1], 0);
        let (high, _r2) = mk_seq(&mut r, vec![2], 5);
        let (low2, _r3) = mk_seq(&mut r, vec![3], 0);
        assert_eq!(r.peek_next().unwrap().id, high);
        assert_eq!(r.pop_next().unwrap().id, high);
        assert_eq!(r.pop_next().unwrap().id, low, "FIFO among equals");
        assert_eq!(r.pop_next().unwrap().id, low2);
        assert!(r.pop_next().is_none());
    }

    #[test]
    fn depths_by_priority_counts_levels() {
        let mut r = Router::new();
        let (_a, _r1) = mk_seq(&mut r, vec![1], 0);
        let (_b, _r2) = mk_seq(&mut r, vec![2], 5);
        let (_c, _r3) = mk_seq(&mut r, vec![3], 0);
        assert_eq!(r.depths_by_priority(), vec![(0, 2), (5, 1)]);
        r.pop_next().unwrap(); // takes the priority-5 one
        assert_eq!(r.depths_by_priority(), vec![(0, 2)]);
    }

    #[test]
    fn take_removes_by_id() {
        let mut r = Router::new();
        let (a, _r1) = mk_seq(&mut r, vec![1], 0);
        let (b, _r2) = mk_seq(&mut r, vec![2], 0);
        assert_eq!(r.take(b).unwrap().id, b);
        assert!(r.take(b).is_none(), "already taken");
        assert_eq!(r.queued(), 1);
        assert_eq!(r.pop_next().unwrap().id, a);
    }

    #[test]
    fn sequence_last_token_and_stop_logic() {
        let mut r = Router::new();
        let (_, _rx) = mk_seq(&mut r, vec![5, 6, 7], 0);
        let mut s = r.pop_next().unwrap();
        assert_eq!(s.last_token(), 7);
        s.generated.push(42);
        assert_eq!(s.last_token(), 42);
        s.stop = vec![vec![41, 42], vec![9]];
        assert!(!s.hit_stop());
        s.generated.push(9);
        assert!(s.hit_stop(), "single-token stop must match the tail");
        s.generated.truncate(1);
        s.generated.insert(0, 41);
        assert!(s.hit_stop(), "multi-token stop must match the tail");
    }

    #[test]
    fn usage_accounts_cached_and_generated() {
        let mut r = Router::new();
        let (_, _rx) = mk_seq(&mut r, vec![1, 2, 3, 4], 0);
        let mut s = r.pop_next().unwrap();
        // Never admitted: no prefill work happened, whatever the cache
        // might have matched.
        assert_eq!(s.usage().prefill_tokens, 0);
        s.admitted = true;
        s.cached_prompt_tokens = 3;
        s.generated.push(8);
        let u = s.usage();
        assert_eq!(u.prompt_tokens, 4);
        assert_eq!(u.cached_prompt_tokens, 3);
        assert_eq!(u.prefill_tokens, 1);
        assert_eq!(u.generated_tokens, 1);
    }

    #[test]
    fn emit_survives_dropped_receiver() {
        let mut r = Router::new();
        let (_, rx) = mk_seq(&mut r, vec![1], 0);
        let s = r.pop_next().unwrap();
        drop(rx);
        assert_eq!(s.emit_token(9), EmitResult::Closed, "reported, not a panic");
        s.emit_finish(FinishReason::Cancelled, s.usage()); // must not panic
    }

    #[test]
    fn enqueue_request_encodes_stops_and_clamps() {
        let mut r = Router::new();
        let tok = ByteTokenizer::new(512);
        let req = GenRequest::text("hi")
            .stop(vec!["ab".into()])
            .max_new_tokens(100);
        let prompt = encode_prompt(&tok, &req.prompt).unwrap();
        assert_eq!(prompt[0], crate::tokenizer::BOS);
        let ctx = SubmitContext {
            max_new_cap: 8,
            stream_capacity: 32,
            now: Duration::from_millis(5),
            wakeup: None,
        };
        let h = enqueue_request(&mut r, &tok, &req, prompt, &ctx).unwrap();
        assert_eq!(h.capacity(), 32, "handle carries the stream capacity");
        assert_eq!(r.queued(), 1);
        let s = r.pop_next().unwrap();
        assert_eq!(s.id, h.id);
        assert_eq!(s.max_new_tokens, 8, "clamped to the engine cap");
        assert_eq!(s.arrived, Duration::from_millis(5), "arrival stamped");
        assert_eq!(s.stop, vec![vec![b'a' as u32, b'b' as u32]]);
        // Invalid submissions are rejected before anything is queued.
        assert!(encode_prompt(&tok, &Prompt::Tokens(vec![])).is_err());
        let zero = GenRequest::text("x").max_new_tokens(0);
        let p = encode_prompt(&tok, &zero.prompt).unwrap();
        assert!(enqueue_request(&mut r, &tok, &zero, p, &ctx).is_err());
        assert_eq!(r.queued(), 0);
    }

    #[test]
    fn requeue_front_puts_sequence_first() {
        let mut r = Router::new();
        let (a, _r1) = mk_seq(&mut r, vec![1], 0);
        let (_b, _r2) = mk_seq(&mut r, vec![2], 0);
        let first = r.pop_next().unwrap();
        assert_eq!(first.id, a);
        r.requeue_front(first);
        assert_eq!(r.pop_next().unwrap().id, a);
    }

    #[test]
    fn registry_registers_resolves_and_prunes() {
        let reg = RequestRegistry::new();
        let g1 = reg.register(11, "acme", 0);
        let g2 = reg.register(12, "", 3);
        assert_ne!(g1, g2, "global ids are unique");
        assert!(g1.starts_with("g1-") && g1.len() > 10, "unguessable suffix: {g1}");
        // Two registries must not mint the same id streams (unpredictable
        // suffixes; counters alone would collide).
        let other = RequestRegistry::new();
        assert_ne!(other.register(11, "acme", 0), g1);
        assert_eq!(reg.depth(), 2);
        assert_eq!(reg.resolve(&g1), Some(11));
        assert_eq!(reg.resolve("nope"), None);
        // Empty tenant normalizes like Sequence::queued does.
        assert_eq!(reg.tenant_ids("default"), vec![12]);
        assert_eq!(reg.tenant_ids("acme"), vec![11]);
        assert!(reg.remove(&g1));
        assert!(!reg.remove(&g1), "second remove is a no-op");
        assert_eq!(reg.depth(), 1);
        assert_eq!(reg.resolve(&g1), None);
    }

    #[test]
    fn registry_tenant_ids_are_scoped() {
        let reg = RequestRegistry::new();
        reg.register(1, "a", 0);
        reg.register(2, "b", 0);
        reg.register(3, "a", 1);
        assert_eq!(reg.tenant_ids("a"), vec![1, 3]);
        assert_eq!(reg.tenant_ids("b"), vec![2]);
        assert!(reg.tenant_ids("c").is_empty());
    }
}
