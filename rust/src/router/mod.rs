//! Request intake and sequence lifecycle.
//!
//! A [`crate::api::GenRequest`] enters through an engine's `submit`,
//! becomes a `Sequence` with a state machine (Queued -> Decoding ->
//! Finished), and streams [`GenEvent`]s back over a channel. The engine
//! thread is the single owner of sequence state; the async server side
//! only holds the sender/receiver endpoints.
//!
//! The router's queue is priority-aware: `peek_next`/`pop_next` select
//! the highest-priority sequence, FIFO within a priority level, so both
//! engines admit in the same order the scheduler's admission outlook
//! was computed for.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

use crate::api::{FinishReason, GenEvent, GenRequest, Prompt, RequestId, SubmissionHandle, Usage};
use crate::error::{Error, Result};
use crate::sampling::SamplingParams;
use crate::tokenizer::ByteTokenizer;

/// Sequence lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    Queued,
    Decoding,
    Finished(FinishReason),
}

/// Engine-side sequence record.
#[derive(Debug)]
pub struct Sequence {
    pub id: RequestId,
    pub tenant: String,
    pub priority: i32,
    pub state: SeqState,
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// Stop sequences as token ids (no BOS); generation finishes with
    /// `FinishReason::Stop` when `generated` ends with any of them.
    pub stop: Vec<Vec<u32>>,
    pub stream: mpsc::Sender<GenEvent>,
    pub arrived: Instant,
    pub first_token_at: Option<Instant>,
    /// Current context length (prompt + generated) stored in KV.
    pub kv_len: usize,
    /// Prompt tokens attached from the prefix cache at admission.
    pub cached_prompt_tokens: usize,
    /// Whether the sequence was ever admitted (prefill ran). Cancelled
    /// while queued => false, and its usage reports zero prefill work.
    pub admitted: bool,
}

impl Sequence {
    /// Build a queued sequence from a typed request (shared by both
    /// engine implementations; `stop` is pre-encoded by the caller's
    /// tokenizer and `max_new_tokens` pre-clamped to the engine cap).
    pub fn queued(
        id: RequestId,
        req: &GenRequest,
        prompt_tokens: Vec<u32>,
        stop: Vec<Vec<u32>>,
        max_new_tokens: usize,
        stream: mpsc::Sender<GenEvent>,
    ) -> Self {
        Sequence {
            id,
            tenant: if req.tenant.is_empty() {
                "default".to_string()
            } else {
                req.tenant.clone()
            },
            priority: req.priority,
            state: SeqState::Queued,
            prompt: prompt_tokens,
            generated: Vec::new(),
            max_new_tokens,
            params: req.params,
            stop,
            stream,
            arrived: Instant::now(),
            first_token_at: None,
            kv_len: 0,
            cached_prompt_tokens: 0,
            admitted: false,
        }
    }

    pub fn last_token(&self) -> u32 {
        *self
            .generated
            .last()
            .unwrap_or_else(|| self.prompt.last().expect("non-empty prompt"))
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, SeqState::Finished(_))
    }

    /// True when the generated tail matches any stop sequence.
    pub fn hit_stop(&self) -> bool {
        self.stop
            .iter()
            .any(|s| !s.is_empty() && self.generated.ends_with(s))
    }

    /// Per-request token accounting (reported on finish). Until the
    /// sequence is admitted no prefill work has happened, so both
    /// cached and prefilled counts stay zero; after admission they
    /// partition `prompt_tokens`.
    pub fn usage(&self) -> Usage {
        Usage {
            prompt_tokens: self.prompt.len(),
            cached_prompt_tokens: self.cached_prompt_tokens,
            prefill_tokens: if self.admitted {
                self.prompt.len() - self.cached_prompt_tokens
            } else {
                0
            },
            generated_tokens: self.generated.len(),
        }
    }

    /// Push an event to the client; ignore a hung-up receiver.
    pub fn emit(&mut self, ev: GenEvent) {
        let _ = self.stream.send(ev);
    }
}

/// Tokenize a request's prompt (shared submit front half; both engines
/// run their own capacity checks on the result before enqueueing).
pub fn encode_prompt(tokenizer: &ByteTokenizer, prompt: &Prompt) -> Result<Vec<u32>> {
    let toks = match prompt {
        Prompt::Text(t) => tokenizer.encode(t),
        Prompt::Tokens(t) => t.clone(),
    };
    if toks.is_empty() {
        return Err(Error::Request("empty prompt".into()));
    }
    Ok(toks)
}

/// Shared submit back half: validate the budget, encode stop sequences,
/// clamp to the engine cap, and enqueue — identical for every engine so
/// the sim twin cannot drift from the real one.
pub fn enqueue_request(
    router: &mut Router,
    tokenizer: &ByteTokenizer,
    req: &GenRequest,
    prompt_tokens: Vec<u32>,
    max_new_cap: usize,
) -> Result<SubmissionHandle> {
    if req.max_new_tokens == 0 {
        return Err(Error::Request("max_new_tokens must be at least 1".into()));
    }
    let stop: Vec<Vec<u32>> = req.stop.iter().map(|s| tokenizer.encode_raw(s)).collect();
    let (tx, rx) = mpsc::channel();
    let id = router.allocate_id();
    let max_new = req.max_new_tokens.min(max_new_cap);
    router.enqueue(Sequence::queued(id, req, prompt_tokens, stop, max_new, tx));
    Ok(SubmissionHandle { id, events: rx })
}

/// Priority-aware intake queue owned by the engine.
#[derive(Debug, Default)]
pub struct Router {
    next_id: RequestId,
    queue: VecDeque<Sequence>,
}

impl Router {
    pub fn new() -> Self {
        Router {
            next_id: 1,
            queue: VecDeque::new(),
        }
    }

    /// Allocate the next request id (monotone).
    pub fn allocate_id(&mut self) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Add a queued sequence to the intake queue.
    pub fn enqueue(&mut self, seq: Sequence) {
        self.queue.push_back(seq);
    }

    /// Index of the sequence `pop_next` would take: highest priority,
    /// earliest arrival within a level.
    fn next_index(&self) -> Option<usize> {
        let mut best: Option<(usize, i32)> = None;
        for (i, s) in self.queue.iter().enumerate() {
            if best.map(|(_, p)| s.priority > p).unwrap_or(true) {
                best = Some((i, s.priority));
            }
        }
        best.map(|(i, _)| i)
    }

    /// The sequence the next prefill would admit (admission outlook must
    /// peek the same sequence `pop_next` will return).
    pub fn peek_next(&self) -> Option<&Sequence> {
        self.next_index().and_then(|i| self.queue.get(i))
    }

    pub fn pop_next(&mut self) -> Option<Sequence> {
        self.next_index().and_then(|i| self.queue.remove(i))
    }

    /// Requeue at the front (admission backoff under KV pressure).
    pub fn requeue_front(&mut self, seq: Sequence) {
        self.queue.push_front(seq);
    }

    /// Remove a queued sequence by id (cancellation before admission).
    pub fn take(&mut self, id: RequestId) -> Option<Sequence> {
        let idx = self.queue.iter().position(|s| s.id == id)?;
        self.queue.remove(idx)
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_seq(
        r: &mut Router,
        prompt: Vec<u32>,
        priority: i32,
    ) -> (RequestId, mpsc::Receiver<GenEvent>) {
        let (tx, rx) = mpsc::channel();
        let req = GenRequest::tokens(prompt.clone()).priority(priority);
        let id = r.allocate_id();
        r.enqueue(Sequence::queued(id, &req, prompt, Vec::new(), 4, tx));
        (id, rx)
    }

    #[test]
    fn submit_assigns_monotone_ids_fifo_within_priority() {
        let mut r = Router::new();
        let (a, _rx1) = mk_seq(&mut r, vec![1], 0);
        let (b, _rx2) = mk_seq(&mut r, vec![2], 0);
        assert!(b > a);
        assert_eq!(r.queued(), 2);
        assert_eq!(r.peek_next().unwrap().id, a);
        assert_eq!(r.pop_next().unwrap().id, a, "FIFO");
    }

    #[test]
    fn higher_priority_pops_first() {
        let mut r = Router::new();
        let (low, _r1) = mk_seq(&mut r, vec![1], 0);
        let (high, _r2) = mk_seq(&mut r, vec![2], 5);
        let (low2, _r3) = mk_seq(&mut r, vec![3], 0);
        assert_eq!(r.peek_next().unwrap().id, high);
        assert_eq!(r.pop_next().unwrap().id, high);
        assert_eq!(r.pop_next().unwrap().id, low, "FIFO among equals");
        assert_eq!(r.pop_next().unwrap().id, low2);
        assert!(r.pop_next().is_none());
    }

    #[test]
    fn take_removes_by_id() {
        let mut r = Router::new();
        let (a, _r1) = mk_seq(&mut r, vec![1], 0);
        let (b, _r2) = mk_seq(&mut r, vec![2], 0);
        assert_eq!(r.take(b).unwrap().id, b);
        assert!(r.take(b).is_none(), "already taken");
        assert_eq!(r.queued(), 1);
        assert_eq!(r.pop_next().unwrap().id, a);
    }

    #[test]
    fn sequence_last_token_and_stop_logic() {
        let mut r = Router::new();
        let (_, _rx) = mk_seq(&mut r, vec![5, 6, 7], 0);
        let mut s = r.pop_next().unwrap();
        assert_eq!(s.last_token(), 7);
        s.generated.push(42);
        assert_eq!(s.last_token(), 42);
        s.stop = vec![vec![41, 42], vec![9]];
        assert!(!s.hit_stop());
        s.generated.push(9);
        assert!(s.hit_stop(), "single-token stop must match the tail");
        s.generated.truncate(1);
        s.generated.insert(0, 41);
        assert!(s.hit_stop(), "multi-token stop must match the tail");
    }

    #[test]
    fn usage_accounts_cached_and_generated() {
        let mut r = Router::new();
        let (_, _rx) = mk_seq(&mut r, vec![1, 2, 3, 4], 0);
        let mut s = r.pop_next().unwrap();
        // Never admitted: no prefill work happened, whatever the cache
        // might have matched.
        assert_eq!(s.usage().prefill_tokens, 0);
        s.admitted = true;
        s.cached_prompt_tokens = 3;
        s.generated.push(8);
        let u = s.usage();
        assert_eq!(u.prompt_tokens, 4);
        assert_eq!(u.cached_prompt_tokens, 3);
        assert_eq!(u.prefill_tokens, 1);
        assert_eq!(u.generated_tokens, 1);
    }

    #[test]
    fn emit_survives_dropped_receiver() {
        let mut r = Router::new();
        let (_, rx) = mk_seq(&mut r, vec![1], 0);
        let mut s = r.pop_next().unwrap();
        drop(rx);
        s.emit(GenEvent::Token(9)); // must not panic
    }

    #[test]
    fn enqueue_request_encodes_stops_and_clamps() {
        let mut r = Router::new();
        let tok = ByteTokenizer::new(512);
        let req = GenRequest::text("hi")
            .stop(vec!["ab".into()])
            .max_new_tokens(100);
        let prompt = encode_prompt(&tok, &req.prompt).unwrap();
        assert_eq!(prompt[0], crate::tokenizer::BOS);
        let h = enqueue_request(&mut r, &tok, &req, prompt, 8).unwrap();
        assert_eq!(r.queued(), 1);
        let s = r.pop_next().unwrap();
        assert_eq!(s.id, h.id);
        assert_eq!(s.max_new_tokens, 8, "clamped to the engine cap");
        assert_eq!(s.stop, vec![vec![b'a' as u32, b'b' as u32]]);
        // Invalid submissions are rejected before anything is queued.
        assert!(encode_prompt(&tok, &Prompt::Tokens(vec![])).is_err());
        let zero = GenRequest::text("x").max_new_tokens(0);
        let p = encode_prompt(&tok, &zero.prompt).unwrap();
        assert!(enqueue_request(&mut r, &tok, &zero, p, 8).is_err());
        assert_eq!(r.queued(), 0);
    }

    #[test]
    fn requeue_front_puts_sequence_first() {
        let mut r = Router::new();
        let (a, _r1) = mk_seq(&mut r, vec![1], 0);
        let (_b, _r2) = mk_seq(&mut r, vec![2], 0);
        let first = r.pop_next().unwrap();
        assert_eq!(first.id, a);
        r.requeue_front(first);
        assert_eq!(r.pop_next().unwrap().id, a);
    }
}
