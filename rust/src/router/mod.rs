//! Request intake and sequence lifecycle.
//!
//! A `Request` enters through the router, becomes a `Sequence` with a
//! state machine (Queued -> Prefilling -> Decoding -> Finished), and
//! streams generated tokens back over a channel. The engine thread is
//! the single owner of sequence state; the async server side only holds
//! the sender/receiver endpoints.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

use crate::kvcache::SeqId;
use crate::sampling::SamplingParams;

/// Why a sequence stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    /// KV capacity forced us to stop early.
    Preempted,
    Error,
}

/// Streamed events a client receives.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    Token(u32),
    Finished {
        reason: FinishReason,
        /// Total generated tokens.
        n_generated: usize,
    },
}

/// An incoming generation request.
#[derive(Debug)]
pub struct Request {
    pub prompt_tokens: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    pub stream: mpsc::Sender<TokenEvent>,
    pub arrived: Instant,
}

/// Sequence lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    Queued,
    Decoding,
    Finished(FinishReason),
}

/// Engine-side sequence record.
#[derive(Debug)]
pub struct Sequence {
    pub id: SeqId,
    pub state: SeqState,
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    pub stream: mpsc::Sender<TokenEvent>,
    pub arrived: Instant,
    pub first_token_at: Option<Instant>,
    /// Current context length (prompt + generated) stored in KV.
    pub kv_len: usize,
}

impl Sequence {
    pub fn last_token(&self) -> u32 {
        *self
            .generated
            .last()
            .unwrap_or_else(|| self.prompt.last().expect("non-empty prompt"))
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, SeqState::Finished(_))
    }

    /// Push a token to the client; ignore a hung-up receiver.
    pub fn emit(&mut self, ev: TokenEvent) {
        let _ = self.stream.send(ev);
    }
}

/// FIFO intake queue owned by the engine.
#[derive(Debug, Default)]
pub struct Router {
    next_id: SeqId,
    pub queue: VecDeque<Sequence>,
}

impl Router {
    pub fn new() -> Self {
        Router {
            next_id: 1,
            queue: VecDeque::new(),
        }
    }

    /// Convert a request into a queued sequence.
    pub fn submit(&mut self, req: Request) -> SeqId {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Sequence {
            id,
            state: SeqState::Queued,
            prompt: req.prompt_tokens,
            generated: Vec::new(),
            max_new_tokens: req.max_new_tokens,
            params: req.params,
            stream: req.stream,
            arrived: req.arrived,
            first_token_at: None,
            kv_len: 0,
        });
        id
    }

    pub fn pop_next(&mut self) -> Option<Sequence> {
        self.queue.pop_front()
    }

    /// Requeue at the front (preemption).
    pub fn requeue_front(&mut self, seq: Sequence) {
        self.queue.push_front(seq);
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_request(prompt: Vec<u32>) -> (Request, mpsc::Receiver<TokenEvent>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                prompt_tokens: prompt,
                max_new_tokens: 4,
                params: SamplingParams::default(),
                stream: tx,
                arrived: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn submit_assigns_monotone_ids() {
        let mut r = Router::new();
        let (q1, _rx1) = mk_request(vec![1]);
        let (q2, _rx2) = mk_request(vec![2]);
        let a = r.submit(q1);
        let b = r.submit(q2);
        assert!(b > a);
        assert_eq!(r.queued(), 2);
        assert_eq!(r.pop_next().unwrap().id, a, "FIFO");
    }

    #[test]
    fn sequence_last_token_logic() {
        let mut r = Router::new();
        let (q, _rx) = mk_request(vec![5, 6, 7]);
        r.submit(q);
        let mut s = r.pop_next().unwrap();
        assert_eq!(s.last_token(), 7);
        s.generated.push(42);
        assert_eq!(s.last_token(), 42);
    }

    #[test]
    fn emit_survives_dropped_receiver() {
        let mut r = Router::new();
        let (q, rx) = mk_request(vec![1]);
        r.submit(q);
        let mut s = r.pop_next().unwrap();
        drop(rx);
        s.emit(TokenEvent::Token(9)); // must not panic
    }

    #[test]
    fn requeue_front_puts_sequence_first() {
        let mut r = Router::new();
        let (q1, _r1) = mk_request(vec![1]);
        let (q2, _r2) = mk_request(vec![2]);
        r.submit(q1);
        r.submit(q2);
        let first = r.pop_next().unwrap();
        let first_id = first.id;
        r.requeue_front(first);
        assert_eq!(r.pop_next().unwrap().id, first_id);
    }
}
