//! Observability substrate: request-lifecycle spans, the always-on
//! bounded flight recorder, and the Prometheus text exposition.
//!
//! The serving core ([`crate::core::EngineCore`]) owns one [`SpanTable`]
//! and one [`FlightRecorder`] and stamps them from the engine's
//! [`crate::util::clock::Clock`], so every backend — PJRT, sim, stub —
//! gets the same observability surface, and under the sim clock every
//! timestamp is a pure function of the scenario (byte-identical across
//! runs). Neither structure feeds back into scheduling: spans and
//! flight entries are write-only side channels, which is what keeps the
//! simulation-test trace fingerprints identical with or without them.
//!
//! Three layers, from per-request to fleet-wide:
//!
//! - **Spans** ([`RequestSpan`]): each request's transition timeline
//!   (submitted → admitted → first token → decode ⇄ paused → finished)
//!   with derived phase times (queue wait, prefill, decode, paused,
//!   TTFT). The finished request's [`SpanBreakdown`] rides to the
//!   client on its event stream and shows up in the server's `done`
//!   line; aggregates land in the `span_*` histograms of
//!   [`crate::metrics::EngineMetrics`]. The simulation harness checks
//!   span conservation as its fifth always-on oracle.
//! - **Flight recorder** ([`FlightRecorder`]): a bounded ring of recent
//!   scheduling events. Unlike the opt-in, unbounded trace
//!   ([`crate::core::EngineCore::enable_trace`]) it is always on, so a
//!   production incident or a failing simulation seed ships its own
//!   black box (`{"admin": {"dump_flight": n}}` on the wire; appended
//!   to simtest violation reports).
//! - **Prometheus exposition** ([`prometheus_text`]): the stats JSON
//!   snapshot rendered as `# TYPE`-annotated metric lines, histograms
//!   included, for scrape-based tooling.
//!
//! See `docs/OBSERVABILITY.md` for the operator-facing guide.

use std::collections::{HashMap, VecDeque};
use std::fmt::Write as _;
use std::time::Duration;

use crate::api::FinishReason;
use crate::kvcache::SeqId;
use crate::util::json::Json;

// ---------------------------------------------------------------------
// Request-lifecycle spans
// ---------------------------------------------------------------------

/// One phase transition in a request's lifecycle timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanEvent {
    /// The request entered the intake queue.
    Submitted,
    /// Admission succeeded; prefill runs in the same step.
    Admitted,
    /// The first generated token was emitted.
    FirstToken,
    /// Parked by stream backpressure.
    Paused,
    /// Rejoined the decode batch.
    Resumed,
    /// Terminal: exactly one per request, always last.
    Finished(FinishReason),
}

impl SpanEvent {
    /// Stable lowercase name (flight-recorder lines, dumps).
    pub fn name(self) -> &'static str {
        match self {
            SpanEvent::Submitted => "submitted",
            SpanEvent::Admitted => "admitted",
            SpanEvent::FirstToken => "first_token",
            SpanEvent::Paused => "paused",
            SpanEvent::Resumed => "resumed",
            SpanEvent::Finished(_) => "finished",
        }
    }
}

/// Per-request phase-time partition, reported with the `done` line and
/// aggregated into the engine's `span_*` histograms. All durations are
/// engine-clock microseconds; the four phase fields partition
/// `total_us` exactly:
/// `queue_wait + prefill + decode + paused == total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SpanBreakdown {
    /// Submission → admission (or the whole life, if never admitted).
    pub queue_wait_us: u64,
    /// Admission → first token (admission → finish when prefill failed
    /// before a token streamed).
    pub prefill_us: u64,
    /// First token → finish, excluding time parked on backpressure.
    pub decode_us: u64,
    /// Total time parked on backpressure.
    pub paused_us: u64,
    /// Submission → first token; `None` when no token was generated.
    pub ttft_us: Option<u64>,
    /// Submission → finish.
    pub total_us: u64,
}

impl SpanBreakdown {
    /// Wire form for the `done` line's `"spans"` object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue_wait_us", Json::Num(self.queue_wait_us as f64)),
            ("prefill_us", Json::Num(self.prefill_us as f64)),
            ("decode_us", Json::Num(self.decode_us as f64)),
            ("paused_us", Json::Num(self.paused_us as f64)),
            (
                "ttft_us",
                match self.ttft_us {
                    Some(t) => Json::Num(t as f64),
                    None => Json::Null,
                },
            ),
            ("total_us", Json::Num(self.total_us as f64)),
        ])
    }
}

/// One request's lifecycle timeline, stamped from the engine clock.
#[derive(Debug, Clone)]
pub struct RequestSpan {
    pub id: SeqId,
    pub submitted_at: Duration,
    pub admitted_at: Option<Duration>,
    pub first_token_at: Option<Duration>,
    pub finished_at: Option<Duration>,
    pub reason: Option<FinishReason>,
    /// Accumulated time parked on backpressure (closed intervals plus,
    /// for a request finishing while parked, the final open one).
    pub paused_time: Duration,
    /// Completed pause intervals.
    pub pauses: u32,
    /// The full transition record `(timestamp, event)`, in order.
    pub timeline: Vec<(Duration, SpanEvent)>,
    /// Open pause interval's start, while parked.
    paused_since: Option<Duration>,
}

impl RequestSpan {
    fn new(id: SeqId, now: Duration) -> Self {
        RequestSpan {
            id,
            submitted_at: now,
            admitted_at: None,
            first_token_at: None,
            finished_at: None,
            reason: None,
            paused_time: Duration::ZERO,
            pauses: 0,
            timeline: vec![(now, SpanEvent::Submitted)],
            paused_since: None,
        }
    }

    pub fn is_finished(&self) -> bool {
        self.finished_at.is_some()
    }

    /// Submission → admission; for a request that never admitted, its
    /// whole (finished) life was queue wait.
    pub fn queue_wait(&self) -> Duration {
        let end = self
            .admitted_at
            .or(self.finished_at)
            .unwrap_or(self.submitted_at);
        end.saturating_sub(self.submitted_at)
    }

    /// Admission → first token (→ finish when no token ever streamed,
    /// e.g. a prefill failure).
    pub fn prefill_time(&self) -> Duration {
        let Some(a) = self.admitted_at else {
            return Duration::ZERO;
        };
        let end = self.first_token_at.or(self.finished_at).unwrap_or(a);
        end.saturating_sub(a)
    }

    /// First token → finish, excluding parked time.
    pub fn decode_time(&self) -> Duration {
        match (self.first_token_at, self.finished_at) {
            (Some(f), Some(e)) => e.saturating_sub(f).saturating_sub(self.paused_time),
            _ => Duration::ZERO,
        }
    }

    /// Submission → first token.
    pub fn ttft(&self) -> Option<Duration> {
        self.first_token_at
            .map(|f| f.saturating_sub(self.submitted_at))
    }

    /// Submission → finish (zero while live).
    pub fn total(&self) -> Duration {
        self.finished_at
            .map(|e| e.saturating_sub(self.submitted_at))
            .unwrap_or(Duration::ZERO)
    }

    /// The finished request's phase partition. `decode_us` is derived
    /// as the remainder of `total_us`, not truncated independently:
    /// under the system clock each phase can carry a sub-microsecond
    /// remainder, and truncating them separately would break the
    /// `queue_wait + prefill + decode + paused == total` contract the
    /// wire format promises.
    pub fn breakdown(&self) -> SpanBreakdown {
        let queue_wait_us = self.queue_wait().as_micros() as u64;
        let prefill_us = self.prefill_time().as_micros() as u64;
        let paused_us = self.paused_time.as_micros() as u64;
        let total_us = self.total().as_micros() as u64;
        SpanBreakdown {
            queue_wait_us,
            prefill_us,
            decode_us: total_us.saturating_sub(queue_wait_us + prefill_us + paused_us),
            paused_us,
            ttft_us: self.ttft().map(|t| t.as_micros() as u64),
            total_us,
        }
    }

    /// Validate the timeline: monotone timestamps, a legal transition
    /// order (the request-lifecycle state machine), a terminal event
    /// exactly when the span is finished, and pause accounting that
    /// matches the recorded intervals. Returns the first problem found.
    /// This is the per-span half of the simulation harness's span
    /// conservation oracle.
    pub fn check(&self) -> std::result::Result<(), String> {
        let id = self.id;
        if self.timeline.first().map(|(_, e)| *e) != Some(SpanEvent::Submitted) {
            return Err(format!("span {id}: timeline does not start with submitted"));
        }
        #[derive(PartialEq, Clone, Copy)]
        enum S {
            Queued,
            Admitted,
            Streaming,
            Parked,
            Done,
        }
        let mut state = S::Queued;
        let mut prev_t = Duration::ZERO;
        let mut paused_total = Duration::ZERO;
        let mut paused_open: Option<Duration> = None;
        for (i, &(t, ev)) in self.timeline.iter().enumerate() {
            if t < prev_t {
                return Err(format!(
                    "span {id}: timestamp went backwards at event {i} ({ev:?})"
                ));
            }
            prev_t = t;
            state = match (state, ev) {
                (S::Queued, SpanEvent::Submitted) if i == 0 => S::Queued,
                (S::Queued, SpanEvent::Admitted) => S::Admitted,
                (S::Admitted, SpanEvent::FirstToken) => S::Streaming,
                (S::Streaming, SpanEvent::Paused) => {
                    paused_open = Some(t);
                    S::Parked
                }
                (S::Parked, SpanEvent::Resumed) => {
                    paused_total += t.saturating_sub(paused_open.take().unwrap());
                    S::Streaming
                }
                (S::Queued | S::Admitted | S::Streaming | S::Parked, SpanEvent::Finished(_)) => {
                    if let Some(p) = paused_open.take() {
                        paused_total += t.saturating_sub(p);
                    }
                    S::Done
                }
                (_, ev) => {
                    return Err(format!("span {id}: illegal transition {ev:?} at event {i}"));
                }
            };
        }
        if (state == S::Done) != self.is_finished() {
            return Err(format!(
                "span {id}: terminal event and finished_at disagree"
            ));
        }
        if self.is_finished() && self.paused_time != paused_total {
            return Err(format!(
                "span {id}: paused_time {:?} != {:?} from timeline",
                self.paused_time, paused_total
            ));
        }
        if self.is_finished() {
            let parts =
                self.queue_wait() + self.prefill_time() + self.decode_time() + self.paused_time;
            if parts != self.total() {
                return Err(format!(
                    "span {id}: phases {:?} do not partition total {:?}",
                    parts,
                    self.total()
                ));
            }
        }
        Ok(())
    }
}

/// The engine's span store: live spans by id plus a bounded ring of
/// recently finished ones (oldest evicted first; aggregate histograms
/// in [`crate::metrics::EngineMetrics`] never lose data). Counters
/// survive eviction, so conservation checks hold on any horizon.
#[derive(Debug)]
pub struct SpanTable {
    active: HashMap<SeqId, RequestSpan>,
    completed: VecDeque<RequestSpan>,
    capacity: usize,
    /// Finished spans evicted from the ring.
    pub completed_dropped: u64,
    pub spans_submitted: u64,
    pub spans_admitted: u64,
    pub spans_finished: u64,
}

impl SpanTable {
    /// Ring capacity for finished spans (floored to 1).
    pub fn new(capacity: usize) -> Self {
        SpanTable {
            active: HashMap::new(),
            completed: VecDeque::new(),
            capacity: capacity.max(1),
            completed_dropped: 0,
            spans_submitted: 0,
            spans_admitted: 0,
            spans_finished: 0,
        }
    }

    pub fn submitted(&mut self, id: SeqId, now: Duration) {
        self.spans_submitted += 1;
        self.active.insert(id, RequestSpan::new(id, now));
    }

    pub fn admitted(&mut self, id: SeqId, now: Duration) {
        if let Some(s) = self.active.get_mut(&id) {
            self.spans_admitted += 1;
            s.admitted_at = Some(now);
            s.timeline.push((now, SpanEvent::Admitted));
        }
    }

    pub fn first_token(&mut self, id: SeqId, now: Duration) {
        if let Some(s) = self.active.get_mut(&id) {
            s.first_token_at = Some(now);
            s.timeline.push((now, SpanEvent::FirstToken));
        }
    }

    pub fn paused(&mut self, id: SeqId, now: Duration) {
        if let Some(s) = self.active.get_mut(&id) {
            s.paused_since = Some(now);
            s.timeline.push((now, SpanEvent::Paused));
        }
    }

    pub fn resumed(&mut self, id: SeqId, now: Duration) {
        if let Some(s) = self.active.get_mut(&id) {
            if let Some(p) = s.paused_since.take() {
                s.paused_time += now.saturating_sub(p);
                s.pauses += 1;
            }
            s.timeline.push((now, SpanEvent::Resumed));
        }
    }

    /// Close the span: stamp the terminal event, fold any open pause
    /// interval, move it to the completed ring, and return the phase
    /// breakdown for the `done` line and the aggregate histograms.
    pub fn finished(
        &mut self,
        id: SeqId,
        now: Duration,
        reason: FinishReason,
    ) -> Option<SpanBreakdown> {
        let mut s = self.active.remove(&id)?;
        if let Some(p) = s.paused_since.take() {
            s.paused_time += now.saturating_sub(p);
            s.pauses += 1;
        }
        s.finished_at = Some(now);
        s.reason = Some(reason);
        s.timeline.push((now, SpanEvent::Finished(reason)));
        self.spans_finished += 1;
        let b = s.breakdown();
        if self.completed.len() == self.capacity {
            self.completed.pop_front();
            self.completed_dropped += 1;
        }
        self.completed.push_back(s);
        Some(b)
    }

    /// Live (unfinished) spans, in arbitrary order.
    pub fn active(&self) -> impl Iterator<Item = &RequestSpan> {
        self.active.values()
    }

    /// Retained finished spans, oldest first.
    pub fn completed(&self) -> impl Iterator<Item = &RequestSpan> {
        self.completed.iter()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn completed_len(&self) -> usize {
        self.completed.len()
    }
}

// ---------------------------------------------------------------------
// Flight recorder
// ---------------------------------------------------------------------

/// One flight-recorder entry: a monotone sequence number (stable across
/// ring eviction), the engine-clock timestamp, and a compact rendered
/// event line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlightEntry {
    pub seq: u64,
    /// Microseconds since the engine clock's epoch.
    pub at_us: u64,
    pub what: String,
}

/// Always-on bounded ring of recent scheduling events — the engine's
/// black box. Capacity comes from
/// [`crate::config::EngineConfig::flight_recorder_capacity`]; when full,
/// the oldest entry is evicted (and counted in `dropped`), so memory is
/// bounded no matter how long the engine runs. Dumped via
/// `{"admin": {"dump_flight": n}}` and appended to simulation-test
/// violation reports.
#[derive(Debug)]
pub struct FlightRecorder {
    buf: VecDeque<FlightEntry>,
    capacity: usize,
    next_seq: u64,
    dropped: u64,
}

impl FlightRecorder {
    /// Ring capacity (floored to 1).
    pub fn new(capacity: usize) -> Self {
        FlightRecorder {
            buf: VecDeque::new(),
            capacity: capacity.max(1),
            next_seq: 0,
            dropped: 0,
        }
    }

    /// Append one event line, evicting the oldest entry when full.
    pub fn record(&mut self, at: Duration, what: String) {
        self.record_with(at, |buf| {
            buf.push_str(&what);
        });
    }

    /// Append one event line rendered directly into the entry's string.
    /// At capacity the evicted entry's `String` is recycled (cleared,
    /// rewritten in place), so a full ring records without allocating —
    /// the step loop's hot-path variant. `f` receives an empty buffer
    /// and writes the line via `std::fmt::Write`.
    pub fn record_with(&mut self, at: Duration, f: impl FnOnce(&mut String)) {
        let mut what = if self.buf.len() == self.capacity {
            let mut old = self.buf.pop_front().expect("capacity >= 1").what;
            self.dropped += 1;
            old.clear();
            old
        } else {
            String::with_capacity(96)
        };
        f(&mut what);
        self.buf.push_back(FlightEntry {
            seq: self.next_seq,
            at_us: at.as_micros() as u64,
            what,
        });
        self.next_seq += 1;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Entries evicted by the ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Total events ever recorded.
    pub fn recorded(&self) -> u64 {
        self.next_seq
    }

    /// The newest `n` entries, oldest first, with ring bookkeeping —
    /// the `{"flight": ...}` payload of the `dump_flight` reply.
    pub fn to_json(&self, n: usize) -> Json {
        let skip = self.buf.len().saturating_sub(n);
        Json::obj(vec![
            ("capacity", Json::Num(self.capacity as f64)),
            ("recorded", Json::Num(self.next_seq as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            (
                "entries",
                Json::Arr(
                    self.buf
                        .iter()
                        .skip(skip)
                        .map(|e| {
                            Json::obj(vec![
                                ("seq", Json::Num(e.seq as f64)),
                                ("at_us", Json::Num(e.at_us as f64)),
                                ("what", Json::Str(e.what.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// The newest `n` entries as plain text, one per line, oldest first
    /// — appended to simulation-test violation reports so a failing
    /// seed ships its own black box.
    pub fn render(&self, n: usize) -> String {
        let skip = self.buf.len().saturating_sub(n);
        let mut out = String::new();
        for e in self.buf.iter().skip(skip) {
            let _ = writeln!(out, "  [{:>6}] t={}us {}", e.seq, e.at_us, e.what);
        }
        out
    }
}

// ---------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------

/// Format a JSON number the way the in-tree serializer does (integers
/// without a trailing `.0`), so the exposition is byte-stable.
fn fmt_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn gauge_line(name: &str, n: f64, out: &mut String) {
    let _ = write!(out, "# TYPE fdpp_{name} gauge\nfdpp_{name} ");
    fmt_num(n, out);
    out.push('\n');
}

/// Render a histogram export (the `{bounds, counts, sum_us, count}`
/// shape of `LatencyHistogram::to_json`) as a Prometheus histogram.
fn histogram_lines(name: &str, h: &Json, out: &mut String) {
    let (Some(bounds), Some(counts)) = (
        h.get("bounds").and_then(Json::as_arr),
        h.get("counts").and_then(Json::as_arr),
    ) else {
        return;
    };
    let _ = writeln!(out, "# TYPE fdpp_{name}_us histogram");
    let mut cumulative = 0.0;
    for (i, c) in counts.iter().enumerate() {
        cumulative += c.as_f64().unwrap_or(0.0);
        let _ = write!(out, "fdpp_{name}_us_bucket{{le=\"");
        match bounds.get(i).and_then(Json::as_f64) {
            Some(b) => fmt_num(b, out),
            None => out.push_str("+Inf"),
        }
        out.push_str("\"} ");
        fmt_num(cumulative, out);
        out.push('\n');
    }
    let _ = write!(out, "fdpp_{name}_us_sum ");
    fmt_num(h.get("sum_us").and_then(Json::as_f64).unwrap_or(0.0), out);
    let _ = write!(out, "\nfdpp_{name}_us_count ");
    fmt_num(h.get("count").and_then(Json::as_f64).unwrap_or(0.0), out);
    out.push('\n');
}

/// Render a stats snapshot (the `{"stats": true}` JSON object, i.e.
/// `InferenceEngine::stats_json` plus whatever the front-end merged in)
/// as Prometheus text exposition: scalar fields become `fdpp_<name>`
/// gauges, booleans 0/1 gauges, the `histograms` object becomes
/// `fdpp_<name>_us` histograms with cumulative buckets, and the
/// `tenants` / `queue_depths` maps become labeled gauges. Key order is
/// the snapshot's (sorted), so the exposition is deterministic.
pub fn prometheus_text(stats: &Json) -> String {
    let mut out = String::new();
    let Json::Obj(map) = stats else {
        return out;
    };
    for (k, v) in map {
        match (k.as_str(), v) {
            (_, Json::Num(n)) => gauge_line(k, *n, &mut out),
            (_, Json::Bool(b)) => gauge_line(k, if *b { 1.0 } else { 0.0 }, &mut out),
            ("histograms", Json::Obj(hs)) => {
                for (name, h) in hs {
                    histogram_lines(name, h, &mut out);
                }
            }
            ("queue_depths", Json::Obj(depths)) => {
                let _ = writeln!(out, "# TYPE fdpp_queue_depth gauge");
                for (priority, n) in depths {
                    let _ = write!(out, "fdpp_queue_depth{{priority=\"{priority}\"}} ");
                    fmt_num(n.as_f64().unwrap_or(0.0), &mut out);
                    out.push('\n');
                }
            }
            ("replicas", Json::Obj(replicas)) => {
                // Fleet stats: one labeled gauge family per numeric
                // replica field (string fields like `health` are
                // covered by the numeric `up` gauge).
                let mut fields = std::collections::BTreeSet::new();
                for r in replicas.values() {
                    if let Json::Obj(m) = r {
                        for (f, v) in m {
                            if matches!(v, Json::Num(_)) {
                                fields.insert(f.clone());
                            }
                        }
                    }
                }
                for field in &fields {
                    let _ = writeln!(out, "# TYPE fdpp_replica_{field} gauge");
                    for (replica, r) in replicas {
                        let _ = write!(out, "fdpp_replica_{field}{{replica=\"{replica}\"}} ");
                        fmt_num(
                            r.get(field).and_then(Json::as_f64).unwrap_or(0.0),
                            &mut out,
                        );
                        out.push('\n');
                    }
                }
            }
            ("per_shard", Json::Obj(shards)) => {
                // Sharded-backend stats: one labeled gauge family per
                // numeric lane field (`shard::ShardMetrics::to_json`
                // keys the object by shard index).
                let mut fields = std::collections::BTreeSet::new();
                for s in shards.values() {
                    if let Json::Obj(m) = s {
                        for (f, v) in m {
                            if matches!(v, Json::Num(_)) {
                                fields.insert(f.clone());
                            }
                        }
                    }
                }
                for field in &fields {
                    let _ = writeln!(out, "# TYPE fdpp_shard_{field} gauge");
                    for (shard, s) in shards {
                        let _ = write!(out, "fdpp_shard_{field}{{shard=\"{shard}\"}} ");
                        fmt_num(
                            s.get(field).and_then(Json::as_f64).unwrap_or(0.0),
                            &mut out,
                        );
                        out.push('\n');
                    }
                }
            }
            ("tenants", Json::Obj(tenants)) => {
                for field in [
                    "requests_finished",
                    "generated_tokens",
                    "cached_prompt_tokens",
                ] {
                    let _ = writeln!(out, "# TYPE fdpp_tenant_{field} gauge");
                    for (tenant, t) in tenants {
                        let _ = write!(
                            out,
                            "fdpp_tenant_{field}{{tenant=\"{}\"}} ",
                            tenant.replace('\\', "\\\\").replace('"', "\\\"")
                        );
                        fmt_num(
                            t.get(field).and_then(Json::as_f64).unwrap_or(0.0),
                            &mut out,
                        );
                        out.push('\n');
                    }
                }
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn span_partitions_phases_exactly() {
        let mut t = SpanTable::new(16);
        t.submitted(1, 2 * MS);
        t.admitted(1, 5 * MS);
        t.first_token(1, 5 * MS);
        t.paused(1, 8 * MS);
        t.resumed(1, 11 * MS);
        t.paused(1, 12 * MS);
        let b = t.finished(1, 20 * MS, FinishReason::Eos).unwrap();
        assert_eq!(b.queue_wait_us, 3_000);
        assert_eq!(b.prefill_us, 0);
        assert_eq!(b.paused_us, 11_000, "3ms closed + 8ms open at finish");
        assert_eq!(b.decode_us, 4_000);
        assert_eq!(b.ttft_us, Some(3_000));
        assert_eq!(b.total_us, 18_000);
        assert_eq!(
            b.queue_wait_us + b.prefill_us + b.decode_us + b.paused_us,
            b.total_us
        );
        let span = t.completed().next().unwrap();
        span.check().unwrap();
        assert_eq!(span.pauses, 2);
    }

    #[test]
    fn span_never_admitted_is_all_queue_wait() {
        let mut t = SpanTable::new(16);
        t.submitted(7, MS);
        let b = t.finished(7, 9 * MS, FinishReason::Cancelled).unwrap();
        assert_eq!(b.queue_wait_us, 8_000);
        assert_eq!(b.prefill_us + b.decode_us + b.paused_us, 0);
        assert_eq!(b.ttft_us, None);
        assert_eq!(b.total_us, 8_000);
        t.completed().next().unwrap().check().unwrap();
    }

    #[test]
    fn span_check_rejects_illegal_timelines() {
        // Paused before any token streamed: illegal.
        let mut t = SpanTable::new(4);
        t.submitted(1, MS);
        t.admitted(1, 2 * MS);
        t.first_token(1, 2 * MS);
        t.finished(1, 3 * MS, FinishReason::Eos);
        let mut span = t.completed().next().unwrap().clone();
        span.check().unwrap();
        span.timeline.insert(2, (2 * MS, SpanEvent::Paused));
        assert!(span.check().is_err(), "pause before first token");

        let mut back = t.completed().next().unwrap().clone();
        back.timeline[1].0 = Duration::ZERO;
        assert!(back.check().is_err(), "non-monotone timestamps");

        let mut wrong = t.completed().next().unwrap().clone();
        wrong.paused_time = Duration::from_millis(5);
        assert!(wrong.check().is_err(), "pause accounting mismatch");
    }

    #[test]
    fn span_table_counters_survive_ring_eviction() {
        let mut t = SpanTable::new(2);
        for id in 0..5u64 {
            t.submitted(id, MS);
            t.finished(id, 2 * MS, FinishReason::Cancelled);
        }
        assert_eq!(t.completed_len(), 2, "ring bounded");
        assert_eq!(t.completed_dropped, 3);
        assert_eq!(t.spans_submitted, 5);
        assert_eq!(t.spans_finished, 5);
        assert_eq!(t.active_len(), 0);
    }

    #[test]
    fn flight_recorder_is_bounded_under_flood() {
        let mut f = FlightRecorder::new(64);
        for i in 0..10_000u64 {
            f.record(Duration::from_micros(i), format!("event {i}"));
        }
        assert_eq!(f.len(), 64, "ring respects capacity under 10k events");
        assert_eq!(f.capacity(), 64);
        assert_eq!(f.dropped(), 10_000 - 64);
        assert_eq!(f.recorded(), 10_000);
        // The retained window is the newest entries, in order.
        let j = f.to_json(3);
        let entries = j.req_arr("entries").unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(
            entries[2].get("what").and_then(Json::as_str),
            Some("event 9999")
        );
        assert_eq!(entries[0].get("seq").and_then(Json::as_usize), Some(9997));
        let text = f.render(2);
        assert!(text.contains("event 9998") && text.contains("event 9999"));
        assert!(!text.contains("event 9997"));
    }

    #[test]
    fn flight_dump_handles_oversized_n() {
        let mut f = FlightRecorder::new(8);
        f.record(MS, "only".into());
        let j = f.to_json(100);
        assert_eq!(j.req_arr("entries").unwrap().len(), 1);
        assert_eq!(j.get("dropped").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn prometheus_renders_gauges_histograms_and_labels() {
        let mut h = crate::metrics::LatencyHistogram::default();
        h.record(Duration::from_micros(3));
        h.record(Duration::from_micros(900));
        let stats = Json::obj(vec![
            ("tokens_generated", Json::Num(42.0)),
            ("kv_refcount_ok", Json::Bool(true)),
            ("histograms", Json::obj(vec![("step", h.to_json())])),
            (
                "queue_depths",
                Json::obj(vec![("0", Json::Num(2.0)), ("5", Json::Num(1.0))]),
            ),
            (
                "tenants",
                Json::obj(vec![(
                    "acme",
                    Json::obj(vec![("generated_tokens", Json::Num(7.0))]),
                )]),
            ),
        ]);
        let text = prometheus_text(&stats);
        assert!(text.contains("fdpp_tokens_generated 42\n"), "{text}");
        assert!(text.contains("fdpp_kv_refcount_ok 1\n"));
        assert!(text.contains("# TYPE fdpp_step_us histogram"));
        assert!(text.contains("fdpp_step_us_count 2\n"));
        assert!(text.contains("fdpp_step_us_sum 903\n"));
        assert!(text.contains("le=\"+Inf\"} 2\n"), "cumulative top bucket");
        assert!(text.contains("fdpp_queue_depth{priority=\"5\"} 1\n"));
        assert!(text.contains("fdpp_tenant_generated_tokens{tenant=\"acme\"} 7\n"));
        // Deterministic: same snapshot, same bytes.
        assert_eq!(text, prometheus_text(&stats));
    }

    #[test]
    fn prometheus_renders_per_replica_labels() {
        let stats = Json::obj(vec![(
            "replicas",
            Json::obj(vec![
                (
                    "0",
                    Json::obj(vec![
                        ("up", Json::Num(1.0)),
                        ("health", Json::Str("up".into())),
                        ("routed", Json::Num(5.0)),
                    ]),
                ),
                (
                    "1",
                    Json::obj(vec![
                        ("up", Json::Num(0.0)),
                        ("health", Json::Str("dead".into())),
                        ("routed", Json::Num(3.0)),
                    ]),
                ),
            ]),
        )]);
        let text = prometheus_text(&stats);
        assert!(text.contains("# TYPE fdpp_replica_up gauge"));
        assert!(text.contains("fdpp_replica_up{replica=\"0\"} 1\n"), "{text}");
        assert!(text.contains("fdpp_replica_up{replica=\"1\"} 0\n"));
        assert!(text.contains("fdpp_replica_routed{replica=\"1\"} 3\n"));
        // String fields get no series of their own.
        assert!(!text.contains("fdpp_replica_health"));
        assert_eq!(text, prometheus_text(&stats));
    }

    #[test]
    fn prometheus_renders_per_shard_labels() {
        let stats = Json::obj(vec![(
            "per_shard",
            Json::obj(vec![
                (
                    "0",
                    Json::obj(vec![
                        ("joins", Json::Num(4.0)),
                        ("kv_elems", Json::Num(96.0)),
                    ]),
                ),
                (
                    "1",
                    Json::obj(vec![
                        ("joins", Json::Num(4.0)),
                        ("kv_elems", Json::Num(64.0)),
                    ]),
                ),
            ]),
        )]);
        let text = prometheus_text(&stats);
        assert!(text.contains("# TYPE fdpp_shard_joins gauge"));
        assert!(text.contains("fdpp_shard_joins{shard=\"0\"} 4\n"), "{text}");
        assert!(text.contains("fdpp_shard_kv_elems{shard=\"1\"} 64\n"));
        assert_eq!(text, prometheus_text(&stats));
    }

    #[test]
    fn breakdown_json_round_trips() {
        let b = SpanBreakdown {
            queue_wait_us: 1,
            prefill_us: 2,
            decode_us: 3,
            paused_us: 4,
            ttft_us: Some(5),
            total_us: 10,
        };
        let j = crate::util::json::parse(&b.to_json().to_string()).unwrap();
        assert_eq!(j.get("ttft_us").and_then(Json::as_usize), Some(5));
        assert_eq!(j.get("total_us").and_then(Json::as_usize), Some(10));
        let none = SpanBreakdown::default().to_json();
        assert_eq!(none.get("ttft_us"), Some(&Json::Null));
    }
}
