//! Prefix cache: a token-level radix tree over KV blocks.
//!
//! New requests frequently share a prompt prefix (system prompts,
//! few-shot templates). Re-prefilling that prefix recomputes and
//! re-stores KV that is already resident. This module keeps a radix
//! tree keyed on token ids whose edges carry the physical KV blocks of
//! the tokens they spell (SGLang-RadixAttention-style, quantized to the
//! paged-cache block size):
//!
//! - `match_prefix` walks the tree and returns the longest cached
//!   prefix (in whole blocks) plus its block ids; the engine attaches
//!   those blocks to the new sequence via
//!   [`KvCache::alloc_seq_with_prefix`] instead of re-prefilling them.
//! - `insert` registers a retired sequence's prompt+generation KV so
//!   future requests can reuse it. Stored blocks get one extra
//!   reference owned by the tree, so they outlive the sequence.
//! - `evict` reclaims least-recently-used leaf blocks whose only
//!   remaining reference is the tree's (no running sequence uses
//!   them), pushing them back to the allocator's free list. Leaves are
//!   trimmed from the tail so a partially-pinned leaf can still yield
//!   its unpinned blocks.
//!
//! The tree stores only *full* blocks: a prefix is reusable at the
//! granularity the paged allocator can share. Sub-block overlaps are
//! handled by the KV cache's copy-on-write when a sequence appends into
//! a shared partial tail.
//!
//! Cache hits reuse *storage*; the block sharing they create is also
//! what makes *compute* reuse possible downstream: sequences whose
//! chains share physical prefix blocks are grouped per decode step by
//! [`crate::core::form_decode_groups`] so an opted-in backend scores
//! the shared prefix once per group (see the "Grouped decode" section
//! of `docs/ARCHITECTURE.md`).

use std::collections::HashMap;

use crate::kvcache::KvCache;

/// Result of a prefix lookup.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PrefixMatch {
    /// Physical blocks covering the matched prefix, in position order.
    pub blocks: Vec<usize>,
    /// Matched length in tokens (multiple of the block size).
    pub tokens: usize,
}

#[derive(Debug)]
struct Node {
    /// Edge label from the parent (token ids); multiple of block_tokens.
    key: Vec<u32>,
    /// Physical blocks for `key`; blocks.len() * block_tokens == key.len().
    blocks: Vec<usize>,
    /// First token of each child's key -> arena index.
    children: HashMap<u32, usize>,
    parent: usize,
    last_access: u64,
    live: bool,
}

/// Token-level radix tree over KV blocks with LRU leaf eviction.
pub struct PrefixCache {
    block_tokens: usize,
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    clock: u64,
    n_cached_blocks: usize,
}

const ROOT: usize = 0;

fn common_prefix_len(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl PrefixCache {
    pub fn new(block_tokens: usize) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        PrefixCache {
            block_tokens,
            nodes: vec![Node {
                key: Vec::new(),
                blocks: Vec::new(),
                children: HashMap::new(),
                parent: ROOT,
                last_access: 0,
                live: true,
            }],
            free_nodes: Vec::new(),
            clock: 1,
            n_cached_blocks: 0,
        }
    }

    /// Blocks currently referenced (retained) by the tree.
    pub fn cached_blocks(&self) -> usize {
        self.n_cached_blocks
    }

    /// Every physical block the tree holds a reference on, one entry
    /// per tree-held reference, sorted — the prefix cache's side of the
    /// simulation-test refcount-conservation oracle.
    pub fn tree_block_refs(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.n_cached_blocks);
        for (idx, n) in self.nodes.iter().enumerate() {
            if idx == ROOT || !n.live {
                continue;
            }
            out.extend_from_slice(&n.blocks);
        }
        out.sort_unstable();
        out
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn new_node(&mut self, node: Node) -> usize {
        if let Some(idx) = self.free_nodes.pop() {
            self.nodes[idx] = node;
            idx
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    /// Longest cached prefix of `tokens`, in whole blocks. Touches the
    /// LRU clock of every node on the matched path.
    pub fn match_prefix(&mut self, tokens: &[u32]) -> PrefixMatch {
        let bt = self.block_tokens;
        let mut out = PrefixMatch::default();
        let mut node = ROOT;
        let mut pos = 0usize;
        let now = self.tick();
        self.nodes[ROOT].last_access = now;
        while pos < tokens.len() {
            let Some(&child) = self.nodes[node].children.get(&tokens[pos]) else {
                break;
            };
            let common = common_prefix_len(&self.nodes[child].key, &tokens[pos..]);
            let common = (common / bt) * bt;
            if common == 0 {
                break;
            }
            self.nodes[child].last_access = now;
            out.blocks
                .extend_from_slice(&self.nodes[child].blocks[..common / bt]);
            out.tokens += common;
            pos += common;
            if common < self.nodes[child].key.len() {
                break; // diverged (or ran out) inside this edge
            }
            node = child;
        }
        out
    }

    /// Longest cached prefix length in tokens, without touching LRU
    /// state — for scheduler admission-cost estimates.
    pub fn peek_match_tokens(&self, tokens: &[u32]) -> usize {
        let bt = self.block_tokens;
        let mut node = ROOT;
        let mut pos = 0usize;
        while pos < tokens.len() {
            let Some(&child) = self.nodes[node].children.get(&tokens[pos]) else {
                break;
            };
            let common = common_prefix_len(&self.nodes[child].key, &tokens[pos..]);
            let common = (common / bt) * bt;
            if common == 0 {
                break;
            }
            pos += common;
            if common < self.nodes[child].key.len() {
                break;
            }
            node = child;
        }
        pos
    }

    /// Register `tokens` (a retired sequence's prompt + generated ids)
    /// backed by `blocks` (its block table, position order). Only the
    /// full-block prefix is stored; blocks newly retained by the tree
    /// get one extra reference in `kv`. Returns the number of blocks
    /// newly cached.
    pub fn insert(&mut self, tokens: &[u32], blocks: &[usize], kv: &mut KvCache) -> usize {
        let bt = self.block_tokens;
        let n_full = (tokens.len() / bt).min(blocks.len());
        if n_full == 0 {
            return 0;
        }
        let end = n_full * bt;
        let mut node = ROOT;
        let mut pos = 0usize;
        let now = self.tick();
        let mut added = 0usize;
        self.nodes[ROOT].last_access = now;
        while pos < end {
            match self.nodes[node].children.get(&tokens[pos]).copied() {
                None => {
                    // New leaf carrying the uncovered tail.
                    let key = tokens[pos..end].to_vec();
                    let tail = blocks[pos / bt..n_full].to_vec();
                    kv.incref_blocks(&tail);
                    added += tail.len();
                    self.n_cached_blocks += tail.len();
                    let leaf = self.new_node(Node {
                        key,
                        blocks: tail,
                        children: HashMap::new(),
                        parent: node,
                        last_access: now,
                        live: true,
                    });
                    self.nodes[node].children.insert(tokens[pos], leaf);
                    return added;
                }
                Some(child) => {
                    let common = common_prefix_len(&self.nodes[child].key, &tokens[pos..end]);
                    let common = (common / bt) * bt;
                    if common == 0 {
                        // Divergence inside the first block of the edge:
                        // not representable at block granularity.
                        return added;
                    }
                    self.nodes[child].last_access = now;
                    if common < self.nodes[child].key.len() {
                        // Split the edge at the block boundary `common`.
                        let mid = self.split_edge(node, child, common, now);
                        node = mid;
                    } else {
                        node = child;
                    }
                    pos += common;
                }
            }
        }
        added
    }

    /// Split `child`'s edge after `at` tokens (block-aligned), inserting
    /// a mid node under `parent`. Returns the mid node's index.
    fn split_edge(&mut self, parent: usize, child: usize, at: usize, now: u64) -> usize {
        let bt = self.block_tokens;
        debug_assert!(at % bt == 0 && at > 0 && at < self.nodes[child].key.len());
        let head_key = self.nodes[child].key[..at].to_vec();
        let head_blocks = self.nodes[child].blocks[..at / bt].to_vec();
        let tail_key = self.nodes[child].key[at..].to_vec();
        let tail_blocks = self.nodes[child].blocks[at / bt..].to_vec();
        let first_head = head_key[0];
        let first_tail = tail_key[0];
        let mid = self.new_node(Node {
            key: head_key,
            blocks: head_blocks,
            children: HashMap::new(),
            parent,
            last_access: now,
            live: true,
        });
        let c = &mut self.nodes[child];
        c.key = tail_key;
        c.blocks = tail_blocks;
        c.parent = mid;
        self.nodes[mid].children.insert(first_tail, child);
        self.nodes[parent].children.insert(first_head, mid);
        mid
    }

    /// Evict least-recently-used leaf blocks until at least
    /// `want_blocks` have been returned to `kv`'s free list, or nothing
    /// evictable remains. Only blocks whose sole reference is the
    /// tree's (refcount 1) are reclaimable; leaves are trimmed from the
    /// tail so partially-pinned leaves still yield their unpinned tail.
    /// Returns the number of blocks freed.
    pub fn evict(&mut self, want_blocks: usize, kv: &mut KvCache) -> usize {
        let mut freed = 0usize;
        while freed < want_blocks {
            // LRU live leaf with at least one reclaimable tail block.
            let mut victim: Option<(usize, u64)> = None;
            for (idx, n) in self.nodes.iter().enumerate() {
                if idx == ROOT || !n.live || !n.children.is_empty() {
                    continue;
                }
                let tail_free = n
                    .blocks
                    .last()
                    .map(|&b| kv.block_refcount(b) == 1)
                    .unwrap_or(false);
                if !tail_free {
                    continue;
                }
                if victim.map(|(_, t)| n.last_access < t).unwrap_or(true) {
                    victim = Some((idx, n.last_access));
                }
            }
            let Some((idx, _)) = victim else { break };
            // Remember the edge's first token *before* trimming: if the
            // whole leaf empties, the parent's child entry is keyed by it.
            let first_token = self.nodes[idx].key.first().copied();
            // Trim reclaimable blocks from the tail of this leaf.
            while freed < want_blocks {
                let Some(&b) = self.nodes[idx].blocks.last() else { break };
                if kv.block_refcount(b) != 1 {
                    break;
                }
                self.nodes[idx].blocks.pop();
                let bt = self.block_tokens;
                let keep = self.nodes[idx].blocks.len() * bt;
                self.nodes[idx].key.truncate(keep);
                kv.decref_blocks(&[b]);
                self.n_cached_blocks -= 1;
                freed += 1;
            }
            if self.nodes[idx].blocks.is_empty() {
                self.remove_leaf(idx, first_token);
            }
        }
        freed
    }

    /// Drop every cached block reference (shutdown / tests).
    pub fn clear(&mut self, kv: &mut KvCache) {
        for idx in 0..self.nodes.len() {
            if idx == ROOT || !self.nodes[idx].live {
                continue;
            }
            let blocks = std::mem::take(&mut self.nodes[idx].blocks);
            kv.decref_blocks(&blocks);
            self.nodes[idx].live = false;
            self.free_nodes.push(idx);
        }
        self.nodes[ROOT].children.clear();
        self.n_cached_blocks = 0;
    }

    /// Unlink and tombstone an emptied leaf. `first_token` is the first
    /// token of the edge as it was keyed under the parent (captured
    /// before any trimming emptied the key — without it the parent
    /// would keep a dangling edge to a reusable arena slot).
    fn remove_leaf(&mut self, idx: usize, first_token: Option<u32>) {
        debug_assert!(self.nodes[idx].children.is_empty());
        let parent = self.nodes[idx].parent;
        if let Some(first) = first_token {
            debug_assert_eq!(self.nodes[parent].children.get(&first), Some(&idx));
            self.nodes[parent].children.remove(&first);
        }
        self.nodes[idx].live = false;
        self.nodes[idx].key.clear();
        self.nodes[idx].blocks.clear();
        self.free_nodes.push(idx);
        // A parent left childless with no other use will be evicted by
        // LRU in a later round (it is now a leaf).
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvGeometry;

    const BT: usize = 4;

    fn kv(total: usize) -> KvCache {
        KvCache::new(
            KvGeometry {
                n_layers: 1,
                n_heads: 1,
                head_dim: 2,
                block_tokens: BT,
                max_seq: 64,
            },
            total,
        )
    }

    /// Allocate a sequence with `n_tokens` capacity, write deterministic
    /// data into every position, and return its block table.
    fn fill_seq(kv: &mut KvCache, id: u64, n_tokens: usize) -> Vec<usize> {
        kv.alloc_seq(id, n_tokens).unwrap();
        let te = kv.geometry().token_elems();
        for pos in 0..n_tokens {
            let col = vec![id as f32 * 100.0 + pos as f32; te];
            kv.write_token(id, pos, &col, &col).unwrap();
        }
        kv.seq_blocks(id).unwrap()
    }

    #[test]
    fn match_on_empty_tree_is_empty() {
        let mut pc = PrefixCache::new(BT);
        let m = pc.match_prefix(&[1, 2, 3, 4]);
        assert_eq!(m.tokens, 0);
        assert!(m.blocks.is_empty());
    }

    #[test]
    fn insert_then_match_full_and_partial() {
        let mut kv = kv(16);
        let mut pc = PrefixCache::new(BT);
        let toks: Vec<u32> = (0..12).collect(); // 3 full blocks
        let blocks = fill_seq(&mut kv, 1, 12);
        assert_eq!(pc.insert(&toks, &blocks, &mut kv), 3);
        assert_eq!(pc.cached_blocks(), 3);

        // Exact prefix reuse.
        let m = pc.match_prefix(&toks);
        assert_eq!(m.tokens, 12);
        assert_eq!(m.blocks, blocks[..3].to_vec());

        // Longer query matches the stored 12.
        let longer: Vec<u32> = (0..20).collect();
        assert_eq!(pc.match_prefix(&longer).tokens, 12);

        // Query diverging after 8 tokens matches 2 blocks.
        let mut div = toks.clone();
        div[9] = 99;
        let m = pc.match_prefix(&div);
        assert_eq!(m.tokens, 8);
        assert_eq!(m.blocks, blocks[..2].to_vec());

        // Sub-block prefix (3 tokens) matches nothing.
        assert_eq!(pc.match_prefix(&toks[..3]).tokens, 0);
    }

    #[test]
    fn insert_dedups_shared_prefix() {
        let mut kv = kv(16);
        let mut pc = PrefixCache::new(BT);
        let a: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let ba = fill_seq(&mut kv, 1, 8);
        assert_eq!(pc.insert(&a, &ba, &mut kv), 2);

        // Second sequence shares the first block, diverges in the second.
        let b: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        let bb = fill_seq(&mut kv, 2, 8);
        let added = pc.insert(&b, &bb, &mut kv);
        assert_eq!(added, 1, "only the diverging tail block is new");
        assert_eq!(pc.cached_blocks(), 3);

        // Both prefixes match fully, sharing the first physical block.
        let ma = pc.match_prefix(&a);
        let mb = pc.match_prefix(&b);
        assert_eq!(ma.tokens, 8);
        assert_eq!(mb.tokens, 8);
        assert_eq!(ma.blocks[0], mb.blocks[0]);
        assert_eq!(ma.blocks[0], ba[0]);
        assert_ne!(ma.blocks[1], mb.blocks[1]);
    }

    #[test]
    fn eviction_frees_lru_leaf_blocks_only_when_unreferenced() {
        let mut kv = kv(8);
        let mut pc = PrefixCache::new(BT);
        let a: Vec<u32> = vec![1, 2, 3, 4];
        let ba = fill_seq(&mut kv, 1, 4);
        pc.insert(&a, &ba, &mut kv);
        // Sequence 1 still holds its block: nothing evictable.
        assert_eq!(pc.evict(1, &mut kv), 0);

        kv.free_seq(1).unwrap();
        assert_eq!(kv.used_blocks(), 1, "tree retains the block");
        assert_eq!(pc.evict(1, &mut kv), 1);
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(pc.cached_blocks(), 0);
        assert_eq!(pc.match_prefix(&a).tokens, 0, "evicted prefix gone");
    }

    #[test]
    fn eviction_prefers_lru() {
        let mut kv = kv(16);
        let mut pc = PrefixCache::new(BT);
        let a: Vec<u32> = vec![1, 1, 1, 1];
        let b: Vec<u32> = vec![2, 2, 2, 2];
        let ba = fill_seq(&mut kv, 1, 4);
        let bb = fill_seq(&mut kv, 2, 4);
        pc.insert(&a, &ba, &mut kv);
        pc.insert(&b, &bb, &mut kv);
        kv.free_seq(1).unwrap();
        kv.free_seq(2).unwrap();
        // Touch `a` so `b` becomes the LRU victim.
        pc.match_prefix(&a);
        assert_eq!(pc.evict(1, &mut kv), 1);
        assert_eq!(pc.match_prefix(&a).tokens, 4, "recently used survives");
        assert_eq!(pc.match_prefix(&b).tokens, 0, "LRU leaf evicted");
    }

    #[test]
    fn evicted_edge_is_reinsertable_and_never_served_stale() {
        // Regression: eviction used to leave a dangling parent edge
        // (the leaf's key was truncated before unlinking), which both
        // blocked re-caching of that prefix and could serve a reused
        // arena node's blocks for the wrong tokens.
        let mut kv = kv(32);
        let mut pc = PrefixCache::new(BT);
        let a: Vec<u32> = vec![1, 1, 1, 1];
        let ba = fill_seq(&mut kv, 1, 4);
        pc.insert(&a, &ba, &mut kv);
        kv.free_seq(1).unwrap();
        assert_eq!(pc.evict(1, &mut kv), 1);
        assert_eq!(pc.match_prefix(&a).tokens, 0);

        // Same prefix must be cacheable again with fresh blocks...
        let ba2 = fill_seq(&mut kv, 2, 4);
        assert_eq!(pc.insert(&a, &ba2, &mut kv), 1, "re-insert after evict");
        let m = pc.match_prefix(&a);
        assert_eq!((m.tokens, m.blocks), (4, ba2.clone()));

        // ...and an unrelated prefix starting with the same token must
        // not resolve through any recycled arena slot.
        let b: Vec<u32> = vec![1, 9, 9, 9];
        assert_eq!(pc.match_prefix(&b).tokens, 0);
        kv.free_seq(2).unwrap();
        pc.clear(&mut kv);
        assert_eq!(kv.free_blocks(), 32);
    }

    #[test]
    fn clear_releases_everything() {
        let mut kv = kv(16);
        let mut pc = PrefixCache::new(BT);
        let toks: Vec<u32> = (0..8).collect();
        let blocks = fill_seq(&mut kv, 1, 8);
        pc.insert(&toks, &blocks, &mut kv);
        kv.free_seq(1).unwrap();
        pc.clear(&mut kv);
        assert_eq!(kv.free_blocks(), 16);
        assert_eq!(pc.cached_blocks(), 0);
    }
}
