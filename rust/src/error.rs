//! Unified error type for the engine.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All the ways the engine can fail.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA runtime failure.
    Xla(xla::Error),
    /// Artifact manifest or HLO file problems.
    Artifact(String),
    /// KV-cache exhaustion or misuse.
    KvCache(String),
    /// Scheduling / batching invariant violation.
    Schedule(String),
    /// Configuration errors.
    Config(String),
    /// Request-level errors (bad input, closed stream, ...).
    Request(String),
    /// A per-tenant concurrency quota rejected the submission
    /// (`EngineConfig::tenant_max_inflight`); surfaced on the wire as
    /// the `quota_exceeded` error code.
    Quota(String),
    /// A per-tenant token-rate refill bucket rejected the submission
    /// (`FleetConfig::tenant_token_rate`); surfaced on the wire as the
    /// `rate_limit_exceeded` error code.
    RateLimit(String),
    /// I/O.
    Io(std::io::Error),
    /// JSON (manifest, lookup tables).
    Json(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::KvCache(m) => write!(f, "kvcache: {m}"),
            Error::Schedule(m) => write!(f, "schedule: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Request(m) => write!(f, "request: {m}"),
            Error::Quota(m) => write!(f, "quota: {m}"),
            Error::RateLimit(m) => write!(f, "rate limit: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(e) => write!(f, "json: {e}"),
        }
    }
}

impl Error {
    /// Stable wire-protocol error code for a rejected submission
    /// (docs/PROTOCOL.md § Errors): quota rejections are
    /// distinguishable so clients can back off instead of retrying,
    /// and rate limits carry their own code so clients can retry after
    /// the bucket refills.
    pub fn wire_code(&self) -> &'static str {
        match self {
            Error::Quota(_) => "quota_exceeded",
            Error::RateLimit(_) => "rate_limit_exceeded",
            _ => "rejected",
        }
    }
}

impl std::error::Error for Error {}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

