//! Workload generation: synthetic request traces with Poisson arrivals
//! and configurable prompt/output length distributions, plus fixed
//! traces for reproducible benches.

use crate::util::rng::Rng;

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival offset from trace start, seconds.
    pub arrival_s: f64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// Tenant id for multi-tenant accounting ("" = default tenant).
    pub tenant: String,
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean arrival rate, requests/second (Poisson process).
    pub rate: f64,
    pub n_requests: usize,
    /// Prompt length range in *characters* (byte tokenizer: ~= tokens).
    pub prompt_len: (usize, usize),
    pub max_new_tokens: (usize, usize),
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            rate: 20.0,
            n_requests: 16,
            prompt_len: (8, 48),
            max_new_tokens: (8, 32),
            seed: 0,
        }
    }
}

const WORDS: &[&str] = &[
    "what", "is", "the", "largest", "ocean", "pacific", "model", "token",
    "fast", "decode", "prefill", "batch", "cache", "kernel", "matrix",
    "softmax", "value", "unified", "flat", "gemm", "tile", "buffer",
];

/// Generate a deterministic trace from the spec.
pub fn generate(spec: &WorkloadSpec) -> Vec<TraceRequest> {
    let mut rng = Rng::seed_from_u64(spec.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for _ in 0..spec.n_requests {
        // Exponential inter-arrival (Poisson process).
        t += rng.gen_exp(spec.rate);
        let target = rng.gen_range(spec.prompt_len.0, spec.prompt_len.1);
        let prompt = word_soup(&mut rng, target);
        let max_new = rng.gen_range(spec.max_new_tokens.0, spec.max_new_tokens.1);
        out.push(TraceRequest {
            arrival_s: t,
            prompt,
            max_new_tokens: max_new,
            tenant: String::new(),
        });
    }
    out
}

/// Shared-prefix workload: N tenants, each with a fixed system prompt,
/// reused across requests with a Zipf-distributed tenant popularity —
/// the traffic shape the prefix cache is built for (multi-tenant
/// serving where a few hot system prompts dominate).
#[derive(Debug, Clone)]
pub struct SharedPrefixSpec {
    /// Distinct tenants (system prompts).
    pub n_tenants: usize,
    /// Zipf exponent for tenant popularity (1.0 = classic Zipf).
    pub zipf_s: f64,
    /// System prompt length in characters (byte tokenizer: ~= tokens).
    pub system_prompt_len: usize,
    /// Per-request unique suffix length range in characters.
    pub suffix_len: (usize, usize),
    pub n_requests: usize,
    pub max_new_tokens: (usize, usize),
    /// Mean arrival rate, requests/second (Poisson process).
    pub rate: f64,
    pub seed: u64,
}

impl Default for SharedPrefixSpec {
    fn default() -> Self {
        SharedPrefixSpec {
            n_tenants: 8,
            zipf_s: 1.0,
            system_prompt_len: 128,
            suffix_len: (4, 12),
            n_requests: 96,
            max_new_tokens: (4, 12),
            rate: 1e9, // offline by default: everything arrives at t=0
            seed: 0,
        }
    }
}

/// Deterministic word soup of exactly `len` characters.
fn word_soup(rng: &mut Rng, len: usize) -> String {
    let mut s = String::new();
    while s.len() < len {
        if !s.is_empty() {
            s.push(' ');
        }
        s.push_str(WORDS[rng.gen_range(0, WORDS.len() - 1)]);
    }
    s.truncate(len.max(1));
    s
}

/// The tenant system prompts a spec generates (exposed so benches can
/// report per-tenant stats).
pub fn tenant_prompts(spec: &SharedPrefixSpec) -> Vec<String> {
    (0..spec.n_tenants)
        .map(|i| {
            let mut rng = Rng::seed_from_u64(spec.seed ^ 0x7E9A97 ^ ((i as u64) << 17));
            // Distinct leading marker so tenants never share a prefix by
            // accident; the shared part within a tenant stays maximal.
            let head = format!("[tenant {i}] ");
            let body_len = spec.system_prompt_len.saturating_sub(head.len()).max(1);
            format!("{head}{}", word_soup(&mut rng, body_len))
        })
        .collect()
}

/// Generate a shared-prefix trace: each request is one tenant's system
/// prompt plus a short unique suffix, tenants drawn Zipf(s).
pub fn shared_prefix_trace(spec: &SharedPrefixSpec) -> Vec<TraceRequest> {
    assert!(spec.n_tenants > 0, "need at least one tenant");
    let prompts = tenant_prompts(spec);
    // Zipf CDF over tenant ranks 1..=n.
    let weights: Vec<f64> = (1..=spec.n_tenants)
        .map(|k| 1.0 / (k as f64).powf(spec.zipf_s))
        .collect();
    let total: f64 = weights.iter().sum();
    let mut rng = Rng::seed_from_u64(spec.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for _ in 0..spec.n_requests {
        t += rng.gen_exp(spec.rate);
        let mut u = rng.next_f64() * total;
        let mut tenant = spec.n_tenants - 1;
        for (k, w) in weights.iter().enumerate() {
            if u < *w {
                tenant = k;
                break;
            }
            u -= w;
        }
        let suffix_len = rng.gen_range(spec.suffix_len.0, spec.suffix_len.1);
        let suffix = word_soup(&mut rng, suffix_len);
        let max_new = rng.gen_range(spec.max_new_tokens.0, spec.max_new_tokens.1);
        out.push(TraceRequest {
            arrival_s: t,
            prompt: format!("{} {suffix}", prompts[tenant]),
            max_new_tokens: max_new,
            tenant: format!("tenant-{tenant}"),
        });
    }
    out
}

/// Small fixed trace used by integration tests and the quickstart.
pub fn fixed_smoke_trace() -> Vec<TraceRequest> {
    vec![
        TraceRequest {
            arrival_s: 0.0,
            prompt: "What is the largest ocean?".into(),
            max_new_tokens: 16,
            tenant: String::new(),
        },
        TraceRequest {
            arrival_s: 0.0,
            prompt: "fast decode".into(),
            max_new_tokens: 8,
            tenant: String::new(),
        },
        TraceRequest {
            arrival_s: 0.01,
            prompt: "unified max value softmax".into(),
            max_new_tokens: 12,
            tenant: String::new(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let spec = WorkloadSpec::default();
        assert_eq!(generate(&spec), generate(&spec));
        let other = WorkloadSpec {
            seed: 1,
            ..WorkloadSpec::default()
        };
        assert_ne!(generate(&spec), generate(&other));
    }

    #[test]
    fn arrivals_monotone_and_lengths_in_range() {
        let spec = WorkloadSpec {
            n_requests: 50,
            ..WorkloadSpec::default()
        };
        let trace = generate(&spec);
        assert_eq!(trace.len(), 50);
        let mut prev = 0.0;
        for r in &trace {
            assert!(r.arrival_s >= prev);
            prev = r.arrival_s;
            assert!(r.prompt.len() <= spec.prompt_len.1);
            assert!(!r.prompt.is_empty());
            assert!(r.max_new_tokens >= spec.max_new_tokens.0);
            assert!(r.max_new_tokens <= spec.max_new_tokens.1);
        }
    }

    #[test]
    fn shared_prefix_trace_deterministic_and_tenant_shaped() {
        let spec = SharedPrefixSpec::default();
        let a = shared_prefix_trace(&spec);
        let b = shared_prefix_trace(&spec);
        assert_eq!(a, b, "trace must be deterministic per seed");
        assert_eq!(a.len(), spec.n_requests);

        let prompts = tenant_prompts(&spec);
        assert_eq!(prompts.len(), spec.n_tenants);
        for (i, p) in prompts.iter().enumerate() {
            assert!(p.starts_with(&format!("[tenant {i}] ")));
            assert_eq!(p.len(), spec.system_prompt_len);
        }

        // Every request extends exactly one tenant's system prompt.
        let mut counts = vec![0usize; spec.n_tenants];
        for r in &a {
            let tenant = prompts
                .iter()
                .position(|p| r.prompt.starts_with(p.as_str()))
                .expect("request must carry a tenant prefix");
            counts[tenant] += 1;
            assert!(r.prompt.len() > prompts[tenant].len(), "suffix present");
            assert_eq!(r.tenant, format!("tenant-{tenant}"), "tenant id labeled");
        }
        // Zipf(1.0): rank 1 must dominate rank n (weights 1 vs 1/8).
        assert!(
            counts[0] > counts[spec.n_tenants - 1],
            "Zipf head should outweigh tail: {counts:?}"
        );
    }

    #[test]
    fn rate_roughly_respected() {
        let spec = WorkloadSpec {
            rate: 100.0,
            n_requests: 200,
            seed: 3,
            ..WorkloadSpec::default()
        };
        let trace = generate(&spec);
        let span = trace.last().unwrap().arrival_s;
        let rate = 200.0 / span;
        assert!(rate > 50.0 && rate < 200.0, "empirical rate {rate}");
    }
}
