//! Workload generation: synthetic request traces with Poisson arrivals
//! and configurable prompt/output length distributions, plus fixed
//! traces for reproducible benches.

use crate::util::rng::Rng;

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival offset from trace start, seconds.
    pub arrival_s: f64,
    pub prompt: String,
    pub max_new_tokens: usize,
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Mean arrival rate, requests/second (Poisson process).
    pub rate: f64,
    pub n_requests: usize,
    /// Prompt length range in *characters* (byte tokenizer: ~= tokens).
    pub prompt_len: (usize, usize),
    pub max_new_tokens: (usize, usize),
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            rate: 20.0,
            n_requests: 16,
            prompt_len: (8, 48),
            max_new_tokens: (8, 32),
            seed: 0,
        }
    }
}

const WORDS: &[&str] = &[
    "what", "is", "the", "largest", "ocean", "pacific", "model", "token",
    "fast", "decode", "prefill", "batch", "cache", "kernel", "matrix",
    "softmax", "value", "unified", "flat", "gemm", "tile", "buffer",
];

/// Generate a deterministic trace from the spec.
pub fn generate(spec: &WorkloadSpec) -> Vec<TraceRequest> {
    let mut rng = Rng::seed_from_u64(spec.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for _ in 0..spec.n_requests {
        // Exponential inter-arrival (Poisson process).
        t += rng.gen_exp(spec.rate);
        let target = rng.gen_range(spec.prompt_len.0, spec.prompt_len.1);
        let mut prompt = String::new();
        while prompt.len() < target {
            if !prompt.is_empty() {
                prompt.push(' ');
            }
            prompt.push_str(WORDS[rng.gen_range(0, WORDS.len() - 1)]);
        }
        prompt.truncate(target.max(1));
        let max_new = rng.gen_range(spec.max_new_tokens.0, spec.max_new_tokens.1);
        out.push(TraceRequest {
            arrival_s: t,
            prompt,
            max_new_tokens: max_new,
        });
    }
    out
}

/// Small fixed trace used by integration tests and the quickstart.
pub fn fixed_smoke_trace() -> Vec<TraceRequest> {
    vec![
        TraceRequest {
            arrival_s: 0.0,
            prompt: "What is the largest ocean?".into(),
            max_new_tokens: 16,
        },
        TraceRequest {
            arrival_s: 0.0,
            prompt: "fast decode".into(),
            max_new_tokens: 8,
        },
        TraceRequest {
            arrival_s: 0.01,
            prompt: "unified max value softmax".into(),
            max_new_tokens: 12,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let spec = WorkloadSpec::default();
        assert_eq!(generate(&spec), generate(&spec));
        let other = WorkloadSpec {
            seed: 1,
            ..WorkloadSpec::default()
        };
        assert_ne!(generate(&spec), generate(&other));
    }

    #[test]
    fn arrivals_monotone_and_lengths_in_range() {
        let spec = WorkloadSpec {
            n_requests: 50,
            ..WorkloadSpec::default()
        };
        let trace = generate(&spec);
        assert_eq!(trace.len(), 50);
        let mut prev = 0.0;
        for r in &trace {
            assert!(r.arrival_s >= prev);
            prev = r.arrival_s;
            assert!(r.prompt.len() <= spec.prompt_len.1);
            assert!(!r.prompt.is_empty());
            assert!(r.max_new_tokens >= spec.max_new_tokens.0);
            assert!(r.max_new_tokens <= spec.max_new_tokens.1);
        }
    }

    #[test]
    fn rate_roughly_respected() {
        let spec = WorkloadSpec {
            rate: 100.0,
            n_requests: 200,
            seed: 3,
            ..WorkloadSpec::default()
        };
        let trace = generate(&spec);
        let span = trace.last().unwrap().arrival_s;
        let rate = 200.0 / span;
        assert!(rate > 50.0 && rate < 200.0, "empirical rate {rate}");
    }
}
