//! FlashDecoding++ — a reproduction of "FlashDecoding++: Faster Large
//! Language Model Inference on GPUs" (Hong et al., 2023) as a three-layer
//! Rust + JAX + Pallas inference engine.
//!
//! Layer 1 (Pallas, build-time Python) implements the paper's kernels:
//! the asynchronized softmax with unified max value (§3) and the flat
//! GEMM with pad-to-8 / double buffering (§4). Layer 2 (JAX) is a
//! Llama-style transformer lowered AOT to HLO text. Layer 3 — this crate —
//! owns everything on the request path: the PJRT runtime, the KV cache,
//! continuous batching, the prefill/decode scheduler, the heuristic
//! dataflow dispatch (§5), the serving loop, and the analytic GPU model
//! that regenerates the paper's figures.
//!
//! Python never runs at serving time; `make artifacts` is the only
//! compile-path entry.
//!
//! # Prefix-sharing KV cache
//!
//! Production traffic repeats prompt prefixes (system prompts, few-shot
//! templates). The `prefixcache` subsystem removes that redundancy:
//!
//! - [`kvcache`] blocks are reference counted; sequences and the prefix
//!   cache share the blocks of a common prefix, with copy-on-write
//!   protecting partially-filled shared tail blocks.
//! - [`prefixcache`] keeps a radix tree keyed on token ids whose edges
//!   carry KV block ids. `match_prefix` finds the longest cached prefix
//!   for a new prompt; `insert` registers retired prefixes; LRU leaf
//!   eviction returns refcount-0 blocks to the allocator under pressure.
//! - [`scheduler`] is cache-aware: admission is charged only for the
//!   blocks a prompt cannot reuse, and preemption prefers victims whose
//!   blocks stay reusable in the cache.
//! - [`engine`] attaches matched blocks at prefill instead of
//!   re-storing them and registers prompts at retirement; [`simengine`]
//!   is the PJRT-free twin that exercises the same block machinery with
//!   a deterministic hash model (benches + tests on a bare checkout).
//!
//! Block lifecycle:
//!
//! ```text
//!   free ──alloc_seq──────────▶ allocated (rc=1, private to one seq)
//!     ▲                            │
//!     │                            │ attach / prefixcache::insert
//!     │                            ▼
//!     │                         shared (rc>1: seqs + tree; immutable,
//!     │                            │         writes trigger COW)
//!     │                            │ owners release (free_seq / detach)
//!     │                            ▼
//!     │                         cached (rc=1, held only by the tree,
//!     │                            │         reusable by match_prefix)
//!     └────evict (LRU leaves)──────┘
//! ```
//!
//! A block returns to the free list exactly when its last reference
//! drops — `free_seq` on a private block, or LRU eviction on a cached
//! one.
//!
//! # One engine core, many backends
//!
//! The entire serving loop lives once, in [`core::EngineCore`]: a
//! generic orchestrator owning admission, prefill/decode stepping,
//! stream flow control, preemption, cross-request dedup, per-tenant
//! quotas, finish accounting, [`core::TraceEvent`] emission, and the
//! [`core::EngineCore::audit`] snapshot. A [`core::Backend`] supplies
//! only compute: [`engine::Engine`] is `EngineCore<PjrtBackend>`
//! (compiled artifacts, device-resident dense KV),
//! [`simengine::SimEngine`] is `EngineCore<SimBackend>` (deterministic
//! hash model), and [`core::StubEngine`] is the differential-testing
//! third backend. Orchestration therefore *cannot* drift between the
//! real and simulated paths — it is the same code — and the production
//! engine exposes the same trace/audit surface the simulation oracles
//! check.
//!
//! # Unified serving API
//!
//! Every front-end — the JSON-lines TCP server ([`server`], protocol in
//! `docs/PROTOCOL.md`), benches, property tests, offline drivers —
//! talks to a generic [`api::InferenceEngine`]: `submit(GenRequest) ->
//! SubmissionHandle`, `step`, `cancel`, `metrics`, implemented once by
//! [`core::EngineCore`] for every backend. The shared admission /
//! eviction / preemption decisions live in [`policy`]. Requests carry
//! tenant, priority, and stop sequences; finish events carry a
//! per-request usage record (prefill / cached / generated token
//! counts), and metrics aggregate per-tenant counters.
//!
//! # Fleet serving
//!
//! [`fleet::Fleet`] scales the same API across N engine replicas: a
//! cache-aware router keeps an approximate [`fleet::RadixMirror`] of
//! each replica's prefix cache (fed from placements and admission
//! traces) and sends each request to the replica holding its longest
//! cached prefix, trading cache affinity against load balance under
//! [`config::FleetConfig::cache_vs_balance`]. Replicas drain or die
//! without losing requests (in-flight work is resubmitted to
//! survivors), fleet-wide tenant quotas and token-rate buckets are
//! enforced before placement, and a fleet of one is byte-identical to
//! a bare engine. The server drives a fleet through the same
//! [`api::InferenceEngine`] trait via the `drain_replica` /
//! `kill_replica` / `fleet_stats` admin verbs (protocol v2.4).
//!
//! Within one replica, [`shard::ShardedBackend`] splits any backend's
//! dense state across M simulated tensor-parallel lanes with per-shard
//! KV mirrors, collective accounting (all-gather at attention,
//! all-reduce at logits — [`shard::ShardMetrics`]), and LIMINAL-style
//! per-lane budgets on [`hwmodel`]. Sharding is invisible to
//! scheduling: the differential matrix proves byte-identical scenario
//! fingerprints for every M, and `BENCH_sharded.json` quantifies the
//! M×batch decode tradeoff.
//!
//! # End-to-end flow control
//!
//! The serving path is flow-controlled end to end, so memory stays
//! bounded under any client behavior:
//!
//! - Every request streams its events over a *bounded* channel
//!   ([`api::event_channel`], capacity =
//!   [`config::EngineConfig::stream_capacity`]). Engines check stream
//!   credit *before* decoding a sequence, so backpressure halts
//!   generation instead of dropping tokens.
//! - When a slow client's buffer fills, the configured
//!   [`config::BackpressurePolicy`] applies: `PauseDecode` parks the
//!   sequence (keeps KV, releases its decode lane, resumes losslessly
//!   once the client drains below half capacity) and `DropSlow`
//!   finishes it with `FinishReason::Overrun` and reclaims its KV.
//!   Dropped receivers (client hang-ups) are detected the same way and
//!   reclaimed.
//! - Preemption under KV pressure is *priority-aware* and its victim
//!   pool spans running and backpressure-paused sequences (parked work
//!   holds KV too): victims are ordered by (priority asc,
//!   reusable-blocks desc, recency), so a request is never preempted
//!   while a strictly lower-priority victim exists
//!   ([`scheduler::preemption_victim`] over
//!   [`policy::preempt_candidates`]).
//! - The server keeps a cross-connection [`router::RequestRegistry`]:
//!   every accepted submission gets a server-global id, `{"cancel": id}`
//!   works from any connection, and the admin
//!   `{"admin": {"cancel_tenant": ...}}` verb bulk-cancels a tenant.
//!
//! # Testing & determinism
//!
//! The stack is tier-1-testable without artifacts because the sim path
//! is *deterministic by construction*: [`simengine::SimEngine`] runs on
//! a manual [`util::clock::Clock`] (one quantum per step), and the
//! [`simtest`] harness expands a single seed into a scripted world —
//! adversarial clients, KV-pressure spikes, credit starvation — then
//! checks five global oracles (KV refcount conservation, stream-credit
//! bounds/losslessness, priority monotonicity, usage conservation, and
//! span conservation over the [`obs`] request timelines) after every
//! step. A failing seed prints a replay command, reproduces
//! byte-identically, and its report carries the engine's flight
//! recorder. The paper kernels are pinned by
//! `tests/conformance_softmax.rs` (unified-max vs two-pass softmax,
//! §3) and `tests/conformance_dataflow.rs` (inflection-table dispatch,
//! §5). See `docs/ARCHITECTURE.md` § "Testing & determinism".
//!
//! # Documentation map
//!
//! - `docs/ARCHITECTURE.md` — module map, KV block lifecycle, request
//!   lifecycle (including the backpressure states), the
//!   paper-technique-to-module table, and the testing & determinism
//!   guide (oracles, seed replay, adding scenarios).
//! - `docs/PROTOCOL.md` — the JSON-lines wire protocol (v2.4): stream
//!   credit semantics, global ids, admin verbs (`cancel_tenant`,
//!   `dump_flight`, `drain_replica`, `kill_replica`, `fleet_stats`),
//!   per-tenant quotas and rate limits, error codes.
//! - `docs/OBSERVABILITY.md` — request-lifecycle spans, the flight
//!   recorder, step-time attribution, the Prometheus exposition, and
//!   how to read `BENCH_serving.json`.
//! - `ROADMAP.md` / `PAPER.md` — project north star and source paper.

pub mod api;
pub mod baselines;
pub mod batching;
pub mod bench_support;
pub mod config;
pub mod core;
pub mod dataflow;
pub mod engine;
pub mod error;
pub mod fleet;
pub mod gemm;
pub mod hwmodel;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod policy;
pub mod prefixcache;
pub mod router;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod simengine;
pub mod simtest;
pub mod softmaxstats;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
