//! FlashDecoding++ — a reproduction of "FlashDecoding++: Faster Large
//! Language Model Inference on GPUs" (Hong et al., 2023) as a three-layer
//! Rust + JAX + Pallas inference engine.
//!
//! Layer 1 (Pallas, build-time Python) implements the paper's kernels:
//! the asynchronized softmax with unified max value (§3) and the flat
//! GEMM with pad-to-8 / double buffering (§4). Layer 2 (JAX) is a
//! Llama-style transformer lowered AOT to HLO text. Layer 3 — this crate —
//! owns everything on the request path: the PJRT runtime, the KV cache,
//! continuous batching, the prefill/decode scheduler, the heuristic
//! dataflow dispatch (§5), the serving loop, and the analytic GPU model
//! that regenerates the paper's figures.
//!
//! Python never runs at serving time; `make artifacts` is the only
//! compile-path entry.

pub mod baselines;
pub mod batching;
pub mod bench_support;
pub mod config;
pub mod dataflow;
pub mod engine;
pub mod error;
pub mod gemm;
pub mod hwmodel;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod router;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod softmaxstats;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
