//! fdpp — FlashDecoding++ engine CLI (leader entrypoint).
//!
//! Subcommands:
//!   serve             JSON-lines TCP API
//!   generate          one-off generation
//!   profile-dataflow  §5 decision flow on the real CPU microkernels
//!   simulate          analytic GPU engine comparison (hwmodel)
//!   inspect           list artifacts + model metadata

use fdpp::api::InferenceEngine;
use fdpp::baselines::{EngineKind, EngineModel};
use fdpp::bench_support::{banner, fmt_speedup, fmt_time, row};
use fdpp::config::{paper_model, paper_models, EngineConfig};
use fdpp::dataflow::profile::build_lookup_table;
use fdpp::engine::Engine;
use fdpp::error::Result;
use fdpp::hwmodel;
use fdpp::runtime::Runtime;
use fdpp::sampling::SamplingParams;
use fdpp::util::cli::Args;

const USAGE: &str = "usage: fdpp [--artifacts DIR] <serve|generate|profile-dataflow|simulate|inspect> [flags]
  serve             --addr HOST:PORT  --sync-softmax
  generate          --prompt TEXT  --max-new-tokens N  --temperature T  --top-k K
  profile-dataflow  --out FILE  --reps N
  simulate          --gpu a100|rtx3090|mi210|rx7900xtx  --model NAME  --batch N  --kv-len N
  inspect";

fn gpu_by_name(name: &str) -> hwmodel::GpuProfile {
    match name.to_lowercase().as_str() {
        "a100" => hwmodel::a100(),
        "rtx3090" => hwmodel::rtx3090(),
        "mi210" => hwmodel::mi210(),
        "rx7900xtx" => hwmodel::rx7900xtx(),
        other => {
            eprintln!("unknown gpu {other}, using a100");
            hwmodel::a100()
        }
    }
}

fn main() {
    fdpp::util::log::init();
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let artifacts = args.str_or("artifacts", "artifacts");
    match args.subcommand.as_deref() {
        Some("serve") => {
            let cfg = EngineConfig {
                artifacts_dir: artifacts.clone(),
                async_softmax: !args.bool_flag("sync-softmax"),
                ..EngineConfig::default()
            };
            let addr = args.str_or("addr", "127.0.0.1:7331");
            fdpp::server::serve(&addr, &artifacts, cfg)
        }
        Some("generate") => {
            let prompt = args.required("prompt")?;
            let max_new = args.usize_or("max-new-tokens", 32)?;
            let temperature = args.f32_or("temperature", 0.0)?;
            let top_k = args.usize_or("top-k", 0)?;
            let rt = Runtime::load(&artifacts)?;
            let mut engine = Engine::new(rt, EngineConfig::default())?;
            engine.warmup()?;
            let t0 = std::time::Instant::now();
            let text = engine.generate_text(
                &prompt,
                max_new,
                SamplingParams {
                    temperature,
                    top_k,
                },
            )?;
            let dt = t0.elapsed();
            println!("{text}");
            eprintln!(
                "[{} tokens in {:.2?}; {:.1} tok/s; recompute rate {:.4}]",
                engine.metrics.tokens_generated,
                dt,
                engine.metrics.tokens_generated as f64 / dt.as_secs_f64(),
                engine.metrics.recompute_rate(),
            );
            Ok(())
        }
        Some("profile-dataflow") => {
            let out = args.str_or("out", "artifacts/lookup_table.json");
            let reps = args.usize_or("reps", 5)?;
            let mut rt = Runtime::load(&artifacts)?;
            let table = build_lookup_table(&mut rt, reps)?;
            banner("§5", "heuristic dataflow lookup table (real CPU profile)");
            row("op [N,K]", &["M1".into(), "M2".into()]);
            for e in &table.entries {
                row(
                    &format!("{} [{},{}]", e.op, e.n, e.k),
                    &[e.m1.to_string(), e.m2.to_string()],
                );
            }
            table.save_json(&out)?;
            println!("wrote {out}");
            Ok(())
        }
        Some("simulate") => {
            let gpu = gpu_by_name(&args.str_or("gpu", "a100"));
            let model = paper_model(&args.str_or("model", "llama2-7b"))?;
            let batch = args.usize_or("batch", 1)?;
            let kv_len = args.usize_or("kv-len", 1024)?;
            banner(
                "simulate",
                &format!("{} on {} (decode bs={batch} kv={kv_len})", model.name, gpu.name),
            );
            let hf = EngineModel::new(EngineKind::HuggingFace)
                .decode_token_time(&model, &gpu, batch, kv_len);
            row("engine", &["tok latency".into(), "vs HF".into()]);
            for kind in EngineKind::all() {
                if !kind.supports(&model) {
                    row(kind.as_str(), &["n/a".into(), "-".into()]);
                    continue;
                }
                let t = EngineModel::new(kind).decode_token_time(&model, &gpu, batch, kv_len);
                row(kind.as_str(), &[fmt_time(t), fmt_speedup(hf / t)]);
            }
            Ok(())
        }
        Some("inspect") => {
            let rt = Runtime::load(&artifacts)?;
            let m = &rt.manifest.model;
            println!(
                "model {} dim={} layers={} heads={} vocab={} max_seq={} phi={:.4}",
                m.name, m.dim, m.n_layers, m.n_heads, m.vocab_size, m.max_seq, m.phi
            );
            println!("paper models known to hwmodel:");
            for pm in paper_models() {
                println!(
                    "  {} dim={} layers={} ctx={} params={:.2}B",
                    pm.name,
                    pm.dim,
                    pm.n_layers,
                    pm.context,
                    pm.param_count() as f64 / 1e9
                );
            }
            println!("{} entries:", rt.manifest.entries.len());
            for e in &rt.manifest.entries {
                println!("  {} ({}, {} outputs)", e.name, e.kind, e.num_outputs);
            }
            Ok(())
        }
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}
