//! Simulation backend: the PJRT-free twin of [`crate::engine::Engine`].
//!
//! [`SimEngine`] is [`crate::core::EngineCore`] over [`SimBackend`] — a
//! deterministic hash model instead of compiled artifacts. The entire
//! serving loop (router, cache-aware scheduler, continuous batcher,
//! flow control, preemption, tracing, audit) is the shared core; this
//! module supplies only the compute: K/V columns that are pure
//! functions of `(token, position)`, and logits derived from a digest
//! of the KV bytes *actually stored in the paged cache*, so any
//! block-sharing bug (double free, COW miss, stale shared block)
//! changes generated tokens instead of passing silently.
//!
//! Because orchestration lives in the core, the sim twin *cannot* drift
//! from the real engine — the same struct runs both. This is what lets
//! `benches/prefix_reuse.rs`, the loopback server test, and the tier-1
//! tests measure prefix-cache hit rates and verify cached-vs-cold
//! output equality on a bare checkout, where the PJRT artifacts of the
//! real engine are unavailable.
//!
//! The sim runs on a manual [`Clock`], advancing [`SIM_STEP`] of
//! virtual time per engine step, so every latency and timeout decision
//! is a deterministic function of the scenario.

use std::time::Duration;

use crate::config::EngineConfig;
use crate::core::{Backend, DecodeGroup, DecodeRun, EngineCore, LaneInput, PrefillRun};
use crate::error::{Error, Result};
use crate::kvcache::{KvCache, KvGeometry, SeqId};
use crate::router::Sequence;
use crate::tokenizer::TOKENIZER_VOCAB;
use crate::util::clock::Clock;

// Re-exported for compatibility: these types moved to the shared core
// (the real engine records the same trace and audit surface now).
pub use crate::core::{EngineAudit, LiveSeq, TraceEvent};

/// Virtual time one engine step costs on the sim's manual clock. Every
/// latency the sim reports (and every idle-timeout decision) is a
/// deterministic multiple of this quantum.
pub const SIM_STEP: Duration = Duration::from_millis(1);

/// Hash-model geometry (kept tiny: the point is block accounting, not
/// FLOPs).
#[derive(Debug, Clone, Copy)]
pub struct SimSpec {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            vocab: TOKENIZER_VOCAB + 61, // a little headroom over specials
            max_seq: 256,
        }
    }
}

// ---------------------------------------------------------------------
// Hash model (shared with the differential-testing stub backend)
// ---------------------------------------------------------------------

/// splitmix64 finalizer — the model's only "weights".
pub(crate) fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Deterministic f32 in [-1, 1) from a hash.
pub(crate) fn hash_f32(x: u64) -> f32 {
    ((mix(x) >> 40) as f32) / (1u64 << 24) as f32 * 2.0 - 1.0
}

/// Seed of the logits digest.
pub(crate) const LOGITS_DIGEST_SEED: u64 = 0x5EED_CAFE;

/// K/V column for `(token, pos)` in [Lyr, H, Dh] layout.
pub(crate) fn sim_token_cols(geo: &KvGeometry, token: u32, pos: usize) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::new();
    let mut v = Vec::new();
    sim_token_cols_into(geo, token, pos, &mut k, &mut v);
    (k, v)
}

/// [`sim_token_cols`] into caller-owned buffers (cleared first), so
/// the decode hot path stages columns without allocating.
pub(crate) fn sim_token_cols_into(
    geo: &KvGeometry,
    token: u32,
    pos: usize,
    k: &mut Vec<f32>,
    v: &mut Vec<f32>,
) {
    let te = geo.token_elems();
    k.clear();
    v.clear();
    k.reserve(te);
    v.reserve(te);
    let base = ((token as u64) << 32) ^ ((pos as u64) << 8);
    for e in 0..te {
        k.push(hash_f32(base ^ ((e as u64) << 1)));
        v.push(hash_f32(base ^ ((e as u64) << 1) ^ 1));
    }
}

/// Prefill K/V for a whole prompt in [Lyr, 1, H, S, Dh] layout
/// (S = prompt length, unpadded).
pub(crate) fn sim_prefill_kv(geo: &KvGeometry, tokens: &[u32]) -> (Vec<f32>, Vec<f32>) {
    let s = tokens.len();
    let n = geo.n_layers * geo.n_heads * s * geo.head_dim;
    let mut k = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    for (t, &tok) in tokens.iter().enumerate() {
        let (kc, vc) = sim_token_cols(geo, tok, t);
        for l in 0..geo.n_layers {
            for h in 0..geo.n_heads {
                let src = (l * geo.n_heads + h) * geo.head_dim;
                let dst = ((l * geo.n_heads + h) * s + t) * geo.head_dim;
                k[dst..dst + geo.head_dim].copy_from_slice(&kc[src..src + geo.head_dim]);
                v[dst..dst + geo.head_dim].copy_from_slice(&vc[src..src + geo.head_dim]);
            }
        }
    }
    (k, v)
}

/// The tokens a retired sim-path sequence may publish to the prefix
/// cache: prompt + generated, truncated to what is actually stored.
/// Shared by [`SimBackend`] and the differential-testing stub — the
/// publication rule must be one definition, or the lockstep-equality
/// invariant could be broken by editing a single copy.
pub(crate) fn sim_publishable_tokens(kv: &KvCache, seq: &Sequence) -> Vec<u32> {
    let Some(kv_len) = kv.seq_len(seq.id) else {
        return Vec::new();
    };
    let mut toks: Vec<u32> = Vec::with_capacity(kv_len);
    toks.extend_from_slice(&seq.prompt);
    for &g in &seq.generated {
        if toks.len() >= kv_len {
            break;
        }
        toks.push(g);
    }
    toks.truncate(kv_len);
    toks
}

/// Fold the KV bytes stored for `id` at positions `start..end` into a
/// running digest (strictly left-to-right, K column then V column per
/// position). Because the fold is positional and reads *stored* bytes,
/// two sequences that physically share their prefix blocks produce the
/// identical digest over the prefix range — which is what lets the
/// grouped decode path compute it once per group. This is the sim's
/// stand-in for an attention partial: order-free to merge across the
/// prefix/suffix split the same way the paper's unified-max softmax
/// ([`crate::softmaxstats::softmax_unified`]) makes real partials
/// mergeable without a synchronization pass.
/// `kcol`/`vcol` are caller-owned staging for the per-position
/// read-back (resized in place, so a persistent caller buffer makes
/// the fold allocation-free).
fn fold_kv_digest(
    kv: &KvCache,
    id: SeqId,
    start: usize,
    end: usize,
    seed: u64,
    kcol: &mut Vec<f32>,
    vcol: &mut Vec<f32>,
) -> Result<u64> {
    let te = kv.geometry().token_elems();
    kcol.clear();
    vcol.clear();
    kcol.resize(te, 0.0);
    vcol.resize(te, 0.0);
    let mut digest = seed;
    for pos in start..end {
        kv.read_token(id, pos, kcol, vcol)?;
        for f in kcol.iter().chain(vcol.iter()) {
            digest = mix(digest ^ f.to_bits() as u64);
        }
    }
    Ok(digest)
}

/// Expand a finished KV digest into a logits row, mixed with the
/// current input token.
fn logits_from_digest(digest: u64, vocab: usize, cur_tok: u32) -> Vec<f32> {
    let mut out = Vec::new();
    logits_from_digest_into(digest, vocab, cur_tok, &mut out);
    out
}

/// [`logits_from_digest`] appended onto a caller-owned flat buffer —
/// the decode hot path writes every lane's row into one backing
/// allocation ([`DecodeRun`]'s layout) without a per-row collect.
fn logits_from_digest_into(digest: u64, vocab: usize, cur_tok: u32, out: &mut Vec<f32>) {
    let d = mix(digest ^ ((cur_tok as u64) << 32));
    out.reserve(vocab);
    for c in 0..vocab {
        out.push(hash_f32(d ^ c as u64));
    }
}

/// Logits for a sequence: a digest over the KV bytes *stored in the
/// paged cache* (so shared-block corruption is observable), mixed with
/// the current input token. Allocates its own staging — prefill-path
/// convenience; decode goes through the scratch-buffer fold directly.
fn logits_from_cache(kv: &KvCache, vocab: usize, id: SeqId, cur_tok: u32) -> Result<Vec<f32>> {
    let len = kv
        .seq_len(id)
        .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
    let (mut kcol, mut vcol) = (Vec::new(), Vec::new());
    let digest = fold_kv_digest(kv, id, 0, len, LOGITS_DIGEST_SEED, &mut kcol, &mut vcol)?;
    Ok(logits_from_digest(digest, vocab, cur_tok))
}

// ---------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------

/// Reused compute buffers: K/V column staging, digest read-back, and
/// the recycled [`DecodeRun`] output (`logits`/`offsets` come back via
/// [`Backend::recycle_run`]), so steady-state sim decode performs zero
/// heap allocations per token. Capacities ratchet up to the largest
/// batch seen and stay there.
#[derive(Debug, Default)]
struct SimScratch {
    kcol: Vec<f32>,
    vcol: Vec<f32>,
    logits: Vec<f32>,
    offsets: Vec<usize>,
}

/// The deterministic hash-model compute backend.
pub struct SimBackend {
    spec: SimSpec,
    scratch: SimScratch,
}

impl SimBackend {
    pub fn new(spec: SimSpec) -> Self {
        SimBackend {
            spec,
            scratch: SimScratch::default(),
        }
    }

    pub fn spec(&self) -> SimSpec {
        self.spec
    }
}

impl Backend for SimBackend {
    type PrefillArtifact = ();

    fn geometry(&self, cfg: &EngineConfig) -> KvGeometry {
        KvGeometry {
            n_layers: self.spec.n_layers,
            n_heads: self.spec.n_heads,
            head_dim: self.spec.head_dim,
            block_tokens: cfg.kv_block_tokens,
            max_seq: self.spec.max_seq,
        }
    }

    fn vocab(&self) -> usize {
        self.spec.vocab
    }

    /// The prompt (+1 generated token) must fit the sim's `max_seq`.
    fn validate_prompt(&self, _cfg: &EngineConfig, prompt_len: usize) -> Result<()> {
        if prompt_len + 1 > self.spec.max_seq {
            return Err(Error::Request(format!(
                "prompt of {prompt_len} tokens exceeds sim max_seq {}",
                self.spec.max_seq
            )));
        }
        Ok(())
    }

    /// Virtual time advances one [`SIM_STEP`] per step, whatever the
    /// action — idle time is time too (it is what the idle timeout
    /// measures).
    fn on_step_start(&mut self, clock: &Clock) {
        clock.advance(SIM_STEP);
    }

    /// "Compute" and store the uncached prompt suffix, then derive the
    /// last position's logits from the stored bytes.
    fn prefill(
        &mut self,
        _cfg: &EngineConfig,
        kv: &mut KvCache,
        seq: &Sequence,
        matched_tokens: usize,
        _clock: &Clock,
    ) -> Result<PrefillRun<()>> {
        let len = seq.prompt.len();
        let geo = kv.geometry();
        let (k, v) = sim_prefill_kv(&geo, &seq.prompt);
        kv.write_prefill_range(seq.id, &k, &v, len, matched_tokens, len)?;
        let logits = logits_from_cache(kv, self.spec.vocab, seq.id, *seq.prompt.last().unwrap())?;
        Ok(PrefillRun {
            last_logits: logits,
            exec_time: Duration::ZERO,
            artifact: (),
        })
    }

    /// Per lane: append the input token's KV (COW protects shared
    /// tails), then read logits over the stored sequence.
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        _cfg: &EngineConfig,
        kv: &mut KvCache,
        _seqs: &std::collections::HashMap<SeqId, Sequence>,
        _batch: &crate::batching::DecodeBatch,
        inputs: &[LaneInput],
        _metrics: &mut crate::metrics::EngineMetrics,
        _clock: &Clock,
    ) -> Result<DecodeRun> {
        let geo = kv.geometry();
        // The output buffers are the ones the core handed back through
        // `recycle_run` after the previous step; staging columns are
        // reused for both the token write and the digest read-back.
        let mut logits = std::mem::take(&mut self.scratch.logits);
        let mut offsets = std::mem::take(&mut self.scratch.offsets);
        logits.clear();
        offsets.clear();
        for inp in inputs {
            kv.grow_one(inp.id)?;
            sim_token_cols_into(
                &geo,
                inp.token,
                inp.pos,
                &mut self.scratch.kcol,
                &mut self.scratch.vcol,
            );
            kv.write_token(inp.id, inp.pos, &self.scratch.kcol, &self.scratch.vcol)?;
            offsets.push(logits.len());
            let len = kv
                .seq_len(inp.id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {}", inp.id)))?;
            let digest = fold_kv_digest(
                kv,
                inp.id,
                0,
                len,
                LOGITS_DIGEST_SEED,
                &mut self.scratch.kcol,
                &mut self.scratch.vcol,
            )?;
            logits_from_digest_into(digest, self.spec.vocab, inp.token, &mut logits);
        }
        Ok(DecodeRun {
            logits,
            offsets,
            row_len: self.spec.vocab,
            exec_time: Duration::ZERO,
        })
    }

    /// Take the step's output buffers back for the next decode.
    fn recycle_run(&mut self, run: DecodeRun) {
        self.scratch.logits = run.logits;
        self.scratch.offsets = run.offsets;
    }

    /// Grouped decode with shared-prefix compute reuse — the sim twin
    /// of CoDec-style attention grouping. The per-position digest fold
    /// is the sim's attention partial, and because it runs strictly
    /// left-to-right over *stored* KV bytes, every member of a group
    /// (which physically shares its prefix blocks) produces the same
    /// partial over the prefix range. So the backend folds the prefix
    /// once per group and continues per member over its divergent
    /// suffix — exactly the prefix-partial + suffix-partial merge the
    /// unified-max softmax ([`crate::softmaxstats::softmax_unified`])
    /// enables on real hardware, where per-group partials merge without
    /// a synchronization pass.
    ///
    /// Byte-identity with [`Backend::decode`] holds because (1) all
    /// KV appends happen first, in input slice order — the same
    /// allocation/COW order the ungrouped path produces, since digest
    /// reads never allocate or mutate — and (2) shared physical blocks
    /// hold identical bytes for every sharer (COW isolates writers), so
    /// the group-shared prefix digest equals each member's own.
    #[allow(clippy::too_many_arguments)]
    fn decode_grouped(
        &mut self,
        _cfg: &EngineConfig,
        kv: &mut KvCache,
        _seqs: &std::collections::HashMap<SeqId, Sequence>,
        _batch: &crate::batching::DecodeBatch,
        inputs: &[LaneInput],
        groups: &[DecodeGroup],
        metrics: &mut crate::metrics::EngineMetrics,
        _clock: &Clock,
    ) -> Result<DecodeRun> {
        let geo = kv.geometry();
        let te = geo.token_elems() as u64;
        // Phase 1: append every input's KV, in input slice order.
        for inp in inputs {
            kv.grow_one(inp.id)?;
            sim_token_cols_into(
                &geo,
                inp.token,
                inp.pos,
                &mut self.scratch.kcol,
                &mut self.scratch.vcol,
            );
            kv.write_token(inp.id, inp.pos, &self.scratch.kcol, &self.scratch.vcol)?;
        }
        // Phase 2: one shared-prefix partial per group, extended per
        // member over its suffix; rows outside any group take the full
        // per-sequence fold.
        let mut rows: Vec<Option<Vec<f32>>> = vec![None; inputs.len()];
        for g in groups {
            let lead = inputs[g.members[0]].id;
            let shared = fold_kv_digest(
                kv,
                lead,
                0,
                g.prefix_tokens,
                LOGITS_DIGEST_SEED,
                &mut self.scratch.kcol,
                &mut self.scratch.vcol,
            )?;
            for &m in &g.members {
                let inp = &inputs[m];
                let d = fold_kv_digest(
                    kv,
                    inp.id,
                    g.prefix_tokens,
                    inp.pos + 1,
                    shared,
                    &mut self.scratch.kcol,
                    &mut self.scratch.vcol,
                )?;
                rows[m] = Some(logits_from_digest(d, self.spec.vocab, inp.token));
            }
            // Every member after the first skipped re-scoring the
            // shared prefix. FLOP/byte conventions are documented on
            // the metrics fields.
            let saved = (g.members.len() as u64 - 1) * g.prefix_tokens as u64;
            metrics.decode_attn_positions_saved += saved;
            metrics.decode_attn_flops_saved += saved * 4 * te;
            metrics.decode_attn_bytes_saved += saved * 8 * te;
        }
        let mut logits = Vec::with_capacity(inputs.len() * self.spec.vocab);
        let mut offsets = Vec::with_capacity(inputs.len());
        for (i, inp) in inputs.iter().enumerate() {
            offsets.push(logits.len());
            match rows[i].take() {
                Some(r) => logits.extend(r),
                None => logits.extend(logits_from_cache(kv, self.spec.vocab, inp.id, inp.token)?),
            }
        }
        Ok(DecodeRun {
            logits,
            offsets,
            row_len: self.spec.vocab,
            exec_time: Duration::ZERO,
        })
    }

    /// Unlike the real engine (whose generated KV may still be
    /// device-resident), the sim writes synchronously into the paged
    /// store, so prompt *and* generated tokens are publishable.
    fn publishable_tokens(&self, kv: &KvCache, seq: &Sequence) -> Vec<u32> {
        sim_publishable_tokens(kv, seq)
    }
}

/// The simulation engine: the shared serving core over the hash-model
/// backend.
pub type SimEngine = EngineCore<SimBackend>;

impl EngineCore<SimBackend> {
    /// Build a sim engine on its own fresh virtual clock.
    pub fn new(cfg: EngineConfig, spec: SimSpec) -> Result<Self> {
        Self::with_clock(cfg, spec, Clock::manual())
    }

    /// Build a sim engine sharing an externally owned clock (the
    /// simulation-test harness uses this to observe and steer virtual
    /// time).
    pub fn with_clock(cfg: EngineConfig, spec: SimSpec, clock: Clock) -> Result<Self> {
        EngineCore::with_backend(SimBackend::new(spec), cfg, clock)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{FinishReason, GenEvent, GenRequest, InferenceEngine, SubmissionHandle};
    use crate::sampling::SamplingParams;

    fn cfg(prefix_cache: bool) -> EngineConfig {
        EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 128,
            max_new_tokens: 16,
            prefix_cache,
            ..EngineConfig::default()
        }
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let mut a = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let mut b = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let pa = a
            .generate_text("determinism probe", 12, SamplingParams::default())
            .unwrap();
        let pb = b
            .generate_text("determinism probe", 12, SamplingParams::default())
            .unwrap();
        assert_eq!(pa, pb);
        assert!(a.metrics.tokens_generated >= 1);
        assert_eq!(a.metrics.tokens_generated, b.metrics.tokens_generated);
    }

    #[test]
    fn concurrent_requests_all_finish_with_usage() {
        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let mut handles = vec![];
        for p in ["alpha", "beta prompt", "gamma gamma gamma"] {
            let h = e.submit(GenRequest::text(p).max_new_tokens(10)).unwrap();
            handles.push((p, h));
        }
        e.run_to_completion().unwrap();
        for (p, h) in &handles {
            let (toks, fin) = h.drain();
            assert!(!toks.is_empty());
            let (_, usage) = fin.expect("finish event");
            assert_eq!(usage.generated_tokens, toks.len());
            // BOS + one id per byte.
            assert_eq!(usage.prompt_tokens, p.len() + 1);
            assert_eq!(
                usage.cached_prompt_tokens + usage.prefill_tokens,
                usage.prompt_tokens
            );
        }
        assert_eq!(e.metrics.requests_finished, 3);
        assert_eq!(e.kv_free_blocks() + e.prefix_cached_blocks(), 128);
    }

    #[test]
    fn repeated_prompt_hits_prefix_cache_with_identical_output() {
        // 32-char prompt -> 33 tokens with BOS -> 4 full blocks of 8.
        let prompt = "system: you are a helpful tool"; // 30 chars + BOS = 31
        let prompt = format!("{prompt}!!"); // 33 tokens with BOS

        let mut warm = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let first = warm
            .generate_text(&prompt, 8, SamplingParams::default())
            .unwrap();
        assert_eq!(warm.metrics.prefix_hits, 0, "cold first request");
        let second = warm
            .generate_text(&prompt, 8, SamplingParams::default())
            .unwrap();
        assert_eq!(warm.metrics.prefix_hits, 1, "second request must hit");
        assert!(warm.metrics.prefix_tokens_reused >= 32);
        assert_eq!(first, second, "cache hit must not change output");

        // And identical to a cache-disabled engine.
        let mut cold = SimEngine::new(cfg(false), SimSpec::default()).unwrap();
        let base = cold
            .generate_text(&prompt, 8, SamplingParams::default())
            .unwrap();
        let base2 = cold
            .generate_text(&prompt, 8, SamplingParams::default())
            .unwrap();
        assert_eq!(first, base);
        assert_eq!(second, base2);
        assert_eq!(cold.metrics.prefix_lookups, 0);
    }

    #[test]
    fn grouped_decode_outputs_byte_identical_with_measured_savings() {
        // A warmup request caches a 4-block shared prefix; a wave of
        // four requests over it then decodes concurrently on shared
        // physical blocks, so the grouped path has real groups to
        // reuse. Outputs must be byte-identical with grouping on or
        // off, and only the grouped run may report saved positions.
        let shared = "system: you are a helpful tool!!"; // 33 tokens with BOS
        let run = |grouped: bool| {
            let mut e = SimEngine::new(
                EngineConfig {
                    grouped_decode: grouped,
                    ..cfg(true)
                },
                SimSpec::default(),
            )
            .unwrap();
            let w = e.submit(GenRequest::text(shared).max_new_tokens(2)).unwrap();
            e.run_to_completion().unwrap();
            let _ = w.drain();
            let handles: Vec<_> = (0..4)
                .map(|i| {
                    e.submit(GenRequest::text(format!("{shared} user {i}")).max_new_tokens(8))
                        .unwrap()
                })
                .collect();
            e.run_to_completion().unwrap();
            let outs: Vec<Vec<u32>> = handles.iter().map(|h| h.drain().0).collect();
            (
                outs,
                e.metrics.decode_attn_positions_saved,
                e.metrics.decode_attn_positions_total,
                e.metrics.grouped_groups_formed,
            )
        };
        let (base, saved_off, total_off, groups_off) = run(false);
        let (out, saved_on, total_on, groups_on) = run(true);
        assert_eq!(base, out, "grouping must not change any output");
        assert_eq!(saved_off, 0, "ungrouped run reuses nothing");
        assert_eq!(groups_off, 0, "formation is gated on the flag");
        assert_eq!(total_off, total_on, "same logical attention span");
        assert!(groups_on > 0, "the shared-prefix wave must form groups");
        assert!(saved_on > 0, "groups must yield measured savings");
        assert!(saved_on < total_on, "savings stay below the total span");
    }

    #[test]
    fn eviction_reclaims_cached_blocks_under_pressure() {
        // Tiny pool: the cache must give blocks back for new prompts.
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 10,
            max_new_tokens: 4,
            prefix_cache: true,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        for i in 0..6 {
            let prompt = format!("tenant-{i} prompt padded to some length....");
            let _h = e.submit(GenRequest::text(&prompt).max_new_tokens(3)).unwrap();
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 6);
        assert!(
            e.metrics.prefix_blocks_evicted > 0,
            "pool of 10 blocks cannot cache 6 distinct prompts without evicting"
        );
        assert_eq!(e.kv_free_blocks() + e.prefix_cached_blocks(), 10);
    }

    /// Find a prompt whose greedy generation runs at least `min_tokens`
    /// under the given budget — optionally requiring a printable-ASCII
    /// token in the output — and return it with that output. The hash
    /// model is deterministic, so this is a stable selection, not a
    /// retry loop.
    fn probe_prompt(min_tokens: usize, budget: usize, need_ascii: bool) -> (String, Vec<u32>) {
        for salt in 0..64u32 {
            let prompt = format!("generation probe {salt}");
            let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
            let h = e
                .submit(GenRequest::text(&prompt).max_new_tokens(budget))
                .unwrap();
            e.run_to_completion().unwrap();
            let (toks, _) = h.drain();
            let ascii_ok = !need_ascii || toks.iter().any(|t| (32..127).contains(t));
            if toks.len() >= min_tokens && ascii_ok {
                return (prompt, toks);
            }
        }
        panic!("no candidate prompt generated {min_tokens}+ tokens");
    }

    #[test]
    fn cancel_mid_decode_returns_kv_blocks_and_reports_cancelled() {
        // Prefix cache off so every block must return to the free list.
        let total = 128;
        let (prompt, _) = probe_prompt(6, 64, false);
        let mut e = SimEngine::new(cfg(false), SimSpec::default()).unwrap();
        let h = e.submit(GenRequest::text(&prompt).max_new_tokens(64)).unwrap();
        // Step until the request is decoding with a few tokens out.
        let mut tokens_seen = 0;
        let mut events = Vec::new();
        while tokens_seen < 4 {
            assert!(!e.is_idle(), "request finished before cancellation");
            e.step().unwrap();
            while let Ok(ev) = h.events.try_recv() {
                if matches!(ev, GenEvent::Token(_)) {
                    tokens_seen += 1;
                }
                events.push(ev);
            }
        }
        assert_eq!(e.running(), 1, "must be mid-decode");
        assert!(e.cancel(h.id).unwrap(), "known id cancels");
        assert!(!e.cancel(h.id).unwrap(), "second cancel is a no-op");
        assert!(e.is_idle(), "cancelled request leaves no work behind");
        while let Ok(ev) = h.events.try_recv() {
            events.push(ev);
        }
        let fin = events
            .iter()
            .find_map(|ev| match ev {
                GenEvent::Finished { reason, usage } => Some((*reason, *usage)),
                _ => None,
            })
            .expect("cancel must emit a finish event");
        assert_eq!(fin.0, FinishReason::Cancelled);
        assert_eq!(fin.1.generated_tokens, tokens_seen);
        assert_eq!(e.metrics.cancellations, 1);
        assert_eq!(
            e.kv_free_blocks(),
            total,
            "every KV block must return on cancel (cache off)"
        );
    }

    #[test]
    fn impossible_requests_rejected_at_submit() {
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 4, // 32-token pool
            max_new_tokens: 4,
            prefix_cache: true,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        let long = "x".repeat(40); // 41 tokens with BOS: exceeds the pool
        assert!(e.submit(GenRequest::text(long).max_new_tokens(4)).is_err());
        assert!(
            e.submit(GenRequest::text("ok").max_new_tokens(0)).is_err(),
            "zero budget must be rejected"
        );
        assert!(e.is_idle(), "rejected requests leave no queued work");
    }

    #[test]
    fn cancel_queued_request_before_admission() {
        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let h = e.submit(GenRequest::text("never admitted").max_new_tokens(8)).unwrap();
        assert_eq!(e.queued(), 1);
        assert!(e.cancel(h.id).unwrap());
        assert_eq!(e.queued(), 0);
        let (toks, fin) = h.drain();
        assert!(toks.is_empty());
        assert_eq!(fin.unwrap().0, FinishReason::Cancelled);
        assert_eq!(e.kv_free_blocks() + e.prefix_cached_blocks(), 128);
    }

    #[test]
    fn stop_sequence_halts_generation() {
        // Self-selecting stop: take an unconstrained run, pick a
        // generated ASCII byte, and require a fresh engine to stop on
        // exactly that byte with a byte-identical prefix.
        let (prompt, full) = probe_prompt(2, 16, true);
        let (idx, stop_tok) = full
            .iter()
            .enumerate()
            .find(|(_, &t)| (32..127).contains(&t))
            .expect("hash model must emit some printable ASCII byte");
        let stop_str = String::from_utf8(vec![*stop_tok as u8]).unwrap();

        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let req = GenRequest::text(&prompt)
            .max_new_tokens(16)
            .stop(vec![stop_str]);
        let h = e.submit(req).unwrap();
        e.run_to_completion().unwrap();
        let (toks, fin) = h.drain();
        let (reason, usage) = fin.unwrap();
        assert_eq!(reason, FinishReason::Stop);
        assert_eq!(toks.len(), idx + 1, "stops right at the matched token");
        assert_eq!(toks[..], full[..idx + 1], "prefix must be byte-identical");
        assert_eq!(usage.generated_tokens, idx + 1);
    }

    #[test]
    fn higher_priority_request_admitted_first() {
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 128,
            max_new_tokens: 16,
            max_running: 1,
            decode_buckets: vec![1],
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        let low = e
            .submit(GenRequest::text("low priority waits").max_new_tokens(4))
            .unwrap();
        let high = e
            .submit(
                GenRequest::text("high priority runs")
                    .priority(5)
                    .max_new_tokens(4),
            )
            .unwrap();
        e.step().unwrap(); // one prefill: must pick the high-priority one
        let (high_toks, _) = high.drain();
        let (low_toks, _) = low.drain();
        assert_eq!(high_toks.len(), 1, "high-priority got the first prefill");
        assert!(low_toks.is_empty(), "low-priority still queued");
        assert_eq!(e.queued(), 1);
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 2);
    }

    #[test]
    fn pause_decode_parks_slow_consumer_and_resumes_losslessly() {
        // Reference: same prompt, roomy stream (no backpressure).
        let (prompt, want) = probe_prompt(10, 16, false);

        let mut e = SimEngine::new(
            EngineConfig {
                stream_capacity: 3,
                backpressure: crate::config::BackpressurePolicy::PauseDecode,
                ..cfg(true)
            },
            SimSpec::default(),
        )
        .unwrap();
        let h = e.submit(GenRequest::text(&prompt).max_new_tokens(16)).unwrap();
        assert_eq!(h.capacity(), 3);
        // Never drain: the stream fills at exactly the capacity and the
        // sequence parks instead of buffering more.
        for _ in 0..20 {
            e.step().unwrap();
        }
        assert_eq!(e.paused(), 1, "slow consumer must be parked");
        assert_eq!(e.running(), 0);
        assert!(e.metrics.backpressure_pauses >= 1);
        assert_eq!(h.events.buffered(), 3, "bounded at the configured capacity");
        assert!(!e.is_idle(), "a paused request is still pending work");

        // Drain while stepping: the sequence resumes and completes with
        // the exact token stream of the unpressured run (greedy = no
        // sampler-order sensitivity; backpressure must be lossless).
        let mut got = Vec::new();
        let mut fin = None;
        let mut steps = 0;
        while fin.is_none() {
            e.step().unwrap();
            let (mut t, f) = h.drain();
            got.append(&mut t);
            if f.is_some() {
                fin = f;
            }
            steps += 1;
            assert!(steps < 10_000, "must terminate once the client drains");
        }
        assert!(e.metrics.backpressure_resumes >= 1);
        assert_eq!(got, want, "pause/resume must not lose or reorder tokens");
        assert!(e.is_idle());
    }

    #[test]
    fn drop_slow_finishes_with_overrun_and_reclaims_kv() {
        let (prompt, _) = probe_prompt(6, 16, false);
        let total = 128;
        let mut e = SimEngine::new(
            EngineConfig {
                stream_capacity: 2,
                backpressure: crate::config::BackpressurePolicy::DropSlow,
                ..cfg(false)
            },
            SimSpec::default(),
        )
        .unwrap();
        let h = e.submit(GenRequest::text(&prompt).max_new_tokens(16)).unwrap();
        // Never drain; DropSlow terminates the request, so completion
        // does not need the client's cooperation.
        e.run_to_completion().unwrap();
        let (toks, fin) = h.drain();
        let (reason, usage) = fin.expect("overrun still delivers the finish event");
        assert_eq!(reason, FinishReason::Overrun);
        assert_eq!(toks.len(), 2, "exactly the buffered tokens survive");
        assert_eq!(usage.generated_tokens, 2, "generation halted at the overrun");
        assert_eq!(e.metrics.backpressure_drops, 1);
        assert_eq!(e.kv_free_blocks(), total, "overrun reclaims KV (cache off)");
        assert!(e.is_idle());
    }

    #[test]
    fn dropped_handle_reclaims_request() {
        let (prompt, _) = probe_prompt(6, 16, false);
        let mut e = SimEngine::new(cfg(false), SimSpec::default()).unwrap();
        let h = e.submit(GenRequest::text(&prompt).max_new_tokens(16)).unwrap();
        e.step().unwrap(); // prefill
        assert_eq!(e.running(), 1);
        drop(h); // client goes away without cancelling
        e.step().unwrap(); // stream scan reaps the disconnect
        assert!(e.is_idle(), "disconnected client's work is reclaimed");
        assert_eq!(e.metrics.client_disconnects, 1);
        assert_eq!(e.kv_free_blocks(), 128);
    }

    #[test]
    fn stalled_stream_never_delays_other_requests() {
        let (slow_prompt, _) = probe_prompt(10, 16, false);
        let mut e = SimEngine::new(
            EngineConfig {
                stream_capacity: 2,
                backpressure: crate::config::BackpressurePolicy::PauseDecode,
                ..cfg(true)
            },
            SimSpec::default(),
        )
        .unwrap();
        let slow = e
            .submit(GenRequest::text(&slow_prompt).max_new_tokens(16))
            .unwrap();
        let fast = e
            .submit(GenRequest::text("fast concurrent stream").max_new_tokens(12))
            .unwrap();
        // Drain only the fast handle each step.
        let mut fast_tokens = Vec::new();
        let mut fast_fin = None;
        let mut steps = 0;
        while fast_fin.is_none() {
            e.step().unwrap();
            let (mut t, f) = fast.drain();
            fast_tokens.append(&mut t);
            if f.is_some() {
                fast_fin = f;
            }
            steps += 1;
            assert!(
                steps < 200,
                "fast stream must finish promptly while the slow one stalls"
            );
        }
        assert!(!fast_tokens.is_empty());
        // The slow request parks once its 2-slot buffer fills (it may
        // still be mid-fill if the fast stream finished very early).
        let mut extra = 0;
        while e.paused() == 0 && extra < 50 {
            e.step().unwrap();
            extra += 1;
        }
        assert_eq!(e.paused(), 1, "slow request parked, not finished");
        assert!(slow.events.buffered() <= 2, "slow buffer stays bounded");
        // Admin-style cleanup: cancelling the paused request works.
        assert!(e.cancel(slow.id).unwrap());
        assert!(e.is_idle());
        let (_, fin) = slow.drain();
        assert_eq!(fin.unwrap().0, FinishReason::Cancelled);
    }

    /// Serving knobs for the tiny-pool preemption tests: 6 KV blocks of
    /// 4 tokens, 2-token stream buffers, PauseDecode.
    fn tiny_pool_cfg() -> EngineConfig {
        EngineConfig {
            kv_block_tokens: 4,
            kv_total_blocks: 6,
            max_new_tokens: 12,
            max_running: 4,
            decode_buckets: vec![1, 2, 4],
            prefix_cache: false,
            stream_capacity: 2,
            backpressure: crate::config::BackpressurePolicy::PauseDecode,
            ..EngineConfig::default()
        }
    }

    /// A 7-char prompt (8 tokens with BOS = 3 blocks of 4) whose first
    /// generated tokens don't hit EOS (deterministic probe on a roomy
    /// pool), so a request over it reliably survives to parking.
    fn probe7(tag: u32) -> String {
        for salt in 0..512u32 {
            let p = format!("p{tag}x{salt:04}");
            assert_eq!(p.len(), 7);
            let mut e = SimEngine::new(
                EngineConfig {
                    kv_total_blocks: 64,
                    stream_capacity: 64,
                    ..tiny_pool_cfg()
                },
                SimSpec::default(),
            )
            .unwrap();
            let h = e.submit(GenRequest::text(&p).max_new_tokens(4)).unwrap();
            e.run_to_completion().unwrap();
            if h.drain().0.len() == 4 {
                return p;
            }
        }
        panic!("no probe prompt survives 4 tokens");
    }

    /// Submit a low-priority request over a probed prompt and step until
    /// its 2-slot stream fills and it parks (holding 3 KV blocks).
    fn park_slow(e: &mut SimEngine) -> SubmissionHandle {
        let h = e
            .submit(GenRequest::text(probe7(0)).priority(0).max_new_tokens(12))
            .unwrap();
        for _ in 0..6 {
            e.step().unwrap();
        }
        assert_eq!(e.paused(), 1, "slow request parked");
        h
    }

    #[test]
    fn paused_victim_preempted_under_kv_pressure() {
        // A parked slow client must not be able to wedge live work: its
        // KV is part of the preemption victim pool.
        let mut e = SimEngine::new(tiny_pool_cfg(), SimSpec::default()).unwrap();
        // Slow, low-priority request: admit, then park (never drained;
        // 2-token stream fills after one decode step). Holds 3 blocks.
        let slow = park_slow(&mut e);
        // High-priority request: admission takes the 3 free blocks, and
        // its first decode step needs headroom the parked request
        // holds — the parked, lower-priority sequence is the victim.
        let fast = e
            .submit(GenRequest::text(probe7(1)).priority(3).max_new_tokens(12))
            .unwrap();
        let mut fast_fin = None;
        let mut steps = 0;
        while fast_fin.is_none() {
            if !e.is_idle() {
                e.step().unwrap();
            }
            let (_, f) = fast.drain();
            if f.is_some() {
                fast_fin = f;
            }
            steps += 1;
            assert!(steps < 1_000, "fast request must complete");
        }
        assert_ne!(
            fast_fin.unwrap().0,
            FinishReason::Preempted,
            "high-priority request survives"
        );
        assert!(e.metrics.preemptions >= 1, "pressure forced a preemption");
        let (_, slow_fin) = slow.drain();
        assert_eq!(
            slow_fin.unwrap().0,
            FinishReason::Preempted,
            "the parked lower-priority request is the victim"
        );
        assert!(e.is_idle());
        assert_eq!(e.kv_free_blocks(), 6, "all blocks return (cache off)");
    }

    #[test]
    fn admission_blocked_by_parked_kv_preempts_strictly_lower_priority() {
        // Pool of 6 blocks (4 tokens each). A parked priority-0 request
        // holds 3; a priority-3 submission needs 4 (15 tokens + 1), so
        // admission is blocked with nothing decoding. The admission
        // path must preempt the parked victim rather than starve the
        // higher-priority waiter.
        let mut e = SimEngine::new(tiny_pool_cfg(), SimSpec::default()).unwrap();
        let slow = park_slow(&mut e);
        let big = e
            .submit(
                GenRequest::text("waiting-high!!") // 15 tokens w/ BOS
                    .priority(3)
                    .max_new_tokens(4),
            )
            .unwrap();
        let mut fin = None;
        let mut steps = 0;
        while fin.is_none() {
            if !e.is_idle() {
                e.step().unwrap();
            }
            let (_, f) = big.drain();
            if f.is_some() {
                fin = f;
            }
            steps += 1;
            assert!(steps < 1_000, "waiter must not starve behind parked KV");
        }
        assert_ne!(fin.unwrap().0, FinishReason::Preempted);
        assert_eq!(e.metrics.preemptions, 1, "parked victim preempted");
        assert_eq!(slow.drain().1.unwrap().0, FinishReason::Preempted);

        // Equal priority: parked work keeps its KV; the waiter queues.
        let mut e = SimEngine::new(tiny_pool_cfg(), SimSpec::default()).unwrap();
        let _slow = park_slow(&mut e);
        let _big = e
            .submit(
                GenRequest::text("waiting-same!!")
                    .priority(0)
                    .max_new_tokens(4),
            )
            .unwrap();
        for _ in 0..30 {
            e.step().unwrap();
        }
        assert_eq!(e.paused(), 1, "equal-priority parked work survives");
        assert_eq!(e.queued(), 1, "waiter stays queued");
        assert_eq!(e.metrics.preemptions, 0);
    }

    #[test]
    fn per_tenant_usage_recorded() {
        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let shared = "tenant system prompt shared across requests!";
        for i in 0..2 {
            let req = GenRequest::text(format!("{shared} {i}"))
                .tenant("acme")
                .max_new_tokens(4);
            let _h = e.submit(req).unwrap();
            e.run_to_completion().unwrap();
        }
        let _h = e
            .submit(GenRequest::text("unrelated").max_new_tokens(4))
            .unwrap();
        e.run_to_completion().unwrap();
        let acme = e.metrics.tenants.get("acme").expect("tenant recorded");
        assert_eq!(acme.requests_finished, 2);
        assert!(acme.generated_tokens >= 2);
        assert!(
            acme.cached_prompt_tokens >= 8,
            "second acme request reuses the shared prefix: {acme:?}"
        );
        let default = e.metrics.tenants.get("default").expect("default tenant");
        assert_eq!(default.requests_finished, 1);
        assert_eq!(default.cached_prompt_tokens, 0);
    }
}
