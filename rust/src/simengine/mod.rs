//! Simulation engine: the PJRT-free twin of [`crate::engine::Engine`].
//!
//! Runs the *entire* serving stack — router, cache-aware scheduler,
//! continuous batcher, paged KV cache with block sharing, radix-tree
//! prefix cache, sampler, metrics — against a deterministic hash model
//! instead of compiled artifacts. The hash model writes K/V columns that
//! are pure functions of `(token, position)` and derives logits from a
//! digest of the KV bytes *actually stored in the paged cache*, so any
//! block-sharing bug (double free, COW miss, stale shared block)
//! changes generated tokens instead of passing silently.
//!
//! This is what lets `benches/prefix_reuse.rs` and the tier-1 tests
//! measure prefix-cache hit rates and verify cached-vs-cold output
//! equality on a bare checkout, where the PJRT artifacts of the real
//! engine are unavailable.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

use crate::batching::Batcher;
use crate::config::EngineConfig;
use crate::error::{Error, Result};
use crate::kvcache::{KvCache, KvGeometry, SeqId};
use crate::metrics::EngineMetrics;
use crate::prefixcache::{PrefixCache, PrefixMatch};
use crate::router::{FinishReason, Request, Router, SeqState, Sequence, TokenEvent};
use crate::sampling::{Sampler, SamplingParams};
use crate::scheduler::{decide, preemption_victim, Action, PreemptCandidate, SchedState};
use crate::tokenizer::{ByteTokenizer, EOS, TOKENIZER_VOCAB};

/// Hash-model geometry (kept tiny: the point is block accounting, not
/// FLOPs).
#[derive(Debug, Clone, Copy)]
pub struct SimSpec {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            vocab: TOKENIZER_VOCAB + 61, // a little headroom over specials
            max_seq: 256,
        }
    }
}

/// splitmix64 finalizer — the model's only "weights".
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Deterministic f32 in [-1, 1) from a hash.
fn hash_f32(x: u64) -> f32 {
    ((mix(x) >> 40) as f32) / (1u64 << 24) as f32 * 2.0 - 1.0
}

/// The simulation engine. Same single-owner discipline as `Engine`.
pub struct SimEngine {
    pub cfg: EngineConfig,
    spec: SimSpec,
    kv: KvCache,
    prefix: PrefixCache,
    batcher: Batcher,
    router: Router,
    sampler: Sampler,
    seqs: HashMap<SeqId, Sequence>,
    pub metrics: EngineMetrics,
    pub tokenizer: ByteTokenizer,
}

impl SimEngine {
    pub fn new(cfg: EngineConfig, spec: SimSpec) -> Result<Self> {
        cfg.validate()?;
        let geo = KvGeometry {
            n_layers: spec.n_layers,
            n_heads: spec.n_heads,
            head_dim: spec.head_dim,
            block_tokens: cfg.kv_block_tokens,
            max_seq: spec.max_seq,
        };
        Ok(SimEngine {
            kv: KvCache::new(geo, cfg.kv_total_blocks),
            prefix: PrefixCache::new(cfg.kv_block_tokens),
            batcher: Batcher::new(cfg.decode_buckets.clone()),
            router: Router::new(),
            sampler: Sampler::new(cfg.seed),
            seqs: HashMap::new(),
            metrics: EngineMetrics::default(),
            tokenizer: ByteTokenizer::new(spec.vocab),
            spec,
            cfg,
        })
    }

    pub fn geometry(&self) -> KvGeometry {
        self.kv.geometry()
    }

    pub fn kv_free_blocks(&self) -> usize {
        self.kv.free_blocks()
    }

    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.cached_blocks()
    }

    /// Submit a text prompt; returns (seq id, token stream).
    pub fn submit_text(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<(SeqId, mpsc::Receiver<TokenEvent>)> {
        let toks = self.tokenizer.encode(prompt);
        self.submit_tokens(toks, max_new_tokens, params)
    }

    /// Submit pre-tokenized input.
    pub fn submit_tokens(
        &mut self,
        prompt_tokens: Vec<u32>,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<(SeqId, mpsc::Receiver<TokenEvent>)> {
        if prompt_tokens.is_empty() {
            return Err(Error::Request("empty prompt".into()));
        }
        if prompt_tokens.len() + 1 > self.spec.max_seq {
            return Err(Error::Request(format!(
                "prompt of {} tokens exceeds sim max_seq {}",
                prompt_tokens.len(),
                self.spec.max_seq
            )));
        }
        let (tx, rx) = mpsc::channel();
        let id = self.router.submit(Request {
            prompt_tokens,
            max_new_tokens: max_new_tokens.min(self.cfg.max_new_tokens),
            params,
            stream: tx,
            arrived: Instant::now(),
        });
        Ok((id, rx))
    }

    pub fn is_idle(&self) -> bool {
        self.router.queued() == 0 && self.batcher.is_empty()
    }

    pub fn running(&self) -> usize {
        self.batcher.len()
    }

    pub fn queued(&self) -> usize {
        self.router.queued()
    }

    fn usable_prefix(&self, prompt_len: usize, matched: usize) -> usize {
        let bt = self.cfg.kv_block_tokens;
        (matched.min(prompt_len.saturating_sub(1)) / bt) * bt
    }

    /// Radix-tree lookup for a prompt, truncated to the usable range.
    fn lookup_prefix(&mut self, prompt: &[u32]) -> PrefixMatch {
        if !self.cfg.prefix_cache {
            return PrefixMatch::default();
        }
        let m = self.prefix.match_prefix(prompt);
        let usable = self.usable_prefix(prompt.len(), m.tokens);
        if usable == 0 {
            return PrefixMatch::default();
        }
        PrefixMatch {
            blocks: m.blocks[..usable / self.cfg.kv_block_tokens].to_vec(),
            tokens: usable,
        }
    }

    /// Admit a sequence's KV: prefix attach, then eviction of the
    /// uncached shortfall + retry, then a cold fallback when nothing is
    /// running (mirror of `Engine::admit_kv` — attach-before-evict,
    /// fresh match after every eviction).
    fn admit_kv(&mut self, id: SeqId, prompt: &[u32]) -> Result<Option<PrefixMatch>> {
        let len = prompt.len();
        let need = (len + 1).div_ceil(self.cfg.kv_block_tokens);
        let matched = self.lookup_prefix(prompt);
        if self
            .kv
            .alloc_seq_with_prefix(id, len + 1, &matched.blocks, matched.tokens)
            .is_ok()
        {
            return Ok(Some(matched));
        }
        let want = need
            .saturating_sub(matched.blocks.len())
            .saturating_sub(self.kv.free_blocks());
        let freed = self.prefix.evict(want, &mut self.kv);
        self.metrics.prefix_blocks_evicted += freed as u64;
        let matched = self.lookup_prefix(prompt);
        if self
            .kv
            .alloc_seq_with_prefix(id, len + 1, &matched.blocks, matched.tokens)
            .is_ok()
        {
            return Ok(Some(matched));
        }
        if !self.batcher.is_empty() {
            return Ok(None);
        }
        let freed = self.prefix.evict(need, &mut self.kv);
        self.metrics.prefix_blocks_evicted += freed as u64;
        self.kv.alloc_seq(id, len + 1)?;
        Ok(Some(PrefixMatch::default()))
    }

    /// Blocks the next queued prefill needs and how many are cached
    /// (a peek: no LRU touch, no attach).
    fn admission_outlook(&self) -> (usize, usize) {
        match self.router.queue.front() {
            Some(s) => {
                let bt = self.cfg.kv_block_tokens;
                let need = (s.prompt.len() + 1).div_ceil(bt);
                let cached = if self.cfg.prefix_cache {
                    let matched = self.prefix.peek_match_tokens(&s.prompt);
                    self.usable_prefix(s.prompt.len(), matched) / bt
                } else {
                    0
                };
                (need, cached)
            }
            None => (0, 0),
        }
    }

    /// Run one scheduling iteration (same policy as the real engine).
    pub fn step(&mut self) -> Result<Action> {
        let (next_blocks, mut cached_blocks) = self.admission_outlook();
        // Pressure-evict only when admission is possible, after touching
        // the head request's matched path so LRU spares it (same
        // discipline as the real engine).
        let uncached = next_blocks.saturating_sub(cached_blocks);
        let admission_possible = next_blocks > 0 && self.batcher.len() < self.cfg.max_running;
        if admission_possible && self.kv.free_blocks() < uncached {
            if let Some(prompt) = self.router.queue.front().map(|s| s.prompt.clone()) {
                let _ = self.prefix.match_prefix(&prompt);
            }
            let want = uncached - self.kv.free_blocks();
            let freed = self.prefix.evict(want, &mut self.kv);
            self.metrics.prefix_blocks_evicted += freed as u64;
            if freed > 0 {
                // Re-peek: eviction may have trimmed blocks the first
                // peek counted as cached.
                cached_blocks = self.admission_outlook().1;
            }
        }
        let action = decide(SchedState {
            queued: self.router.queued(),
            running: self.batcher.len(),
            max_running: self.cfg.max_running,
            free_blocks: self.kv.free_blocks(),
            next_prefill_blocks: next_blocks,
            cached_prefill_blocks: cached_blocks,
        });
        match action {
            Action::Prefill => self.step_prefill()?,
            Action::Decode => self.step_decode()?,
            Action::Idle => {}
        }
        Ok(action)
    }

    pub fn run_to_completion(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    /// Offline helper: generate for one prompt, blocking.
    pub fn generate_text(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<String> {
        let (_, rx) = self.submit_text(prompt, max_new_tokens, params)?;
        self.run_to_completion()?;
        let mut out = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            if let TokenEvent::Token(t) = ev {
                out.push(t);
            }
        }
        Ok(self.tokenizer.decode(&out))
    }

    // -----------------------------------------------------------------
    // Hash model
    // -----------------------------------------------------------------

    /// K/V column for `(token, pos)` in [Lyr, H, Dh] layout.
    fn token_cols(&self, token: u32, pos: usize) -> (Vec<f32>, Vec<f32>) {
        let g = self.kv.geometry();
        let te = g.token_elems();
        let mut k = Vec::with_capacity(te);
        let mut v = Vec::with_capacity(te);
        let base = ((token as u64) << 32) ^ ((pos as u64) << 8);
        for e in 0..te {
            k.push(hash_f32(base ^ ((e as u64) << 1)));
            v.push(hash_f32(base ^ ((e as u64) << 1) ^ 1));
        }
        (k, v)
    }

    /// Prefill K/V for a whole prompt in [Lyr, 1, H, S, Dh] layout
    /// (S = prompt length, unpadded).
    fn prefill_kv(&self, tokens: &[u32]) -> (Vec<f32>, Vec<f32>) {
        let g = self.kv.geometry();
        let s = tokens.len();
        let n = g.n_layers * g.n_heads * s * g.head_dim;
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for (t, &tok) in tokens.iter().enumerate() {
            let (kc, vc) = self.token_cols(tok, t);
            for l in 0..g.n_layers {
                for h in 0..g.n_heads {
                    let src = (l * g.n_heads + h) * g.head_dim;
                    let dst = ((l * g.n_heads + h) * s + t) * g.head_dim;
                    k[dst..dst + g.head_dim].copy_from_slice(&kc[src..src + g.head_dim]);
                    v[dst..dst + g.head_dim].copy_from_slice(&vc[src..src + g.head_dim]);
                }
            }
        }
        (k, v)
    }

    /// Logits for a sequence: a digest over the KV bytes *stored in the
    /// paged cache* (so shared-block corruption is observable), mixed
    /// with the current input token.
    fn logits_for(&self, id: SeqId, cur_tok: u32) -> Result<Vec<f32>> {
        let g = self.kv.geometry();
        let te = g.token_elems();
        let len = self
            .kv
            .seq_len(id)
            .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
        let mut kcol = vec![0.0f32; te];
        let mut vcol = vec![0.0f32; te];
        let mut digest: u64 = 0x5EED_CAFE;
        for pos in 0..len {
            self.kv.read_token(id, pos, &mut kcol, &mut vcol)?;
            for f in kcol.iter().chain(vcol.iter()) {
                digest = mix(digest ^ f.to_bits() as u64);
            }
        }
        digest = mix(digest ^ ((cur_tok as u64) << 32));
        let logits = (0..self.spec.vocab)
            .map(|c| hash_f32(digest ^ c as u64))
            .collect();
        Ok(logits)
    }

    // -----------------------------------------------------------------
    // Prefill
    // -----------------------------------------------------------------

    fn step_prefill(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let mut seq = match self.router.pop_next() {
            Some(s) => s,
            None => return Ok(()),
        };
        let len = seq.prompt.len();

        // Prefix lookup + KV admission (same discipline as the real
        // engine; see `Engine::admit_kv`).
        let matched = match self.admit_kv(seq.id, &seq.prompt) {
            Ok(Some(m)) => m,
            Ok(None) => {
                self.router.requeue_front(seq);
                return self.step_decode();
            }
            Err(e) => {
                self.router.requeue_front(seq);
                return Err(e);
            }
        };
        if self.cfg.prefix_cache {
            self.metrics.prefix_lookups += 1;
            if matched.tokens > 0 {
                self.metrics.prefix_hits += 1;
            }
        }
        self.metrics.prefix_tokens_reused += matched.tokens as u64;
        self.metrics.prefill_tokens_computed += (len - matched.tokens) as u64;

        // "Compute" and store the uncached suffix only.
        let (k, v) = self.prefill_kv(&seq.prompt);
        self.kv
            .write_prefill_range(seq.id, &k, &v, len, matched.tokens, len)?;
        seq.kv_len = len;

        // First generated token.
        let logits = self.logits_for(seq.id, *seq.prompt.last().unwrap())?;
        let tok = self.sampler.sample(&logits, seq.params);
        seq.generated.push(tok);
        seq.first_token_at = Some(Instant::now());
        self.metrics.first_token.record(seq.arrived.elapsed());
        seq.emit(TokenEvent::Token(tok));
        self.metrics.tokens_generated += 1;
        self.metrics.requests_admitted += 1;

        if tok == EOS || seq.max_new_tokens <= 1 {
            let reason = if tok == EOS {
                FinishReason::Eos
            } else {
                FinishReason::MaxTokens
            };
            self.finish_seq(&mut seq, reason)?;
        } else {
            seq.state = SeqState::Decoding;
            self.batcher.admit(seq.id)?;
            self.seqs.insert(seq.id, seq);
        }
        self.metrics.prefill_steps += 1;
        self.metrics.step.record(t0.elapsed());
        Ok(())
    }

    // -----------------------------------------------------------------
    // Decode
    // -----------------------------------------------------------------

    fn step_decode(&mut self) -> Result<()> {
        let t0 = Instant::now();
        // KV headroom: reclaim cached blocks first (even for a lone
        // sequence), preempt last (needs >= 2 running).
        while self.kv.free_blocks() < self.batcher.len() {
            let want = self.batcher.len() - self.kv.free_blocks();
            let freed = self.prefix.evict(want, &mut self.kv);
            self.metrics.prefix_blocks_evicted += freed as u64;
            if self.kv.free_blocks() >= self.batcher.len() || self.batcher.len() <= 1 {
                break;
            }
            self.preempt_one()?;
        }
        let batch = self.batcher.assemble()?;
        let max_seq = self.spec.max_seq;
        let mut finished: Vec<SeqId> = Vec::new();
        for slot in batch.lanes.iter() {
            let Some(id) = slot else { continue };
            let (tok, pos) = {
                let s = &self.seqs[id];
                (s.last_token(), s.kv_len)
            };
            // Append the input token's KV (COW protects shared tails),
            // then read logits over the stored sequence.
            self.kv.grow_one(*id)?;
            let (kc, vc) = self.token_cols(tok, pos);
            self.kv.write_token(*id, pos, &kc, &vc)?;
            let logits = self.logits_for(*id, tok)?;
            let seq = self.seqs.get_mut(id).unwrap();
            seq.kv_len += 1;
            let new_tok = self.sampler.sample(&logits, seq.params);
            seq.generated.push(new_tok);
            seq.emit(TokenEvent::Token(new_tok));
            self.metrics.tokens_generated += 1;
            self.metrics.decode_rows += 1;
            let done_eos = new_tok == EOS;
            let done_len =
                seq.generated.len() >= seq.max_new_tokens || seq.kv_len + 1 >= max_seq;
            if done_eos || done_len {
                finished.push(*id);
            }
        }
        for id in finished {
            let mut seq = self.seqs.remove(&id).unwrap();
            let reason = if seq.generated.last() == Some(&EOS) {
                FinishReason::Eos
            } else {
                FinishReason::MaxTokens
            };
            self.batcher.remove(id)?;
            self.finish_seq(&mut seq, reason)?;
        }
        self.metrics.decode_steps += 1;
        let dt = t0.elapsed();
        self.metrics.step.record(dt);
        let lanes = batch.occupancy().max(1) as u32;
        self.metrics.per_token.record(dt / lanes);
        Ok(())
    }

    fn preempt_one(&mut self) -> Result<()> {
        let candidates: Vec<PreemptCandidate> = self
            .batcher
            .running_ids()
            .into_iter()
            .map(|id| {
                let reusable = self
                    .kv
                    .seq_blocks(id)
                    .map(|bs| {
                        bs.iter()
                            .filter(|&&b| self.kv.block_refcount(b) > 1)
                            .count()
                    })
                    .unwrap_or(0);
                PreemptCandidate {
                    id,
                    reusable_blocks: reusable,
                }
            })
            .collect();
        let id = preemption_victim(&candidates)
            .ok_or_else(|| Error::Schedule("no preemption victim".into()))?;
        let mut seq = self.seqs.remove(&id).unwrap();
        self.metrics.preemptions += 1;
        self.batcher.remove(id)?;
        self.finish_seq(&mut seq, FinishReason::Preempted)
    }

    /// Register the retired sequence's stored tokens in the prefix
    /// cache. Unlike the real engine (whose generated KV may still be
    /// device-resident), the sim writes synchronously into the paged
    /// store, so prompt *and* generated tokens are publishable.
    fn register_prefix(&mut self, seq: &Sequence) {
        if !self.cfg.prefix_cache || !self.kv.contains(seq.id) {
            return;
        }
        let Some(kv_len) = self.kv.seq_len(seq.id) else {
            return;
        };
        let Some(blocks) = self.kv.seq_blocks(seq.id) else {
            return;
        };
        let mut toks: Vec<u32> = Vec::with_capacity(kv_len);
        toks.extend_from_slice(&seq.prompt);
        for &g in &seq.generated {
            if toks.len() >= kv_len {
                break;
            }
            toks.push(g);
        }
        toks.truncate(kv_len);
        self.prefix.insert(&toks, &blocks, &mut self.kv);
    }

    fn finish_seq(&mut self, seq: &mut Sequence, reason: FinishReason) -> Result<()> {
        seq.state = SeqState::Finished(reason);
        seq.emit(TokenEvent::Finished {
            reason,
            n_generated: seq.generated.len(),
        });
        self.register_prefix(seq);
        if self.kv.contains(seq.id) {
            self.kv.free_seq(seq.id)?;
        }
        self.metrics.requests_finished += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(prefix_cache: bool) -> EngineConfig {
        EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 128,
            max_new_tokens: 16,
            prefix_cache,
            ..EngineConfig::default()
        }
    }

    fn collect(rx: &mpsc::Receiver<TokenEvent>) -> (Vec<u32>, Option<FinishReason>) {
        let mut toks = vec![];
        let mut fin = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                TokenEvent::Token(t) => toks.push(t),
                TokenEvent::Finished { reason, .. } => fin = Some(reason),
            }
        }
        (toks, fin)
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let mut a = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let mut b = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let pa = a.generate_text("determinism probe", 12, SamplingParams::default()).unwrap();
        let pb = b.generate_text("determinism probe", 12, SamplingParams::default()).unwrap();
        assert_eq!(pa, pb);
        assert!(a.metrics.tokens_generated >= 1);
        assert_eq!(a.metrics.tokens_generated, b.metrics.tokens_generated);
    }

    #[test]
    fn concurrent_requests_all_finish() {
        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let mut rxs = vec![];
        for p in ["alpha", "beta prompt", "gamma gamma gamma"] {
            let (_, rx) = e.submit_text(p, 10, SamplingParams::default()).unwrap();
            rxs.push(rx);
        }
        e.run_to_completion().unwrap();
        for rx in &rxs {
            let (toks, fin) = collect(rx);
            assert!(!toks.is_empty());
            assert!(fin.is_some());
        }
        assert_eq!(e.metrics.requests_finished, 3);
        assert_eq!(e.kv_free_blocks() + e.prefix_cached_blocks(), 128);
    }

    #[test]
    fn repeated_prompt_hits_prefix_cache_with_identical_output() {
        // 32-char prompt -> 33 tokens with BOS -> 4 full blocks of 8.
        let prompt = "system: you are a helpful tool"; // 30 chars + BOS = 31
        let prompt = format!("{prompt}!!"); // 33 tokens with BOS

        let mut warm = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let first = warm.generate_text(&prompt, 8, SamplingParams::default()).unwrap();
        assert_eq!(warm.metrics.prefix_hits, 0, "cold first request");
        let second = warm.generate_text(&prompt, 8, SamplingParams::default()).unwrap();
        assert_eq!(warm.metrics.prefix_hits, 1, "second request must hit");
        assert!(warm.metrics.prefix_tokens_reused >= 32);
        assert_eq!(first, second, "cache hit must not change output");

        // And identical to a cache-disabled engine.
        let mut cold = SimEngine::new(cfg(false), SimSpec::default()).unwrap();
        let base = cold.generate_text(&prompt, 8, SamplingParams::default()).unwrap();
        let base2 = cold.generate_text(&prompt, 8, SamplingParams::default()).unwrap();
        assert_eq!(first, base);
        assert_eq!(second, base2);
        assert_eq!(cold.metrics.prefix_lookups, 0);
    }

    #[test]
    fn eviction_reclaims_cached_blocks_under_pressure() {
        // Tiny pool: the cache must give blocks back for new prompts.
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 10,
            max_new_tokens: 4,
            prefix_cache: true,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        for i in 0..6 {
            let prompt = format!("tenant-{i} prompt padded to some length....");
            let (_, _rx) = e.submit_text(&prompt, 3, SamplingParams::default()).unwrap();
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 6);
        assert!(
            e.metrics.prefix_blocks_evicted > 0,
            "pool of 10 blocks cannot cache 6 distinct prompts without evicting"
        );
        assert_eq!(e.kv_free_blocks() + e.prefix_cached_blocks(), 10);
    }
}
