//! Simulation engine: the PJRT-free twin of [`crate::engine::Engine`].
//!
//! Runs the *entire* serving stack — router, cache-aware scheduler,
//! continuous batcher, paged KV cache with block sharing, radix-tree
//! prefix cache, sampler, metrics — against a deterministic hash model
//! instead of compiled artifacts. The hash model writes K/V columns that
//! are pure functions of `(token, position)` and derives logits from a
//! digest of the KV bytes *actually stored in the paged cache*, so any
//! block-sharing bug (double free, COW miss, stale shared block)
//! changes generated tokens instead of passing silently.
//!
//! The twin implements the same [`crate::api::InferenceEngine`] trait
//! as the real engine and shares its admission / eviction / preemption
//! logic through [`crate::policy`], so neither the policy nor the API
//! surface can drift. This is what lets `benches/prefix_reuse.rs`, the
//! loopback server test, and the tier-1 tests measure prefix-cache hit
//! rates and verify cached-vs-cold output equality on a bare checkout,
//! where the PJRT artifacts of the real engine are unavailable.

use std::collections::HashMap;
use std::time::Duration;

use crate::api::{
    FinishReason, GenRequest, InferenceEngine, RequestId, SubmissionHandle, Usage, Wakeup,
};
use crate::batching::Batcher;
use crate::config::EngineConfig;
use crate::error::{Error, Result};
use crate::kvcache::{KvAudit, KvCache, KvGeometry, SeqId};
use crate::metrics::EngineMetrics;
use crate::policy::{self, StreamOp};
use crate::prefixcache::PrefixCache;
use crate::router::{self, Router, SeqState, Sequence, SubmitContext};
use crate::sampling::Sampler;
use crate::scheduler::{decide, preemption_victim, Action};
use crate::tokenizer::{ByteTokenizer, EOS, TOKENIZER_VOCAB};
use crate::util::clock::Clock;

/// Virtual time one engine step costs on the sim's manual clock. Every
/// latency the sim reports (and every idle-timeout decision) is a
/// deterministic multiple of this quantum.
pub const SIM_STEP: Duration = Duration::from_millis(1);

/// One observable scheduling event, recorded when tracing is enabled
/// ([`SimEngine::enable_trace`]). The simulation-test harness replays
/// scenarios and checks its oracles against this stream; it is also
/// what makes two runs comparably *byte-identical* (equal traces).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A request was admitted (prefill ran); `cached` prompt tokens
    /// were served from the prefix cache.
    Admitted { id: SeqId, cached: usize },
    /// One generated token was emitted to the request's stream.
    Token { id: SeqId, token: u32 },
    /// The sequence was parked by stream backpressure.
    Paused { id: SeqId },
    /// A parked sequence rejoined the decode batch.
    Resumed { id: SeqId },
    /// A parked sequence sat idle past `stream_idle_timeout` and was
    /// demoted to `Overrun`.
    Expired { id: SeqId },
    /// Decode-pressure preemption: the chosen victim, its priority, and
    /// the full candidate pool `(id, priority)` the choice ran over —
    /// recorded so an external oracle can verify priority monotonicity
    /// without trusting the policy it is checking.
    Preempted {
        id: SeqId,
        priority: i32,
        pool: Vec<(SeqId, i32)>,
    },
    /// Admission-relief preemption of a parked victim on behalf of a
    /// blocked higher-priority waiter.
    AdmissionRelief {
        id: SeqId,
        priority: i32,
        waiter_priority: i32,
    },
    /// The request finished; exactly one per request.
    Finished {
        id: SeqId,
        reason: FinishReason,
        usage: Usage,
    },
}

/// One live sequence in an [`EngineAudit`] snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LiveSeq {
    pub id: SeqId,
    pub priority: i32,
    pub paused: bool,
}

/// A full accounting snapshot of the sim engine's shared state, taken
/// between steps by the simulation-test oracles: the KV allocator's
/// books, the prefix tree's retained block references, and the live
/// sequence set.
#[derive(Debug, Clone)]
pub struct EngineAudit {
    pub kv: KvAudit,
    /// Blocks retained by the prefix tree, one entry per tree-held
    /// reference.
    pub tree_blocks: Vec<usize>,
    pub live: Vec<LiveSeq>,
    pub queued: usize,
}

/// Hash-model geometry (kept tiny: the point is block accounting, not
/// FLOPs).
#[derive(Debug, Clone, Copy)]
pub struct SimSpec {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            vocab: TOKENIZER_VOCAB + 61, // a little headroom over specials
            max_seq: 256,
        }
    }
}

/// splitmix64 finalizer — the model's only "weights".
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// Deterministic f32 in [-1, 1) from a hash.
fn hash_f32(x: u64) -> f32 {
    ((mix(x) >> 40) as f32) / (1u64 << 24) as f32 * 2.0 - 1.0
}

/// The simulation engine. Same single-owner discipline as `Engine`.
pub struct SimEngine {
    pub cfg: EngineConfig,
    spec: SimSpec,
    kv: KvCache,
    prefix: PrefixCache,
    batcher: Batcher,
    router: Router,
    sampler: Sampler,
    seqs: HashMap<SeqId, Sequence>,
    /// Sequences parked by stream backpressure: they stay in `seqs`
    /// (state `Paused`) and keep their KV, but hold no decode lane.
    paused: Vec<SeqId>,
    /// Virtual time: a manual [`Clock`] advanced [`SIM_STEP`] per step,
    /// so every latency and timeout decision is deterministic.
    clock: Clock,
    /// Engine-loop wakeup each new stream notifies on client drains.
    wakeup: Option<Wakeup>,
    /// Scheduling-event trace (None until [`SimEngine::enable_trace`]).
    trace: Option<Vec<TraceEvent>>,
    pub metrics: EngineMetrics,
    pub tokenizer: ByteTokenizer,
}

impl SimEngine {
    /// Build a sim engine on its own fresh virtual clock.
    pub fn new(cfg: EngineConfig, spec: SimSpec) -> Result<Self> {
        Self::with_clock(cfg, spec, Clock::manual())
    }

    /// Build a sim engine sharing an externally owned clock (the
    /// simulation-test harness uses this to observe and steer virtual
    /// time).
    pub fn with_clock(cfg: EngineConfig, spec: SimSpec, clock: Clock) -> Result<Self> {
        cfg.validate()?;
        let geo = KvGeometry {
            n_layers: spec.n_layers,
            n_heads: spec.n_heads,
            head_dim: spec.head_dim,
            block_tokens: cfg.kv_block_tokens,
            max_seq: spec.max_seq,
        };
        Ok(SimEngine {
            kv: KvCache::new(geo, cfg.kv_total_blocks),
            prefix: PrefixCache::new(cfg.kv_block_tokens),
            batcher: Batcher::new(cfg.decode_buckets.clone()),
            router: Router::new(),
            sampler: Sampler::new(cfg.seed),
            seqs: HashMap::new(),
            paused: Vec::new(),
            clock,
            wakeup: None,
            trace: None,
            metrics: EngineMetrics::default(),
            tokenizer: ByteTokenizer::new(spec.vocab),
            spec,
            cfg,
        })
    }

    pub fn geometry(&self) -> KvGeometry {
        self.kv.geometry()
    }

    /// A handle onto the engine's (virtual) clock.
    pub fn clock(&self) -> Clock {
        self.clock.clone()
    }

    /// Start recording [`TraceEvent`]s (drained with
    /// [`SimEngine::take_trace`]).
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Drain the recorded trace (empty when tracing is disabled).
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        self.trace.as_mut().map(std::mem::take).unwrap_or_default()
    }

    fn push_trace(&mut self, ev: TraceEvent) {
        if let Some(t) = self.trace.as_mut() {
            t.push(ev);
        }
    }

    /// Accounting snapshot for the simulation-test oracles.
    pub fn audit(&self) -> EngineAudit {
        let mut live: Vec<LiveSeq> = self
            .seqs
            .values()
            .map(|s| LiveSeq {
                id: s.id,
                priority: s.priority,
                paused: s.state == SeqState::Paused,
            })
            .collect();
        live.sort_by_key(|l| l.id);
        EngineAudit {
            kv: self.kv.audit(),
            tree_blocks: self.prefix.tree_block_refs(),
            live,
            queued: self.router.queued(),
        }
    }

    /// Test-only fault hook: double-free the first KV block of the
    /// oldest live sequence, exactly the class of bug the refcount
    /// oracle exists to catch. Returns `false` when nothing is live.
    #[cfg(test)]
    pub fn inject_double_free(&mut self) -> bool {
        let Some(id) = self.audit().live.first().map(|l| l.id) else {
            return false;
        };
        let Some(blocks) = self.kv.seq_blocks(id) else {
            return false;
        };
        let Some(&b) = blocks.first() else {
            return false;
        };
        self.kv.debug_force_decref(b);
        true
    }

    pub fn kv_free_blocks(&self) -> usize {
        self.kv.free_blocks()
    }

    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.cached_blocks()
    }

    // -----------------------------------------------------------------
    // Hash model
    // -----------------------------------------------------------------

    /// K/V column for `(token, pos)` in [Lyr, H, Dh] layout.
    fn token_cols(&self, token: u32, pos: usize) -> (Vec<f32>, Vec<f32>) {
        let g = self.kv.geometry();
        let te = g.token_elems();
        let mut k = Vec::with_capacity(te);
        let mut v = Vec::with_capacity(te);
        let base = ((token as u64) << 32) ^ ((pos as u64) << 8);
        for e in 0..te {
            k.push(hash_f32(base ^ ((e as u64) << 1)));
            v.push(hash_f32(base ^ ((e as u64) << 1) ^ 1));
        }
        (k, v)
    }

    /// Prefill K/V for a whole prompt in [Lyr, 1, H, S, Dh] layout
    /// (S = prompt length, unpadded).
    fn prefill_kv(&self, tokens: &[u32]) -> (Vec<f32>, Vec<f32>) {
        let g = self.kv.geometry();
        let s = tokens.len();
        let n = g.n_layers * g.n_heads * s * g.head_dim;
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        for (t, &tok) in tokens.iter().enumerate() {
            let (kc, vc) = self.token_cols(tok, t);
            for l in 0..g.n_layers {
                for h in 0..g.n_heads {
                    let src = (l * g.n_heads + h) * g.head_dim;
                    let dst = ((l * g.n_heads + h) * s + t) * g.head_dim;
                    k[dst..dst + g.head_dim].copy_from_slice(&kc[src..src + g.head_dim]);
                    v[dst..dst + g.head_dim].copy_from_slice(&vc[src..src + g.head_dim]);
                }
            }
        }
        (k, v)
    }

    /// Logits for a sequence: a digest over the KV bytes *stored in the
    /// paged cache* (so shared-block corruption is observable), mixed
    /// with the current input token.
    fn logits_for(&self, id: SeqId, cur_tok: u32) -> Result<Vec<f32>> {
        let g = self.kv.geometry();
        let te = g.token_elems();
        let len = self
            .kv
            .seq_len(id)
            .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
        let mut kcol = vec![0.0f32; te];
        let mut vcol = vec![0.0f32; te];
        let mut digest: u64 = 0x5EED_CAFE;
        for pos in 0..len {
            self.kv.read_token(id, pos, &mut kcol, &mut vcol)?;
            for f in kcol.iter().chain(vcol.iter()) {
                digest = mix(digest ^ f.to_bits() as u64);
            }
        }
        digest = mix(digest ^ ((cur_tok as u64) << 32));
        let logits = (0..self.spec.vocab)
            .map(|c| hash_f32(digest ^ c as u64))
            .collect();
        Ok(logits)
    }

    // -----------------------------------------------------------------
    // Prefill
    // -----------------------------------------------------------------

    fn step_prefill(&mut self) -> Result<()> {
        let t0 = self.clock.now();
        let mut seq = match self.router.pop_next() {
            Some(s) => s,
            None => return Ok(()),
        };
        let len = seq.prompt.len();

        // Prefix lookup + KV admission (shared policy; see
        // `policy::admit_kv`). Paused sequences count as pending work:
        // their blocks return when they resume or finish, so admission
        // must wait for them rather than fail the request.
        let matched = match policy::admit_kv(
            &self.cfg,
            &mut self.kv,
            &mut self.prefix,
            &mut self.metrics,
            self.batcher.is_empty() && self.paused.is_empty(),
            seq.id,
            &seq.prompt,
        ) {
            Ok(Some(m)) => m,
            Ok(None) => {
                // Admission must wait for KV. If nothing is decoding,
                // the holders are parked on backpressure and decode
                // will never free blocks — preempt a strictly
                // lower-priority parked victim so a high-priority
                // waiter is not starved by a stalled client.
                if self.batcher.is_empty() {
                    if let Some(victim) = policy::admission_relief_victim(
                        &self.kv,
                        &self.seqs,
                        &self.paused,
                        seq.priority,
                    ) {
                        self.paused.retain(|&p| p != victim);
                        let mut vseq = self.seqs.remove(&victim).unwrap();
                        self.metrics.preemptions += 1;
                        self.push_trace(TraceEvent::AdmissionRelief {
                            id: vseq.id,
                            priority: vseq.priority,
                            waiter_priority: seq.priority,
                        });
                        self.finish_seq(&mut vseq, FinishReason::Preempted)?;
                    }
                }
                self.router.requeue_front(seq);
                return self.step_decode();
            }
            Err(_) => {
                // Truly stuck (see `Engine::step_prefill`): fail the
                // request rather than wedge the queue head forever.
                self.finish_seq(&mut seq, FinishReason::Error)?;
                return Ok(());
            }
        };
        policy::note_admission(&self.cfg, &mut self.metrics, &mut seq, matched.tokens);
        self.push_trace(TraceEvent::Admitted {
            id: seq.id,
            cached: matched.tokens,
        });

        // "Compute" and store the uncached suffix only.
        let (k, v) = self.prefill_kv(&seq.prompt);
        self.kv
            .write_prefill_range(seq.id, &k, &v, len, matched.tokens, len)?;
        seq.kv_len = len;

        // First generated token. A fresh stream always has credit
        // (capacity >= 1); a client that already hung up is reaped by
        // the next step's stream scan.
        let logits = self.logits_for(seq.id, *seq.prompt.last().unwrap())?;
        let tok = self.sampler.sample(&logits, seq.params);
        seq.generated.push(tok);
        let now = self.clock.now();
        seq.first_token_at = Some(now);
        self.metrics.first_token.record(now.saturating_sub(seq.arrived));
        let _ = seq.emit_token(tok);
        self.push_trace(TraceEvent::Token { id: seq.id, token: tok });
        self.metrics.tokens_generated += 1;
        self.metrics.requests_admitted += 1;

        let done_eos = tok == EOS;
        let done_stop = seq.hit_stop();
        if done_eos || done_stop || seq.max_new_tokens <= 1 {
            let reason = if done_eos {
                FinishReason::Eos
            } else if done_stop {
                FinishReason::Stop
            } else {
                FinishReason::MaxTokens
            };
            self.finish_seq(&mut seq, reason)?;
        } else {
            seq.state = SeqState::Decoding;
            self.batcher.admit(seq.id)?;
            self.seqs.insert(seq.id, seq);
        }
        self.metrics.prefill_steps += 1;
        self.metrics.step.record(self.clock.now().saturating_sub(t0));
        Ok(())
    }

    // -----------------------------------------------------------------
    // Decode
    // -----------------------------------------------------------------

    fn step_decode(&mut self) -> Result<()> {
        let t0 = self.clock.now();
        // The stream scan may have paused or dropped every running
        // sequence; there is nothing to decode then.
        if self.batcher.is_empty() {
            return Ok(());
        }
        // KV headroom via the shared policy: reclaim cached blocks
        // first, preempt last. The victim pool spans running *and*
        // backpressure-paused sequences (parked work holds KV too).
        while policy::reclaim_decode_headroom(
            &mut self.kv,
            &mut self.prefix,
            &mut self.metrics,
            self.batcher.len(),
            self.batcher.len() + self.paused.len(),
        ) {
            self.preempt_one()?;
        }
        if self.batcher.is_empty() {
            return Ok(()); // preemption may have taken the last runner
        }
        let batch = self.batcher.assemble()?;
        let max_seq = self.spec.max_seq;
        let mut finished: Vec<(SeqId, FinishReason)> = Vec::new();
        let mut emitted: Vec<(SeqId, u32)> = Vec::new();
        for slot in batch.lanes.iter() {
            let Some(id) = slot else { continue };
            let (tok, pos) = {
                let s = &self.seqs[id];
                (s.last_token(), s.kv_len)
            };
            // Append the input token's KV (COW protects shared tails),
            // then read logits over the stored sequence.
            self.kv.grow_one(*id)?;
            let (kc, vc) = self.token_cols(tok, pos);
            self.kv.write_token(*id, pos, &kc, &vc)?;
            let logits = self.logits_for(*id, tok)?;
            let seq = self.seqs.get_mut(id).unwrap();
            seq.kv_len += 1;
            let new_tok = self.sampler.sample(&logits, seq.params);
            seq.generated.push(new_tok);
            // Cannot be Full: the pre-decode stream scan guaranteed at
            // least one credit and this is the step's only token. A
            // mid-step disconnect is reaped by the next scan.
            let _ = seq.emit_token(new_tok);
            emitted.push((*id, new_tok));
            self.metrics.tokens_generated += 1;
            self.metrics.decode_rows += 1;
            let done_eos = new_tok == EOS;
            let done_stop = seq.hit_stop();
            let done_len = seq.generated.len() >= seq.max_new_tokens || seq.kv_len + 1 >= max_seq;
            if done_eos || done_stop || done_len {
                let reason = if done_eos {
                    FinishReason::Eos
                } else if done_stop {
                    FinishReason::Stop
                } else {
                    FinishReason::MaxTokens
                };
                finished.push((*id, reason));
            }
        }
        for (id, token) in emitted {
            self.push_trace(TraceEvent::Token { id, token });
        }
        for (id, reason) in finished {
            let mut seq = self.seqs.remove(&id).unwrap();
            self.batcher.remove(id)?;
            self.finish_seq(&mut seq, reason)?;
        }
        self.metrics.decode_steps += 1;
        let dt = self.clock.now().saturating_sub(t0);
        self.metrics.step.record(dt);
        let lanes = batch.occupancy().max(1) as u32;
        self.metrics.per_token.record(dt / lanes);
        Ok(())
    }

    /// Preempt one victim under KV pressure: the shared census spans
    /// running *and* paused sequences (a parked slow client's KV is
    /// reclaimable like any other), ordered by the scheduler's
    /// (priority asc, parked first, reusable desc, recency) rule.
    fn preempt_one(&mut self) -> Result<()> {
        let mut pool = self.batcher.running_ids();
        pool.extend(self.paused.iter().copied());
        let candidates = policy::preempt_candidates(&self.kv, &self.seqs, &pool);
        let id = preemption_victim(&candidates)
            .ok_or_else(|| Error::Schedule("no preemption victim".into()))?;
        let mut seq = self.seqs.remove(&id).unwrap();
        self.metrics.preemptions += 1;
        self.push_trace(TraceEvent::Preempted {
            id,
            priority: seq.priority,
            pool: candidates.iter().map(|c| (c.id, c.priority)).collect(),
        });
        if self.paused.contains(&id) {
            self.paused.retain(|&p| p != id);
        } else {
            self.batcher.remove(id)?;
        }
        self.finish_seq(&mut seq, FinishReason::Preempted)
    }

    // -----------------------------------------------------------------
    // Stream flow control
    // -----------------------------------------------------------------

    /// Apply backpressure at the top of every step. The *decisions*
    /// (resume order, hysteresis, policy) are the shared
    /// [`policy::plan_stream_ops`]; this method supplies only the sim's
    /// mechanics for each transition. Running *before* the scheduling
    /// decision keeps the scheduler's view of the running set accurate,
    /// and checking credit before decode means a generated token always
    /// has a slot — backpressure halts generation, it never loses data.
    fn service_streams(&mut self) -> Result<()> {
        let free_lanes = self.cfg.max_running.saturating_sub(self.batcher.len());
        let now = self.clock.now();
        let ops = policy::plan_stream_ops(
            &self.seqs,
            &self.paused,
            &self.batcher.running_ids(),
            self.cfg.backpressure,
            free_lanes,
            now,
            self.cfg.stream_idle_timeout(),
        );
        for op in ops {
            match op {
                StreamOp::Resume(id) => {
                    self.batcher.admit(id)?;
                    self.paused.retain(|&p| p != id);
                    let seq = self.seqs.get_mut(&id).unwrap();
                    seq.state = SeqState::Decoding;
                    seq.paused_at = None;
                    self.metrics.backpressure_resumes += 1;
                    self.push_trace(TraceEvent::Resumed { id });
                }
                StreamOp::ReapPaused(id) => {
                    self.paused.retain(|&p| p != id);
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.metrics.client_disconnects += 1;
                    self.finish_seq(&mut seq, FinishReason::Cancelled)?;
                }
                StreamOp::ReapRunning(id) => {
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.batcher.remove(id)?;
                    self.metrics.client_disconnects += 1;
                    self.finish_seq(&mut seq, FinishReason::Cancelled)?;
                }
                StreamOp::Pause(id) => {
                    self.batcher.remove(id)?;
                    let seq = self.seqs.get_mut(&id).unwrap();
                    seq.state = SeqState::Paused;
                    seq.paused_at = Some(now);
                    self.paused.push(id);
                    self.metrics.backpressure_pauses += 1;
                    self.push_trace(TraceEvent::Paused { id });
                }
                StreamOp::DropOverrun(id) => {
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.batcher.remove(id)?;
                    self.metrics.backpressure_drops += 1;
                    self.finish_seq(&mut seq, FinishReason::Overrun)?;
                }
                StreamOp::ExpireIdle(id) => {
                    self.paused.retain(|&p| p != id);
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.metrics.stream_idle_drops += 1;
                    self.push_trace(TraceEvent::Expired { id });
                    self.finish_seq(&mut seq, FinishReason::Overrun)?;
                }
            }
        }
        Ok(())
    }

    /// Register the retired sequence's stored tokens in the prefix
    /// cache. Unlike the real engine (whose generated KV may still be
    /// device-resident), the sim writes synchronously into the paged
    /// store, so prompt *and* generated tokens are publishable.
    fn register_prefix(&mut self, seq: &Sequence) {
        if !self.cfg.prefix_cache || !self.kv.contains(seq.id) {
            return;
        }
        let Some(kv_len) = self.kv.seq_len(seq.id) else {
            return;
        };
        let Some(blocks) = self.kv.seq_blocks(seq.id) else {
            return;
        };
        let mut toks: Vec<u32> = Vec::with_capacity(kv_len);
        toks.extend_from_slice(&seq.prompt);
        for &g in &seq.generated {
            if toks.len() >= kv_len {
                break;
            }
            toks.push(g);
        }
        toks.truncate(kv_len);
        self.prefix.insert(&toks, &blocks, &mut self.kv);
    }

    fn finish_seq(&mut self, seq: &mut Sequence, reason: FinishReason) -> Result<()> {
        seq.state = SeqState::Finished(reason);
        let usage = seq.usage();
        seq.emit_finish(reason, usage);
        self.push_trace(TraceEvent::Finished {
            id: seq.id,
            reason,
            usage,
        });
        self.metrics.record_finish(&seq.tenant, usage);
        self.register_prefix(seq);
        if self.kv.contains(seq.id) {
            self.kv.free_seq(seq.id)?;
        }
        self.metrics.requests_finished += 1;
        Ok(())
    }
}

impl InferenceEngine for SimEngine {
    /// Queue a typed request; the prompt (+1 generated token) must fit
    /// the sim's `max_seq` and the KV pool.
    fn submit(&mut self, req: GenRequest) -> Result<SubmissionHandle> {
        let prompt_tokens = router::encode_prompt(&self.tokenizer, &req.prompt)?;
        if prompt_tokens.len() + 1 > self.spec.max_seq {
            return Err(Error::Request(format!(
                "prompt of {} tokens exceeds sim max_seq {}",
                prompt_tokens.len(),
                self.spec.max_seq
            )));
        }
        let need = (prompt_tokens.len() + 1).div_ceil(self.cfg.kv_block_tokens);
        if need > self.cfg.kv_total_blocks {
            return Err(Error::Request(format!(
                "prompt needs {need} KV blocks, pool has {}",
                self.cfg.kv_total_blocks
            )));
        }
        router::enqueue_request(
            &mut self.router,
            &self.tokenizer,
            &req,
            prompt_tokens,
            &SubmitContext {
                max_new_cap: self.cfg.max_new_tokens,
                stream_capacity: self.cfg.stream_capacity,
                now: self.clock.now(),
                wakeup: self.wakeup.as_ref(),
            },
        )
    }

    fn set_wakeup(&mut self, wakeup: Wakeup) {
        self.wakeup = Some(wakeup);
    }

    /// Run one scheduling iteration (same policy as the real engine):
    /// service stream flow control, then prefill/decode/idle. Virtual
    /// time advances one [`SIM_STEP`] per call, whatever the action —
    /// idle time is time too (it is what the idle timeout measures).
    fn step(&mut self) -> Result<Action> {
        self.clock.advance(SIM_STEP);
        self.service_streams()?;
        let state = policy::plan_admission(
            &self.cfg,
            &mut self.kv,
            &mut self.prefix,
            &mut self.metrics,
            self.router.peek_next(),
            self.router.queued(),
            self.batcher.len(),
        );
        let action = decide(state);
        match action {
            Action::Prefill => self.step_prefill()?,
            Action::Decode => self.step_decode()?,
            Action::Idle => {}
        }
        Ok(action)
    }

    /// Cancel a queued, running, or paused request; its KV blocks are
    /// released (stored tokens may survive in the prefix cache, held by
    /// the tree alone).
    fn cancel(&mut self, id: RequestId) -> Result<bool> {
        if let Some(mut seq) = self.router.take(id) {
            self.metrics.cancellations += 1;
            self.finish_seq(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        if self.paused.contains(&id) {
            self.paused.retain(|&p| p != id);
            let mut seq = self.seqs.remove(&id).unwrap();
            self.metrics.cancellations += 1;
            self.finish_seq(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        if let Some(mut seq) = self.seqs.remove(&id) {
            self.metrics.cancellations += 1;
            self.batcher.remove(id)?;
            self.finish_seq(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        Ok(false)
    }

    fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    fn is_idle(&self) -> bool {
        self.router.queued() == 0 && self.batcher.is_empty() && self.paused.is_empty()
    }

    fn queued(&self) -> usize {
        self.router.queued()
    }

    fn running(&self) -> usize {
        self.batcher.len()
    }

    fn paused(&self) -> usize {
        self.paused.len()
    }

    fn queue_depths(&self) -> Vec<(i32, usize)> {
        self.router.depths_by_priority()
    }

    fn encode(&self, text: &str) -> Vec<u32> {
        self.tokenizer.encode(text)
    }

    fn decode(&self, tokens: &[u32]) -> String {
        self.tokenizer.decode(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::GenEvent;
    use crate::sampling::SamplingParams;

    fn cfg(prefix_cache: bool) -> EngineConfig {
        EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 128,
            max_new_tokens: 16,
            prefix_cache,
            ..EngineConfig::default()
        }
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let mut a = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let mut b = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let pa = a
            .generate_text("determinism probe", 12, SamplingParams::default())
            .unwrap();
        let pb = b
            .generate_text("determinism probe", 12, SamplingParams::default())
            .unwrap();
        assert_eq!(pa, pb);
        assert!(a.metrics.tokens_generated >= 1);
        assert_eq!(a.metrics.tokens_generated, b.metrics.tokens_generated);
    }

    #[test]
    fn concurrent_requests_all_finish_with_usage() {
        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let mut handles = vec![];
        for p in ["alpha", "beta prompt", "gamma gamma gamma"] {
            let h = e.submit(GenRequest::text(p).max_new_tokens(10)).unwrap();
            handles.push((p, h));
        }
        e.run_to_completion().unwrap();
        for (p, h) in &handles {
            let (toks, fin) = h.drain();
            assert!(!toks.is_empty());
            let (_, usage) = fin.expect("finish event");
            assert_eq!(usage.generated_tokens, toks.len());
            // BOS + one id per byte.
            assert_eq!(usage.prompt_tokens, p.len() + 1);
            assert_eq!(
                usage.cached_prompt_tokens + usage.prefill_tokens,
                usage.prompt_tokens
            );
        }
        assert_eq!(e.metrics.requests_finished, 3);
        assert_eq!(e.kv_free_blocks() + e.prefix_cached_blocks(), 128);
    }

    #[test]
    fn repeated_prompt_hits_prefix_cache_with_identical_output() {
        // 32-char prompt -> 33 tokens with BOS -> 4 full blocks of 8.
        let prompt = "system: you are a helpful tool"; // 30 chars + BOS = 31
        let prompt = format!("{prompt}!!"); // 33 tokens with BOS

        let mut warm = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let first = warm
            .generate_text(&prompt, 8, SamplingParams::default())
            .unwrap();
        assert_eq!(warm.metrics.prefix_hits, 0, "cold first request");
        let second = warm
            .generate_text(&prompt, 8, SamplingParams::default())
            .unwrap();
        assert_eq!(warm.metrics.prefix_hits, 1, "second request must hit");
        assert!(warm.metrics.prefix_tokens_reused >= 32);
        assert_eq!(first, second, "cache hit must not change output");

        // And identical to a cache-disabled engine.
        let mut cold = SimEngine::new(cfg(false), SimSpec::default()).unwrap();
        let base = cold
            .generate_text(&prompt, 8, SamplingParams::default())
            .unwrap();
        let base2 = cold
            .generate_text(&prompt, 8, SamplingParams::default())
            .unwrap();
        assert_eq!(first, base);
        assert_eq!(second, base2);
        assert_eq!(cold.metrics.prefix_lookups, 0);
    }

    #[test]
    fn eviction_reclaims_cached_blocks_under_pressure() {
        // Tiny pool: the cache must give blocks back for new prompts.
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 10,
            max_new_tokens: 4,
            prefix_cache: true,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        for i in 0..6 {
            let prompt = format!("tenant-{i} prompt padded to some length....");
            let _h = e.submit(GenRequest::text(&prompt).max_new_tokens(3)).unwrap();
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 6);
        assert!(
            e.metrics.prefix_blocks_evicted > 0,
            "pool of 10 blocks cannot cache 6 distinct prompts without evicting"
        );
        assert_eq!(e.kv_free_blocks() + e.prefix_cached_blocks(), 10);
    }

    /// Find a prompt whose greedy generation runs at least `min_tokens`
    /// under the given budget — optionally requiring a printable-ASCII
    /// token in the output — and return it with that output. The hash
    /// model is deterministic, so this is a stable selection, not a
    /// retry loop.
    fn probe_prompt(min_tokens: usize, budget: usize, need_ascii: bool) -> (String, Vec<u32>) {
        for salt in 0..64u32 {
            let prompt = format!("generation probe {salt}");
            let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
            let h = e
                .submit(GenRequest::text(&prompt).max_new_tokens(budget))
                .unwrap();
            e.run_to_completion().unwrap();
            let (toks, _) = h.drain();
            let ascii_ok = !need_ascii || toks.iter().any(|t| (32..127).contains(t));
            if toks.len() >= min_tokens && ascii_ok {
                return (prompt, toks);
            }
        }
        panic!("no candidate prompt generated {min_tokens}+ tokens");
    }

    #[test]
    fn cancel_mid_decode_returns_kv_blocks_and_reports_cancelled() {
        // Prefix cache off so every block must return to the free list.
        let total = 128;
        let (prompt, _) = probe_prompt(6, 64, false);
        let mut e = SimEngine::new(cfg(false), SimSpec::default()).unwrap();
        let h = e.submit(GenRequest::text(&prompt).max_new_tokens(64)).unwrap();
        // Step until the request is decoding with a few tokens out.
        let mut tokens_seen = 0;
        let mut events = Vec::new();
        while tokens_seen < 4 {
            assert!(!e.is_idle(), "request finished before cancellation");
            e.step().unwrap();
            while let Ok(ev) = h.events.try_recv() {
                if matches!(ev, GenEvent::Token(_)) {
                    tokens_seen += 1;
                }
                events.push(ev);
            }
        }
        assert_eq!(e.running(), 1, "must be mid-decode");
        assert!(e.cancel(h.id).unwrap(), "known id cancels");
        assert!(!e.cancel(h.id).unwrap(), "second cancel is a no-op");
        assert!(e.is_idle(), "cancelled request leaves no work behind");
        while let Ok(ev) = h.events.try_recv() {
            events.push(ev);
        }
        let fin = events
            .iter()
            .find_map(|ev| match ev {
                GenEvent::Finished { reason, usage } => Some((*reason, *usage)),
                _ => None,
            })
            .expect("cancel must emit a finish event");
        assert_eq!(fin.0, FinishReason::Cancelled);
        assert_eq!(fin.1.generated_tokens, tokens_seen);
        assert_eq!(e.metrics.cancellations, 1);
        assert_eq!(
            e.kv_free_blocks(),
            total,
            "every KV block must return on cancel (cache off)"
        );
    }

    #[test]
    fn impossible_requests_rejected_at_submit() {
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 4, // 32-token pool
            max_new_tokens: 4,
            prefix_cache: true,
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        let long = "x".repeat(40); // 41 tokens with BOS: exceeds the pool
        assert!(e.submit(GenRequest::text(long).max_new_tokens(4)).is_err());
        assert!(
            e.submit(GenRequest::text("ok").max_new_tokens(0)).is_err(),
            "zero budget must be rejected"
        );
        assert!(e.is_idle(), "rejected requests leave no queued work");
    }

    #[test]
    fn cancel_queued_request_before_admission() {
        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let h = e.submit(GenRequest::text("never admitted").max_new_tokens(8)).unwrap();
        assert_eq!(e.queued(), 1);
        assert!(e.cancel(h.id).unwrap());
        assert_eq!(e.queued(), 0);
        let (toks, fin) = h.drain();
        assert!(toks.is_empty());
        assert_eq!(fin.unwrap().0, FinishReason::Cancelled);
        assert_eq!(e.kv_free_blocks() + e.prefix_cached_blocks(), 128);
    }

    #[test]
    fn stop_sequence_halts_generation() {
        // Self-selecting stop: take an unconstrained run, pick a
        // generated ASCII byte, and require a fresh engine to stop on
        // exactly that byte with a byte-identical prefix.
        let (prompt, full) = probe_prompt(2, 16, true);
        let (idx, stop_tok) = full
            .iter()
            .enumerate()
            .find(|(_, &t)| (32..127).contains(&t))
            .expect("hash model must emit some printable ASCII byte");
        let stop_str = String::from_utf8(vec![*stop_tok as u8]).unwrap();

        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let req = GenRequest::text(&prompt)
            .max_new_tokens(16)
            .stop(vec![stop_str]);
        let h = e.submit(req).unwrap();
        e.run_to_completion().unwrap();
        let (toks, fin) = h.drain();
        let (reason, usage) = fin.unwrap();
        assert_eq!(reason, FinishReason::Stop);
        assert_eq!(toks.len(), idx + 1, "stops right at the matched token");
        assert_eq!(toks[..], full[..idx + 1], "prefix must be byte-identical");
        assert_eq!(usage.generated_tokens, idx + 1);
    }

    #[test]
    fn higher_priority_request_admitted_first() {
        let cfg = EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 128,
            max_new_tokens: 16,
            max_running: 1,
            decode_buckets: vec![1],
            ..EngineConfig::default()
        };
        let mut e = SimEngine::new(cfg, SimSpec::default()).unwrap();
        let low = e
            .submit(GenRequest::text("low priority waits").max_new_tokens(4))
            .unwrap();
        let high = e
            .submit(
                GenRequest::text("high priority runs")
                    .priority(5)
                    .max_new_tokens(4),
            )
            .unwrap();
        e.step().unwrap(); // one prefill: must pick the high-priority one
        let (high_toks, _) = high.drain();
        let (low_toks, _) = low.drain();
        assert_eq!(high_toks.len(), 1, "high-priority got the first prefill");
        assert!(low_toks.is_empty(), "low-priority still queued");
        assert_eq!(e.queued(), 1);
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 2);
    }

    #[test]
    fn pause_decode_parks_slow_consumer_and_resumes_losslessly() {
        // Reference: same prompt, roomy stream (no backpressure).
        let (prompt, want) = probe_prompt(10, 16, false);

        let mut e = SimEngine::new(
            EngineConfig {
                stream_capacity: 3,
                backpressure: crate::config::BackpressurePolicy::PauseDecode,
                ..cfg(true)
            },
            SimSpec::default(),
        )
        .unwrap();
        let h = e.submit(GenRequest::text(&prompt).max_new_tokens(16)).unwrap();
        assert_eq!(h.capacity(), 3);
        // Never drain: the stream fills at exactly the capacity and the
        // sequence parks instead of buffering more.
        for _ in 0..20 {
            e.step().unwrap();
        }
        assert_eq!(e.paused(), 1, "slow consumer must be parked");
        assert_eq!(e.running(), 0);
        assert!(e.metrics.backpressure_pauses >= 1);
        assert_eq!(h.events.buffered(), 3, "bounded at the configured capacity");
        assert!(!e.is_idle(), "a paused request is still pending work");

        // Drain while stepping: the sequence resumes and completes with
        // the exact token stream of the unpressured run (greedy = no
        // sampler-order sensitivity; backpressure must be lossless).
        let mut got = Vec::new();
        let mut fin = None;
        let mut steps = 0;
        while fin.is_none() {
            e.step().unwrap();
            let (mut t, f) = h.drain();
            got.append(&mut t);
            if f.is_some() {
                fin = f;
            }
            steps += 1;
            assert!(steps < 10_000, "must terminate once the client drains");
        }
        assert!(e.metrics.backpressure_resumes >= 1);
        assert_eq!(got, want, "pause/resume must not lose or reorder tokens");
        assert!(e.is_idle());
    }

    #[test]
    fn drop_slow_finishes_with_overrun_and_reclaims_kv() {
        let (prompt, _) = probe_prompt(6, 16, false);
        let total = 128;
        let mut e = SimEngine::new(
            EngineConfig {
                stream_capacity: 2,
                backpressure: crate::config::BackpressurePolicy::DropSlow,
                ..cfg(false)
            },
            SimSpec::default(),
        )
        .unwrap();
        let h = e.submit(GenRequest::text(&prompt).max_new_tokens(16)).unwrap();
        // Never drain; DropSlow terminates the request, so completion
        // does not need the client's cooperation.
        e.run_to_completion().unwrap();
        let (toks, fin) = h.drain();
        let (reason, usage) = fin.expect("overrun still delivers the finish event");
        assert_eq!(reason, FinishReason::Overrun);
        assert_eq!(toks.len(), 2, "exactly the buffered tokens survive");
        assert_eq!(usage.generated_tokens, 2, "generation halted at the overrun");
        assert_eq!(e.metrics.backpressure_drops, 1);
        assert_eq!(e.kv_free_blocks(), total, "overrun reclaims KV (cache off)");
        assert!(e.is_idle());
    }

    #[test]
    fn dropped_handle_reclaims_request() {
        let (prompt, _) = probe_prompt(6, 16, false);
        let mut e = SimEngine::new(cfg(false), SimSpec::default()).unwrap();
        let h = e.submit(GenRequest::text(&prompt).max_new_tokens(16)).unwrap();
        e.step().unwrap(); // prefill
        assert_eq!(e.running(), 1);
        drop(h); // client goes away without cancelling
        e.step().unwrap(); // stream scan reaps the disconnect
        assert!(e.is_idle(), "disconnected client's work is reclaimed");
        assert_eq!(e.metrics.client_disconnects, 1);
        assert_eq!(e.kv_free_blocks(), 128);
    }

    #[test]
    fn stalled_stream_never_delays_other_requests() {
        let (slow_prompt, _) = probe_prompt(10, 16, false);
        let mut e = SimEngine::new(
            EngineConfig {
                stream_capacity: 2,
                backpressure: crate::config::BackpressurePolicy::PauseDecode,
                ..cfg(true)
            },
            SimSpec::default(),
        )
        .unwrap();
        let slow = e
            .submit(GenRequest::text(&slow_prompt).max_new_tokens(16))
            .unwrap();
        let fast = e
            .submit(GenRequest::text("fast concurrent stream").max_new_tokens(12))
            .unwrap();
        // Drain only the fast handle each step.
        let mut fast_tokens = Vec::new();
        let mut fast_fin = None;
        let mut steps = 0;
        while fast_fin.is_none() {
            e.step().unwrap();
            let (mut t, f) = fast.drain();
            fast_tokens.append(&mut t);
            if f.is_some() {
                fast_fin = f;
            }
            steps += 1;
            assert!(
                steps < 200,
                "fast stream must finish promptly while the slow one stalls"
            );
        }
        assert!(!fast_tokens.is_empty());
        // The slow request parks once its 2-slot buffer fills (it may
        // still be mid-fill if the fast stream finished very early).
        let mut extra = 0;
        while e.paused() == 0 && extra < 50 {
            e.step().unwrap();
            extra += 1;
        }
        assert_eq!(e.paused(), 1, "slow request parked, not finished");
        assert!(slow.events.buffered() <= 2, "slow buffer stays bounded");
        // Admin-style cleanup: cancelling the paused request works.
        assert!(e.cancel(slow.id).unwrap());
        assert!(e.is_idle());
        let (_, fin) = slow.drain();
        assert_eq!(fin.unwrap().0, FinishReason::Cancelled);
    }

    /// Serving knobs for the tiny-pool preemption tests: 6 KV blocks of
    /// 4 tokens, 2-token stream buffers, PauseDecode.
    fn tiny_pool_cfg() -> EngineConfig {
        EngineConfig {
            kv_block_tokens: 4,
            kv_total_blocks: 6,
            max_new_tokens: 12,
            max_running: 4,
            decode_buckets: vec![1, 2, 4],
            prefix_cache: false,
            stream_capacity: 2,
            backpressure: crate::config::BackpressurePolicy::PauseDecode,
            ..EngineConfig::default()
        }
    }

    /// A 7-char prompt (8 tokens with BOS = 3 blocks of 4) whose first
    /// generated tokens don't hit EOS (deterministic probe on a roomy
    /// pool), so a request over it reliably survives to parking.
    fn probe7(tag: u32) -> String {
        for salt in 0..512u32 {
            let p = format!("p{tag}x{salt:04}");
            assert_eq!(p.len(), 7);
            let mut e = SimEngine::new(
                EngineConfig {
                    kv_total_blocks: 64,
                    stream_capacity: 64,
                    ..tiny_pool_cfg()
                },
                SimSpec::default(),
            )
            .unwrap();
            let h = e.submit(GenRequest::text(&p).max_new_tokens(4)).unwrap();
            e.run_to_completion().unwrap();
            if h.drain().0.len() == 4 {
                return p;
            }
        }
        panic!("no probe prompt survives 4 tokens");
    }

    /// Submit a low-priority request over a probed prompt and step until
    /// its 2-slot stream fills and it parks (holding 3 KV blocks).
    fn park_slow(e: &mut SimEngine) -> SubmissionHandle {
        let h = e
            .submit(GenRequest::text(probe7(0)).priority(0).max_new_tokens(12))
            .unwrap();
        for _ in 0..6 {
            e.step().unwrap();
        }
        assert_eq!(e.paused(), 1, "slow request parked");
        h
    }

    #[test]
    fn paused_victim_preempted_under_kv_pressure() {
        // A parked slow client must not be able to wedge live work: its
        // KV is part of the preemption victim pool.
        let mut e = SimEngine::new(tiny_pool_cfg(), SimSpec::default()).unwrap();
        // Slow, low-priority request: admit, then park (never drained;
        // 2-token stream fills after one decode step). Holds 3 blocks.
        let slow = park_slow(&mut e);
        // High-priority request: admission takes the 3 free blocks, and
        // its first decode step needs headroom the parked request
        // holds — the parked, lower-priority sequence is the victim.
        let fast = e
            .submit(GenRequest::text(probe7(1)).priority(3).max_new_tokens(12))
            .unwrap();
        let mut fast_fin = None;
        let mut steps = 0;
        while fast_fin.is_none() {
            if !e.is_idle() {
                e.step().unwrap();
            }
            let (_, f) = fast.drain();
            if f.is_some() {
                fast_fin = f;
            }
            steps += 1;
            assert!(steps < 1_000, "fast request must complete");
        }
        assert_ne!(
            fast_fin.unwrap().0,
            FinishReason::Preempted,
            "high-priority request survives"
        );
        assert!(e.metrics.preemptions >= 1, "pressure forced a preemption");
        let (_, slow_fin) = slow.drain();
        assert_eq!(
            slow_fin.unwrap().0,
            FinishReason::Preempted,
            "the parked lower-priority request is the victim"
        );
        assert!(e.is_idle());
        assert_eq!(e.kv_free_blocks(), 6, "all blocks return (cache off)");
    }

    #[test]
    fn admission_blocked_by_parked_kv_preempts_strictly_lower_priority() {
        // Pool of 6 blocks (4 tokens each). A parked priority-0 request
        // holds 3; a priority-3 submission needs 4 (15 tokens + 1), so
        // admission is blocked with nothing decoding. The admission
        // path must preempt the parked victim rather than starve the
        // higher-priority waiter.
        let mut e = SimEngine::new(tiny_pool_cfg(), SimSpec::default()).unwrap();
        let slow = park_slow(&mut e);
        let big = e
            .submit(
                GenRequest::text("waiting-high!!") // 15 tokens w/ BOS
                    .priority(3)
                    .max_new_tokens(4),
            )
            .unwrap();
        let mut fin = None;
        let mut steps = 0;
        while fin.is_none() {
            if !e.is_idle() {
                e.step().unwrap();
            }
            let (_, f) = big.drain();
            if f.is_some() {
                fin = f;
            }
            steps += 1;
            assert!(steps < 1_000, "waiter must not starve behind parked KV");
        }
        assert_ne!(fin.unwrap().0, FinishReason::Preempted);
        assert_eq!(e.metrics.preemptions, 1, "parked victim preempted");
        assert_eq!(slow.drain().1.unwrap().0, FinishReason::Preempted);

        // Equal priority: parked work keeps its KV; the waiter queues.
        let mut e = SimEngine::new(tiny_pool_cfg(), SimSpec::default()).unwrap();
        let _slow = park_slow(&mut e);
        let _big = e
            .submit(
                GenRequest::text("waiting-same!!")
                    .priority(0)
                    .max_new_tokens(4),
            )
            .unwrap();
        for _ in 0..30 {
            e.step().unwrap();
        }
        assert_eq!(e.paused(), 1, "equal-priority parked work survives");
        assert_eq!(e.queued(), 1, "waiter stays queued");
        assert_eq!(e.metrics.preemptions, 0);
    }

    #[test]
    fn per_tenant_usage_recorded() {
        let mut e = SimEngine::new(cfg(true), SimSpec::default()).unwrap();
        let shared = "tenant system prompt shared across requests!";
        for i in 0..2 {
            let req = GenRequest::text(format!("{shared} {i}"))
                .tenant("acme")
                .max_new_tokens(4);
            let _h = e.submit(req).unwrap();
            e.run_to_completion().unwrap();
        }
        let _h = e
            .submit(GenRequest::text("unrelated").max_new_tokens(4))
            .unwrap();
        e.run_to_completion().unwrap();
        let acme = e.metrics.tenants.get("acme").expect("tenant recorded");
        assert_eq!(acme.requests_finished, 2);
        assert!(acme.generated_tokens >= 2);
        assert!(
            acme.cached_prompt_tokens >= 8,
            "second acme request reuses the shared prefix: {acme:?}"
        );
        let default = e.metrics.tenants.get("default").expect("default tenant");
        assert_eq!(default.requests_finished, 1);
        assert_eq!(default.cached_prompt_tokens, 0);
    }
}
