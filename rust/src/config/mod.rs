//! Model and engine configuration.
//!
//! `ModelConfig` mirrors the paper's Table 2 (plus the tiny model the
//! real CPU path serves); `EngineConfig` collects the serving knobs.

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Architecture hyperparameters of a served model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn_hidden: usize,
    /// Maximum context length (Table 2 "Context Length").
    pub context: usize,
    /// C1: unified scaling factor for the asynchronized softmax (§3).
    pub phi: f64,
    /// C1: safe exponent window (a, b) around phi.
    pub softmax_a: f64,
    pub softmax_b: f64,
}

fn default_a() -> f64 {
    -25.0
}
fn default_b() -> f64 {
    18.0
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// The four [N, K] linear shapes of Figure 9(a) (fused-QKV layout).
    pub fn linear_shapes(&self) -> [(&'static str, usize, usize); 4] {
        let (d, f) = (self.dim, self.ffn_hidden);
        [
            ("qkv_proj", 3 * d, d),
            ("o_proj", d, d),
            ("ffn1", f, d),
            ("ffn2", d, f),
        ]
    }

    /// Parameter count (decoder-only, untied embeddings).
    pub fn param_count(&self) -> usize {
        let (d, f, v, l) = (self.dim, self.ffn_hidden, self.vocab_size, self.n_layers);
        let per_layer = 4 * d * d + 3 * d * f + 2 * d;
        2 * v * d + l * per_layer + d
    }
}

/// Paper Table 2 model configurations.
pub fn paper_models() -> Vec<ModelConfig> {
    vec![
        ModelConfig {
            name: "llama2-7b".into(),
            vocab_size: 32000,
            dim: 4096,
            n_layers: 32,
            n_heads: 32,
            ffn_hidden: 11008,
            context: 4096,
            phi: 0.0,
            softmax_a: default_a(),
            softmax_b: default_b(),
        },
        ModelConfig {
            name: "llama2-13b".into(),
            vocab_size: 32000,
            dim: 5120,
            n_layers: 40,
            n_heads: 40,
            ffn_hidden: 13824,
            context: 4096,
            phi: 0.0,
            softmax_a: default_a(),
            softmax_b: default_b(),
        },
        ModelConfig {
            name: "opt-6.7b".into(),
            vocab_size: 50272,
            dim: 4096,
            n_layers: 32,
            n_heads: 32,
            ffn_hidden: 16384,
            context: 2048,
            phi: 0.0,
            softmax_a: default_a(),
            softmax_b: default_b(),
        },
        ModelConfig {
            name: "chatglm2-6b".into(),
            vocab_size: 65024,
            dim: 4096,
            n_layers: 28,
            n_heads: 32,
            ffn_hidden: 13696,
            context: 32768,
            phi: 0.0,
            softmax_a: default_a(),
            softmax_b: default_b(),
        },
    ]
}

pub fn paper_model(name: &str) -> Result<ModelConfig> {
    paper_models()
        .into_iter()
        .find(|m| m.name == name)
        .ok_or_else(|| Error::Config(format!("unknown paper model {name}")))
}

/// What an engine does when a request's bounded event stream is full
/// (the client consumes slower than the engine generates). See
/// `docs/ARCHITECTURE.md` for the full backpressure state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackpressurePolicy {
    /// Park the sequence: it keeps its KV blocks but releases its
    /// decode lane until the client drains below half capacity, then
    /// rejoins the batch. Memory stays bounded; no token is lost.
    PauseDecode,
    /// Finish the sequence early with
    /// [`crate::api::FinishReason::Overrun`] and reclaim its KV. The
    /// tokens already buffered remain deliverable.
    DropSlow,
}

impl BackpressurePolicy {
    /// Stable config-file name.
    pub fn as_str(self) -> &'static str {
        match self {
            BackpressurePolicy::PauseDecode => "pause_decode",
            BackpressurePolicy::DropSlow => "drop_slow",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pause_decode" => Ok(BackpressurePolicy::PauseDecode),
            "drop_slow" => Ok(BackpressurePolicy::DropSlow),
            other => Err(Error::Config(format!(
                "backpressure must be \"pause_decode\" or \"drop_slow\", got {other:?}"
            ))),
        }
    }
}

/// Serving-engine knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Directory holding manifest.json, weights/ and *.hlo.txt.
    pub artifacts_dir: String,
    /// Decode batch buckets available as compiled executables.
    pub decode_buckets: Vec<usize>,
    /// Prefill sequence-length buckets.
    pub prefill_buckets: Vec<usize>,
    /// KV pages per sequence pool (paged host store).
    pub kv_block_tokens: usize,
    pub kv_total_blocks: usize,
    /// Max sequences resident in the decode batch at once.
    pub max_running: usize,
    /// Hard cap on generated tokens per request.
    pub max_new_tokens: usize,
    /// Use the asynchronized-softmax decode artifacts (C1). When false
    /// the engine serves from the `_sync` baseline artifacts.
    pub async_softmax: bool,
    /// Enable the radix-tree prefix cache: requests reuse the KV of the
    /// longest cached prompt prefix instead of re-prefilling it.
    pub prefix_cache: bool,
    /// Enable prefix-shared grouped decode (CoDec-style): sequences in
    /// the decode batch that share a block-aligned KV prefix are
    /// surfaced to the backend as [`crate::core::DecodeGroup`]s so the
    /// shared prefix's attention is computed once per group instead of
    /// once per sequence. Off by default; backends that do not opt in
    /// fall back to the per-sequence path and outputs are byte-identical
    /// either way.
    pub grouped_decode: bool,
    /// Sampling temperature <= 0 means greedy.
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Token capacity of each request's bounded event stream: at most
    /// this many undelivered tokens buffer per request (the terminal
    /// finish event has its own slot). Must be >= 1.
    pub stream_capacity: usize,
    /// What to do when a request's stream is full.
    pub backpressure: BackpressurePolicy,
    /// How long a `pause_decode`-parked request may sit idle (client
    /// neither draining below the resume threshold nor disconnecting)
    /// before it is demoted to `overrun` and its KV reclaimed, in
    /// engine-clock milliseconds. 0 disables the timeout: parked work
    /// then holds KV until pressure preempts it. Bounds quiet-time KV
    /// occupancy even when nothing else wants the blocks.
    pub stream_idle_timeout_ms: u64,
    /// Per-tenant concurrency quota: at most this many of one tenant's
    /// requests may be in flight (queued + running + paused) at once;
    /// further submissions are rejected with a structured
    /// `quota_exceeded` error. 0 disables the quota.
    pub tenant_max_inflight: usize,
    /// Capacity of the always-on flight recorder: the ring of recent
    /// scheduling events kept for `{"admin": {"dump_flight": n}}` and
    /// for simulation-test violation reports (see `src/obs`). Oldest
    /// entries are evicted when full, so memory stays bounded. Must be
    /// >= 1; this also bounds how many *finished* request spans are
    /// retained for inspection.
    pub flight_recorder_capacity: usize,
    /// Decode chunking (Kernel-Looping-style orchestration
    /// amortization): each running sequence may generate up to this
    /// many tokens inside one scheduler step, with per-token early exit
    /// on stop sequences, `max_new_tokens`, and stream credit — credit
    /// is checked before every token, so the lossless-stream guarantee
    /// is unchanged. Policy work (stream servicing, admission planning,
    /// preemption scans, decode-group formation) runs once per chunk
    /// boundary instead of once per token. Must be >= 1; 1 is the
    /// classic one-token-per-step loop.
    pub decode_chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: "artifacts".into(),
            decode_buckets: vec![1, 2, 4, 8],
            prefill_buckets: vec![16, 32, 64],
            kv_block_tokens: 16,
            kv_total_blocks: 256,
            max_running: 8,
            max_new_tokens: 64,
            async_softmax: true,
            prefix_cache: true,
            grouped_decode: false,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            stream_capacity: 256,
            backpressure: BackpressurePolicy::PauseDecode,
            stream_idle_timeout_ms: 0,
            tenant_max_inflight: 0,
            flight_recorder_capacity: 512,
            decode_chunk: 1,
        }
    }
}

impl EngineConfig {
    /// Load overrides from a JSON file (missing fields keep defaults).
    pub fn from_json_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = crate::util::json::parse(&text)?;
        let d = EngineConfig::default();
        let usizes = |key: &str, dv: usize| -> usize {
            j.get(key).and_then(Json::as_usize).unwrap_or(dv)
        };
        let buckets = |key: &str, dv: &[usize]| -> Vec<usize> {
            j.get(key)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_else(|| dv.to_vec())
        };
        Ok(EngineConfig {
            artifacts_dir: j
                .get("artifacts_dir")
                .and_then(Json::as_str)
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            decode_buckets: buckets("decode_buckets", &d.decode_buckets),
            prefill_buckets: buckets("prefill_buckets", &d.prefill_buckets),
            kv_block_tokens: usizes("kv_block_tokens", d.kv_block_tokens),
            kv_total_blocks: usizes("kv_total_blocks", d.kv_total_blocks),
            max_running: usizes("max_running", d.max_running),
            max_new_tokens: usizes("max_new_tokens", d.max_new_tokens),
            async_softmax: j
                .get("async_softmax")
                .and_then(Json::as_bool)
                .unwrap_or(d.async_softmax),
            prefix_cache: j
                .get("prefix_cache")
                .and_then(Json::as_bool)
                .unwrap_or(d.prefix_cache),
            grouped_decode: j
                .get("grouped_decode")
                .and_then(Json::as_bool)
                .unwrap_or(d.grouped_decode),
            temperature: j
                .get("temperature")
                .and_then(Json::as_f64)
                .unwrap_or(d.temperature as f64) as f32,
            top_k: usizes("top_k", d.top_k),
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            stream_capacity: usizes("stream_capacity", d.stream_capacity),
            backpressure: match j.get("backpressure").and_then(Json::as_str) {
                Some(s) => BackpressurePolicy::parse(s)?,
                None => d.backpressure,
            },
            stream_idle_timeout_ms: usizes(
                "stream_idle_timeout_ms",
                d.stream_idle_timeout_ms as usize,
            ) as u64,
            tenant_max_inflight: usizes("tenant_max_inflight", d.tenant_max_inflight),
            flight_recorder_capacity: usizes(
                "flight_recorder_capacity",
                d.flight_recorder_capacity,
            ),
            decode_chunk: usizes("decode_chunk", d.decode_chunk),
        })
    }

    /// The parked-request idle timeout as a duration; `None` when
    /// disabled (`stream_idle_timeout_ms == 0`).
    pub fn stream_idle_timeout(&self) -> Option<std::time::Duration> {
        (self.stream_idle_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(self.stream_idle_timeout_ms))
    }

    pub fn validate(&self) -> Result<()> {
        if self.decode_buckets.is_empty() {
            return Err(Error::Config("decode_buckets empty".into()));
        }
        let mut sorted = self.decode_buckets.clone();
        sorted.sort_unstable();
        if sorted != self.decode_buckets {
            return Err(Error::Config("decode_buckets must be ascending".into()));
        }
        if self.kv_block_tokens == 0 || self.kv_total_blocks == 0 {
            return Err(Error::Config("kv cache must be non-empty".into()));
        }
        if self.max_new_tokens == 0 {
            return Err(Error::Config("max_new_tokens cap must be at least 1".into()));
        }
        if self.max_running > *self.decode_buckets.last().unwrap() {
            return Err(Error::Config(
                "max_running exceeds largest decode bucket".into(),
            ));
        }
        if self.stream_capacity == 0 {
            return Err(Error::Config(
                "stream_capacity must be at least 1".into(),
            ));
        }
        if self.flight_recorder_capacity == 0 {
            return Err(Error::Config(
                "flight_recorder_capacity must be at least 1".into(),
            ));
        }
        if self.decode_chunk == 0 {
            return Err(Error::Config(
                "decode_chunk must be at least 1".into(),
            ));
        }
        Ok(())
    }
}

/// How the fleet front end picks a replica for each submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Rotate through healthy replicas regardless of state.
    RoundRobin,
    /// Pick the replica with the fewest in-flight requests.
    LeastLoaded,
    /// Score replicas by `cache_vs_balance * cached-prefix fraction -
    /// (1 - cache_vs_balance) * normalized load` using the router's
    /// radix mirror of each replica's prefix cache.
    CacheAware,
}

impl RoutePolicy {
    /// Stable config-file name.
    pub fn as_str(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastLoaded => "least_loaded",
            RoutePolicy::CacheAware => "cache_aware",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "round_robin" => Ok(RoutePolicy::RoundRobin),
            "least_loaded" => Ok(RoutePolicy::LeastLoaded),
            "cache_aware" => Ok(RoutePolicy::CacheAware),
            other => Err(Error::Config(format!(
                "route policy must be \"round_robin\", \"least_loaded\" or \
                 \"cache_aware\", got {other:?}"
            ))),
        }
    }
}

/// Knobs of the replica fleet layered above `EngineCore` (see
/// `src/fleet`). Per-replica serving knobs stay in [`EngineConfig`];
/// this covers only what the router in front of the replicas decides.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of engine replicas the fleet owns. Must be >= 1.
    pub n_replicas: usize,
    /// Routing policy for new submissions.
    pub policy: RoutePolicy,
    /// Cache-aware tradeoff in `[0, 1]`: 1.0 routes purely on cached
    /// prefix length, 0.0 degenerates to least-loaded.
    pub cache_vs_balance: f64,
    /// Fleet-wide per-tenant concurrency quota across all replicas
    /// (on top of each replica's own `tenant_max_inflight`). 0
    /// disables it.
    pub tenant_max_inflight: usize,
    /// Per-tenant token-rate refill bucket: sustained budget in
    /// projected tokens (prompt + generation budget) per second of
    /// engine-clock time. 0.0 disables rate limiting.
    pub tenant_token_rate: f64,
    /// Burst capacity of the refill bucket, in tokens. Must be > 0
    /// when `tenant_token_rate` is set; a fresh tenant starts with a
    /// full bucket.
    pub tenant_token_burst: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            n_replicas: 2,
            policy: RoutePolicy::CacheAware,
            cache_vs_balance: 0.75,
            tenant_max_inflight: 0,
            tenant_token_rate: 0.0,
            tenant_token_burst: 0.0,
        }
    }
}

impl FleetConfig {
    pub fn validate(&self) -> Result<()> {
        if self.n_replicas == 0 {
            return Err(Error::Config("fleet needs at least one replica".into()));
        }
        if !self.cache_vs_balance.is_finite()
            || !(0.0..=1.0).contains(&self.cache_vs_balance)
        {
            return Err(Error::Config(
                "cache_vs_balance must be a finite value in [0, 1]".into(),
            ));
        }
        if !self.tenant_token_rate.is_finite() || self.tenant_token_rate < 0.0 {
            return Err(Error::Config(
                "tenant_token_rate must be finite and >= 0".into(),
            ));
        }
        if !self.tenant_token_burst.is_finite() || self.tenant_token_burst < 0.0 {
            return Err(Error::Config(
                "tenant_token_burst must be finite and >= 0".into(),
            ));
        }
        if self.tenant_token_rate > 0.0 && self.tenant_token_burst <= 0.0 {
            return Err(Error::Config(
                "tenant_token_burst must be > 0 when tenant_token_rate is set".into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_configs_match_paper() {
        let m = paper_model("llama2-7b").unwrap();
        assert_eq!((m.dim, m.n_heads, m.n_layers, m.context), (4096, 32, 32, 4096));
        let m = paper_model("llama2-13b").unwrap();
        assert_eq!((m.dim, m.n_heads, m.n_layers, m.context), (5120, 40, 40, 4096));
        let m = paper_model("opt-6.7b").unwrap();
        assert_eq!((m.dim, m.n_heads, m.n_layers, m.context), (4096, 32, 32, 2048));
        let m = paper_model("chatglm2-6b").unwrap();
        assert_eq!((m.dim, m.n_heads, m.n_layers, m.context), (4096, 32, 28, 32768));
    }

    #[test]
    fn llama7b_param_count_near_7b() {
        let m = paper_model("llama2-7b").unwrap();
        let p = m.param_count() as f64;
        assert!(p > 6.0e9 && p < 7.5e9, "param count {p}");
    }

    #[test]
    fn linear_shapes_match_fig9a() {
        // Figure 9(c): Llama2-7B shapes [12288,4096] (QKV), [4096,4096]
        // (O), [11008,4096] and [4096,11008] (FFN).
        let m = paper_model("llama2-7b").unwrap();
        let s = m.linear_shapes();
        assert_eq!(s[0], ("qkv_proj", 12288, 4096));
        assert_eq!(s[1], ("o_proj", 4096, 4096));
        assert_eq!(s[2], ("ffn1", 11008, 4096));
        assert_eq!(s[3], ("ffn2", 4096, 11008));
    }

    #[test]
    fn engine_config_validation() {
        let mut c = EngineConfig::default();
        c.validate().unwrap();
        c.decode_buckets = vec![4, 1];
        assert!(c.validate().is_err());
        c.decode_buckets = vec![1, 4];
        c.max_running = 100;
        assert!(c.validate().is_err());
        c.max_running = 4;
        c.stream_capacity = 0;
        assert!(c.validate().is_err(), "zero stream capacity rejected");
        c.stream_capacity = 256;
        c.flight_recorder_capacity = 0;
        assert!(c.validate().is_err(), "zero flight capacity rejected");
        c.flight_recorder_capacity = 512;
        c.decode_chunk = 0;
        assert!(c.validate().is_err(), "zero decode chunk rejected");
        c.decode_chunk = 4;
        c.validate().unwrap();
    }

    #[test]
    fn stream_idle_timeout_zero_means_disabled() {
        let mut c = EngineConfig::default();
        assert_eq!(c.stream_idle_timeout_ms, 0);
        assert_eq!(c.stream_idle_timeout(), None);
        c.stream_idle_timeout_ms = 250;
        assert_eq!(
            c.stream_idle_timeout(),
            Some(std::time::Duration::from_millis(250))
        );
        c.validate().unwrap();
    }

    #[test]
    fn backpressure_policy_names_round_trip() {
        for p in [BackpressurePolicy::PauseDecode, BackpressurePolicy::DropSlow] {
            assert_eq!(BackpressurePolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(BackpressurePolicy::parse("block_forever").is_err());
    }

    #[test]
    fn route_policy_names_round_trip() {
        for p in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::CacheAware,
        ] {
            assert_eq!(RoutePolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(RoutePolicy::parse("random").is_err());
    }

    #[test]
    fn fleet_config_validation() {
        let mut f = FleetConfig::default();
        f.validate().unwrap();
        f.n_replicas = 0;
        assert!(f.validate().is_err(), "zero replicas rejected");
        f.n_replicas = 2;
        f.cache_vs_balance = 1.5;
        assert!(f.validate().is_err(), "tradeoff outside [0,1] rejected");
        f.cache_vs_balance = f64::NAN;
        assert!(f.validate().is_err(), "NaN tradeoff rejected");
        f.cache_vs_balance = 0.5;
        f.tenant_token_rate = 100.0;
        assert!(f.validate().is_err(), "rate without burst rejected");
        f.tenant_token_burst = 50.0;
        f.validate().unwrap();
        f.tenant_token_rate = -1.0;
        assert!(f.validate().is_err(), "negative rate rejected");
    }
}
