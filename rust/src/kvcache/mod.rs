//! Paged KV-cache manager.
//!
//! Host-side paged storage of per-sequence K/V (vLLM-style block tables)
//! plus gather/scatter between the paged store and the dense
//! `[Lyr, B, H, Lmax, Dh]` batch tensors the decode artifacts consume.
//!
//! The engine keeps the dense tensor device-resident across decode steps
//! and only syncs with the paged store when the batch composition
//! changes; this module owns the real memory and the block accounting.

use std::collections::HashMap;

use crate::error::{Error, Result};

pub type SeqId = u64;

/// Geometry of the cache tensors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvGeometry {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    /// Tokens per block (page).
    pub block_tokens: usize,
    /// Dense batch tensor sequence capacity (artifact Lmax).
    pub max_seq: usize,
}

impl KvGeometry {
    /// f32 elements per token per K (or V): one column across layers/heads.
    pub fn token_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.head_dim
    }

    /// f32 elements of one block's K (or V) plane: [Lyr, H, BT, Dh].
    pub fn block_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.block_tokens * self.head_dim
    }

    /// Dense cache elements for a batch bucket: [Lyr, B, H, Lmax, Dh].
    pub fn dense_elems(&self, batch: usize) -> usize {
        self.n_layers * batch * self.n_heads * self.max_seq * self.head_dim
    }
}

/// One sequence's cache state.
#[derive(Debug, Clone)]
struct SeqEntry {
    blocks: Vec<usize>,
    /// Tokens currently stored.
    len: usize,
}

/// Paged KV store with block allocator.
pub struct KvCache {
    geo: KvGeometry,
    /// K and V slabs: total_blocks x block_elems each.
    k_data: Vec<f32>,
    v_data: Vec<f32>,
    free: Vec<usize>,
    seqs: HashMap<SeqId, SeqEntry>,
    total_blocks: usize,
}

impl KvCache {
    pub fn new(geo: KvGeometry, total_blocks: usize) -> Self {
        let be = geo.block_elems();
        KvCache {
            geo,
            k_data: vec![0.0; total_blocks * be],
            v_data: vec![0.0; total_blocks * be],
            free: (0..total_blocks).rev().collect(),
            seqs: HashMap::new(),
            total_blocks,
        }
    }

    pub fn geometry(&self) -> KvGeometry {
        self.geo
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.geo.block_tokens)
    }

    /// Register a sequence with capacity for `tokens` tokens.
    pub fn alloc_seq(&mut self, id: SeqId, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&id) {
            return Err(Error::KvCache(format!("seq {id} already allocated")));
        }
        if tokens > self.geo.max_seq {
            return Err(Error::KvCache(format!(
                "seq {id}: {tokens} tokens exceeds max_seq {}",
                self.geo.max_seq
            )));
        }
        let need = self.blocks_for(tokens.max(1));
        if need > self.free.len() {
            return Err(Error::KvCache(format!(
                "out of KV blocks: need {need}, free {}",
                self.free.len()
            )));
        }
        let blocks = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.seqs.insert(
            id,
            SeqEntry {
                blocks,
                len: 0,
            },
        );
        Ok(())
    }

    /// Grow a sequence's bookkeeping by one token (decode step),
    /// allocating a new block when it crosses a block boundary.
    pub fn grow_one(&mut self, id: SeqId) -> Result<()> {
        let geo_bt = self.geo.block_tokens;
        let max_seq = self.geo.max_seq;
        let need_block = {
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
            if e.len + 1 > max_seq {
                return Err(Error::KvCache(format!("seq {id} exceeds max_seq {max_seq}")));
            }
            e.len + 1 > e.blocks.len() * geo_bt
        };
        if need_block {
            let b = self
                .free
                .pop()
                .ok_or_else(|| Error::KvCache("out of KV blocks".into()))?;
            self.seqs.get_mut(&id).unwrap().blocks.push(b);
        }
        self.seqs.get_mut(&id).unwrap().len += 1;
        Ok(())
    }

    /// Release a sequence and all its blocks.
    pub fn free_seq(&mut self, id: SeqId) -> Result<()> {
        let e = self
            .seqs
            .remove(&id)
            .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
        self.free.extend(e.blocks);
        Ok(())
    }

    /// Write prefill output K/V (layout [Lyr, 1, H, S, Dh]) for the first
    /// `len` tokens of a freshly allocated sequence.
    pub fn write_prefill(&mut self, id: SeqId, k: &[f32], v: &[f32], s_padded: usize, len: usize) -> Result<()> {
        let g = self.geo;
        let expect = g.n_layers * g.n_heads * s_padded * g.head_dim;
        if k.len() != expect || v.len() != expect {
            return Err(Error::KvCache(format!(
                "prefill kv size {} != expected {expect}",
                k.len()
            )));
        }
        {
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
            let cap = e.blocks.len() * g.block_tokens;
            if len > cap {
                return Err(Error::KvCache(format!("seq {id}: {len} tokens > capacity {cap}")));
            }
        }
        for t in 0..len {
            self.copy_token_in(id, t, k, v, s_padded, t)?;
        }
        self.seqs.get_mut(&id).unwrap().len = len;
        Ok(())
    }

    /// Copy one token column from a [Lyr, 1, H, S, Dh] source into the
    /// paged store at position `pos`.
    fn copy_token_in(
        &mut self,
        id: SeqId,
        pos: usize,
        k: &[f32],
        v: &[f32],
        src_s: usize,
        src_t: usize,
    ) -> Result<()> {
        let g = self.geo;
        let e = self.seqs.get(&id).unwrap();
        let block = e.blocks[pos / g.block_tokens];
        let bt = pos % g.block_tokens;
        let be = g.block_elems();
        for l in 0..g.n_layers {
            for h in 0..g.n_heads {
                let src = ((l * g.n_heads + h) * src_s + src_t) * g.head_dim;
                let dst = block * be + ((l * g.n_heads + h) * g.block_tokens + bt) * g.head_dim;
                self.k_data[dst..dst + g.head_dim].copy_from_slice(&k[src..src + g.head_dim]);
                self.v_data[dst..dst + g.head_dim].copy_from_slice(&v[src..src + g.head_dim]);
            }
        }
        Ok(())
    }

    /// Gather sequences into dense batch tensors [Lyr, B, H, Lmax, Dh]
    /// (lane i <- lanes[i]; None lanes stay zero).
    pub fn gather_dense(
        &self,
        lanes: &[Option<SeqId>],
        batch: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let g = self.geo;
        let expect = g.dense_elems(batch);
        if k_out.len() != expect || v_out.len() != expect {
            return Err(Error::KvCache(format!(
                "dense buffer {} != expected {expect}",
                k_out.len()
            )));
        }
        if lanes.len() > batch {
            return Err(Error::KvCache("more lanes than batch".into()));
        }
        k_out.fill(0.0);
        v_out.fill(0.0);
        let be = g.block_elems();
        for (lane, slot) in lanes.iter().enumerate() {
            let Some(id) = *slot else { continue };
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
            for t in 0..e.len {
                let block = e.blocks[t / g.block_tokens];
                let bt = t % g.block_tokens;
                for l in 0..g.n_layers {
                    for h in 0..g.n_heads {
                        let src =
                            block * be + ((l * g.n_heads + h) * g.block_tokens + bt) * g.head_dim;
                        let dst = (((l * batch + lane) * g.n_heads + h) * g.max_seq + t)
                            * g.head_dim;
                        k_out[dst..dst + g.head_dim]
                            .copy_from_slice(&self.k_data[src..src + g.head_dim]);
                        v_out[dst..dst + g.head_dim]
                            .copy_from_slice(&self.v_data[src..src + g.head_dim]);
                    }
                }
            }
        }
        Ok(())
    }

    /// Scatter dense batch tensors back into the paged store (after the
    /// device-resident cache advanced by some decode steps). None lanes
    /// are skipped.
    pub fn scatter_dense(
        &mut self,
        lanes: &[Option<SeqId>],
        batch: usize,
        k_in: &[f32],
        v_in: &[f32],
    ) -> Result<()> {
        let g = self.geo;
        let expect = g.dense_elems(batch);
        if k_in.len() != expect || v_in.len() != expect {
            return Err(Error::KvCache(format!(
                "dense buffer {} != expected {expect}",
                k_in.len()
            )));
        }
        let be = g.block_elems();
        for (lane, slot) in lanes.iter().enumerate() {
            let Some(id) = *slot else { continue };
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?
                .clone();
            for t in 0..e.len {
                let block = e.blocks[t / g.block_tokens];
                let bt = t % g.block_tokens;
                for l in 0..g.n_layers {
                    for h in 0..g.n_heads {
                        let dst =
                            block * be + ((l * g.n_heads + h) * g.block_tokens + bt) * g.head_dim;
                        let src = (((l * batch + lane) * g.n_heads + h) * g.max_seq + t)
                            * g.head_dim;
                        self.k_data[dst..dst + g.head_dim]
                            .copy_from_slice(&k_in[src..src + g.head_dim]);
                        self.v_data[dst..dst + g.head_dim]
                            .copy_from_slice(&v_in[src..src + g.head_dim]);
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> KvGeometry {
        KvGeometry {
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            block_tokens: 8,
            max_seq: 32,
        }
    }

    fn prefill_data(g: &KvGeometry, s: usize, seed: f32) -> (Vec<f32>, Vec<f32>) {
        let n = g.n_layers * g.n_heads * s * g.head_dim;
        let k: Vec<f32> = (0..n).map(|i| seed + i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| -seed - i as f32).collect();
        (k, v)
    }

    #[test]
    fn alloc_free_accounting() {
        let mut c = KvCache::new(geo(), 8);
        assert_eq!(c.free_blocks(), 8);
        c.alloc_seq(1, 10).unwrap(); // 2 blocks of 8
        assert_eq!(c.used_blocks(), 2);
        c.alloc_seq(2, 1).unwrap();
        assert_eq!(c.used_blocks(), 3);
        c.free_seq(1).unwrap();
        assert_eq!(c.used_blocks(), 1);
        assert!(c.free_seq(1).is_err());
        assert!(c.alloc_seq(2, 4).is_err()); // double alloc
    }

    #[test]
    fn oom_when_exhausted() {
        let mut c = KvCache::new(geo(), 2);
        c.alloc_seq(1, 16).unwrap();
        assert!(c.alloc_seq(2, 1).is_err());
    }

    #[test]
    fn grow_one_crosses_block_boundary() {
        let mut c = KvCache::new(geo(), 4);
        c.alloc_seq(1, 8).unwrap();
        let (k, v) = prefill_data(&geo(), 8, 1.0);
        c.write_prefill(1, &k, &v, 8, 8).unwrap();
        assert_eq!(c.used_blocks(), 1);
        c.grow_one(1).unwrap(); // token 9 -> needs block 2
        assert_eq!(c.used_blocks(), 2);
        assert_eq!(c.seq_len(1), Some(9));
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let g = geo();
        let mut c = KvCache::new(g, 8);
        c.alloc_seq(7, 5).unwrap();
        let (k, v) = prefill_data(&g, 5, 100.0);
        c.write_prefill(7, &k, &v, 5, 5).unwrap();

        let batch = 2;
        let mut kd = vec![0.0; g.dense_elems(batch)];
        let mut vd = vec![0.0; g.dense_elems(batch)];
        c.gather_dense(&[Some(7)], batch, &mut kd, &mut vd).unwrap();
        // spot check: token 3, layer 1, head 0, dim 2
        let (l, h, t, d) = (1usize, 0usize, 3usize, 2usize);
        let src = ((l * g.n_heads + h) * 5 + t) * g.head_dim + d;
        let dst = (((l * batch + 0) * g.n_heads + h) * g.max_seq + t) * g.head_dim + d;
        assert_eq!(kd[dst], k[src]);
        assert_eq!(vd[dst], v[src]);

        // mutate the dense copy and scatter back
        kd[dst] = 9999.0;
        c.scatter_dense(&[Some(7)], batch, &kd, &vd).unwrap();
        let mut kd2 = vec![0.0; g.dense_elems(batch)];
        let mut vd2 = vec![0.0; g.dense_elems(batch)];
        c.gather_dense(&[Some(7)], batch, &mut kd2, &mut vd2).unwrap();
        assert_eq!(kd2[dst], 9999.0);
    }

    #[test]
    fn max_seq_enforced() {
        let mut c = KvCache::new(geo(), 64);
        assert!(c.alloc_seq(1, 33).is_err()); // > max_seq 32
        c.alloc_seq(2, 32).unwrap();
        let (k, v) = prefill_data(&geo(), 32, 0.0);
        c.write_prefill(2, &k, &v, 32, 32).unwrap();
        assert!(c.grow_one(2).is_err());
    }
}
