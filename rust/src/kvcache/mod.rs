//! Paged KV-cache manager with block sharing.
//!
//! Host-side paged storage of per-sequence K/V (vLLM-style block tables)
//! plus gather/scatter between the paged store and the dense
//! `[Lyr, B, H, Lmax, Dh]` batch tensors the decode artifacts consume.
//!
//! Blocks are *reference counted* so the prefix cache (`prefixcache`)
//! and multiple sequences can share the KV of a common prompt prefix:
//!
//! - `alloc_seq` gives a sequence private blocks (refcount 1 each).
//! - `alloc_seq_with_prefix` attaches already-filled shared blocks for
//!   the matched prefix (incref) and allocates fresh blocks only for
//!   the uncached tail.
//! - A block returns to the free list exactly when its last reference
//!   drops (`decref_block`), never before.
//! - Writes go through `ensure_writable`: writing into a block whose
//!   refcount is > 1 first copies it (copy-on-write), so shared data is
//!   immutable. This is what makes a partially-filled shared tail block
//!   safe to append into.
//! - `scatter_dense` skips shared blocks entirely: the decode artifacts
//!   only append at new positions, so a shared prefix block's contents
//!   on device are identical to the paged copy.
//!
//! The engine keeps the dense tensor device-resident across decode steps
//! and only syncs with the paged store when the batch composition
//! changes; this module owns the real memory and the block accounting.

use std::collections::HashMap;

use crate::error::{Error, Result};

pub type SeqId = u64;

/// Geometry of the cache tensors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvGeometry {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    /// Tokens per block (page).
    pub block_tokens: usize,
    /// Dense batch tensor sequence capacity (artifact Lmax).
    pub max_seq: usize,
}

impl KvGeometry {
    /// f32 elements per token per K (or V): one column across layers/heads.
    pub fn token_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.head_dim
    }

    /// f32 elements of one block's K (or V) plane: [Lyr, H, BT, Dh].
    pub fn block_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.block_tokens * self.head_dim
    }

    /// Dense cache elements for a batch bucket: [Lyr, B, H, Lmax, Dh].
    pub fn dense_elems(&self, batch: usize) -> usize {
        self.n_layers * batch * self.n_heads * self.max_seq * self.head_dim
    }
}

/// One sequence's cache state.
#[derive(Debug, Clone)]
struct SeqEntry {
    blocks: Vec<usize>,
    /// Tokens currently stored.
    len: usize,
}

/// Allocator accounting snapshot ([`KvCache::audit`]), consumed by the
/// simulation-test oracles: refcount conservation requires that every
/// block's refcount equal the number of owners visible here (sequence
/// tables) plus the prefix tree's retained references, and that a block
/// be on the free list exactly when its refcount is zero.
#[derive(Debug, Clone)]
pub struct KvAudit {
    pub total_blocks: usize,
    pub free_list: Vec<usize>,
    pub refcounts: Vec<u32>,
    /// Every live sequence's block table, ascending by sequence id.
    pub seq_blocks: Vec<(SeqId, Vec<usize>)>,
}

/// Paged KV store with a reference-counted block allocator.
pub struct KvCache {
    geo: KvGeometry,
    /// K and V slabs: total_blocks x block_elems each.
    k_data: Vec<f32>,
    v_data: Vec<f32>,
    free: Vec<usize>,
    /// Per-block reference count; 0 iff the block is on the free list.
    refcount: Vec<u32>,
    seqs: HashMap<SeqId, SeqEntry>,
    total_blocks: usize,
}

impl KvCache {
    pub fn new(geo: KvGeometry, total_blocks: usize) -> Self {
        let be = geo.block_elems();
        KvCache {
            geo,
            k_data: vec![0.0; total_blocks * be],
            v_data: vec![0.0; total_blocks * be],
            free: (0..total_blocks).rev().collect(),
            refcount: vec![0; total_blocks],
            seqs: HashMap::new(),
            total_blocks,
        }
    }

    pub fn geometry(&self) -> KvGeometry {
        self.geo
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// The sequence's block table (physical block ids in position order).
    pub fn seq_blocks(&self, id: SeqId) -> Option<Vec<usize>> {
        self.seqs.get(&id).map(|s| s.blocks.clone())
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    /// Current reference count of a physical block.
    pub fn block_refcount(&self, block: usize) -> u32 {
        self.refcount[block]
    }

    /// Full allocator snapshot for invariant auditing (the
    /// simulation-test refcount-conservation oracle): the free list,
    /// every block's refcount, and every sequence's block table.
    pub fn audit(&self) -> KvAudit {
        let mut seq_blocks: Vec<(SeqId, Vec<usize>)> = self
            .seqs
            .iter()
            .map(|(&id, e)| (id, e.blocks.clone()))
            .collect();
        seq_blocks.sort_by_key(|(id, _)| *id);
        KvAudit {
            total_blocks: self.total_blocks,
            free_list: self.free.clone(),
            refcounts: self.refcount.clone(),
            seq_blocks,
        }
    }

    /// Test-only fault hook: force one reference off a block, bypassing
    /// ownership — the double-free bug class. Exists so the simulation
    /// tests can prove their refcount oracle actually catches it.
    #[cfg(test)]
    pub fn debug_force_decref(&mut self, block: usize) {
        if self.refcount[block] > 0 {
            self.refcount[block] -= 1;
        }
        if self.refcount[block] == 0 {
            self.free.push(block);
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.geo.block_tokens)
    }

    // -----------------------------------------------------------------
    // Block-level reference counting
    // -----------------------------------------------------------------

    fn alloc_block(&mut self) -> Option<usize> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refcount[b], 0, "free block {b} had references");
        self.refcount[b] = 1;
        Some(b)
    }

    fn decref_block(&mut self, b: usize) {
        debug_assert!(self.refcount[b] > 0, "decref of free block {b}");
        self.refcount[b] -= 1;
        if self.refcount[b] == 0 {
            self.free.push(b);
        }
    }

    /// Add one reference to each block (prefix-cache retention, shared
    /// attach). The blocks must be live (refcount > 0).
    pub fn incref_blocks(&mut self, blocks: &[usize]) {
        for &b in blocks {
            debug_assert!(self.refcount[b] > 0, "incref of free block {b}");
            self.refcount[b] += 1;
        }
    }

    /// Drop one reference from each block; blocks whose last reference
    /// drops return to the free list.
    pub fn decref_blocks(&mut self, blocks: &[usize]) {
        for &b in blocks {
            self.decref_block(b);
        }
    }

    // -----------------------------------------------------------------
    // Sequence lifecycle
    // -----------------------------------------------------------------

    /// Register a sequence with capacity for `tokens` tokens.
    pub fn alloc_seq(&mut self, id: SeqId, tokens: usize) -> Result<()> {
        self.alloc_seq_with_prefix(id, tokens, &[], 0)
    }

    /// Register a sequence whose first `shared_tokens` tokens are served
    /// from `shared` blocks (attached by incref, not copied); fresh
    /// blocks are allocated only for the remaining capacity. The shared
    /// blocks must exactly cover `shared_tokens`
    /// (`shared.len() == ceil(shared_tokens / block_tokens)`) and the
    /// sequence starts with `len = shared_tokens`.
    pub fn alloc_seq_with_prefix(
        &mut self,
        id: SeqId,
        tokens: usize,
        shared: &[usize],
        shared_tokens: usize,
    ) -> Result<()> {
        if self.seqs.contains_key(&id) {
            return Err(Error::KvCache(format!("seq {id} already allocated")));
        }
        if tokens > self.geo.max_seq {
            return Err(Error::KvCache(format!(
                "seq {id}: {tokens} tokens exceeds max_seq {}",
                self.geo.max_seq
            )));
        }
        if shared_tokens > tokens {
            return Err(Error::KvCache(format!(
                "seq {id}: shared prefix {shared_tokens} exceeds capacity {tokens}"
            )));
        }
        if shared.len() != self.blocks_for(shared_tokens) {
            return Err(Error::KvCache(format!(
                "seq {id}: {} shared blocks cannot cover {shared_tokens} tokens",
                shared.len()
            )));
        }
        let total_needed = self.blocks_for(tokens.max(1)).max(shared.len());
        let need = total_needed - shared.len();
        if need > self.free.len() {
            return Err(Error::KvCache(format!(
                "out of KV blocks: need {need}, free {}",
                self.free.len()
            )));
        }
        self.incref_blocks(shared);
        // Block-table capacity covers the sequence's full possible life
        // (`max_seq`), so `grow_one`'s boundary pushes never reallocate
        // on the decode hot path (the zero-alloc-per-token invariant).
        let mut blocks =
            Vec::with_capacity(self.blocks_for(self.geo.max_seq.max(tokens.max(1))));
        blocks.extend_from_slice(shared);
        for _ in 0..need {
            blocks.push(self.alloc_block().expect("checked free count"));
        }
        self.seqs.insert(
            id,
            SeqEntry {
                blocks,
                len: shared_tokens,
            },
        );
        Ok(())
    }

    /// Grow a sequence's bookkeeping by one token (decode step),
    /// allocating a new block when it crosses a block boundary and
    /// copying a shared tail block before it is appended into.
    pub fn grow_one(&mut self, id: SeqId) -> Result<()> {
        let bt = self.geo.block_tokens;
        let max_seq = self.geo.max_seq;
        let (pos, n_blocks) = {
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
            if e.len + 1 > max_seq {
                return Err(Error::KvCache(format!("seq {id} exceeds max_seq {max_seq}")));
            }
            (e.len, e.blocks.len())
        };
        if pos / bt >= n_blocks {
            let b = self
                .alloc_block()
                .ok_or_else(|| Error::KvCache("out of KV blocks".into()))?;
            self.seqs.get_mut(&id).unwrap().blocks.push(b);
        } else {
            // The new token lands in an existing block; copy-on-write if
            // that block is shared (partially-filled shared tail).
            self.ensure_writable(id, pos)?;
        }
        self.seqs.get_mut(&id).unwrap().len += 1;
        Ok(())
    }

    /// Release a sequence; each of its blocks loses one reference.
    pub fn free_seq(&mut self, id: SeqId) -> Result<()> {
        let e = self
            .seqs
            .remove(&id)
            .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
        for &b in &e.blocks {
            self.decref_block(b);
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Writes (always through copy-on-write)
    // -----------------------------------------------------------------

    /// Make the block holding token `pos` privately owned by `id`,
    /// copying it first when shared. Returns the physical block id.
    fn ensure_writable(&mut self, id: SeqId, pos: usize) -> Result<usize> {
        let bt = self.geo.block_tokens;
        let idx = pos / bt;
        let block = {
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
            *e.blocks.get(idx).ok_or_else(|| {
                Error::KvCache(format!("seq {id}: pos {pos} beyond block table"))
            })?
        };
        if self.refcount[block] <= 1 {
            return Ok(block);
        }
        let fresh = self
            .alloc_block()
            .ok_or_else(|| Error::KvCache("out of KV blocks (copy-on-write)".into()))?;
        let be = self.geo.block_elems();
        self.k_data.copy_within(block * be..(block + 1) * be, fresh * be);
        self.v_data.copy_within(block * be..(block + 1) * be, fresh * be);
        self.decref_block(block); // still shared elsewhere: cannot hit 0
        self.seqs.get_mut(&id).unwrap().blocks[idx] = fresh;
        Ok(fresh)
    }

    /// Write prefill output K/V (layout [Lyr, 1, H, S, Dh]) for the first
    /// `len` tokens of a freshly allocated sequence.
    pub fn write_prefill(
        &mut self,
        id: SeqId,
        k: &[f32],
        v: &[f32],
        s_padded: usize,
        len: usize,
    ) -> Result<()> {
        self.write_prefill_range(id, k, v, s_padded, 0, len)
    }

    /// Write prefill output K/V for token positions `start..len` only —
    /// the prefix-reuse path: positions before `start` are already
    /// served by attached shared blocks and must not be rewritten.
    /// Sets the sequence length to `len`.
    pub fn write_prefill_range(
        &mut self,
        id: SeqId,
        k: &[f32],
        v: &[f32],
        s_padded: usize,
        start: usize,
        len: usize,
    ) -> Result<()> {
        let g = self.geo;
        let expect = g.n_layers * g.n_heads * s_padded * g.head_dim;
        if k.len() != expect || v.len() != expect {
            return Err(Error::KvCache(format!(
                "prefill kv size {} != expected {expect}",
                k.len()
            )));
        }
        if start > len {
            return Err(Error::KvCache(format!(
                "prefill range start {start} > len {len}"
            )));
        }
        {
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
            let cap = e.blocks.len() * g.block_tokens;
            if len > cap {
                return Err(Error::KvCache(format!("seq {id}: {len} tokens > capacity {cap}")));
            }
        }
        for t in start..len {
            self.copy_token_in(id, t, k, v, s_padded, t)?;
        }
        self.seqs.get_mut(&id).unwrap().len = len;
        Ok(())
    }

    /// Write one token column (layouts [Lyr, H, Dh]) at position `pos`.
    pub fn write_token(&mut self, id: SeqId, pos: usize, k: &[f32], v: &[f32]) -> Result<()> {
        let g = self.geo;
        let te = g.token_elems();
        if k.len() != te || v.len() != te {
            return Err(Error::KvCache(format!(
                "token kv size {} != expected {te}",
                k.len()
            )));
        }
        let block = self.ensure_writable(id, pos)?;
        let bt = pos % g.block_tokens;
        let be = g.block_elems();
        for l in 0..g.n_layers {
            for h in 0..g.n_heads {
                let src = (l * g.n_heads + h) * g.head_dim;
                let dst = block * be + ((l * g.n_heads + h) * g.block_tokens + bt) * g.head_dim;
                self.k_data[dst..dst + g.head_dim].copy_from_slice(&k[src..src + g.head_dim]);
                self.v_data[dst..dst + g.head_dim].copy_from_slice(&v[src..src + g.head_dim]);
            }
        }
        Ok(())
    }

    /// Read one token column (layouts [Lyr, H, Dh]) at position `pos`.
    pub fn read_token(
        &self,
        id: SeqId,
        pos: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let g = self.geo;
        let te = g.token_elems();
        if k_out.len() != te || v_out.len() != te {
            return Err(Error::KvCache(format!(
                "token kv size {} != expected {te}",
                k_out.len()
            )));
        }
        let e = self
            .seqs
            .get(&id)
            .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
        if pos >= e.len {
            return Err(Error::KvCache(format!(
                "seq {id}: read at {pos} beyond len {}",
                e.len
            )));
        }
        let block = e.blocks[pos / g.block_tokens];
        let bt = pos % g.block_tokens;
        let be = g.block_elems();
        for l in 0..g.n_layers {
            for h in 0..g.n_heads {
                let dst = (l * g.n_heads + h) * g.head_dim;
                let src = block * be + ((l * g.n_heads + h) * g.block_tokens + bt) * g.head_dim;
                k_out[dst..dst + g.head_dim].copy_from_slice(&self.k_data[src..src + g.head_dim]);
                v_out[dst..dst + g.head_dim].copy_from_slice(&self.v_data[src..src + g.head_dim]);
            }
        }
        Ok(())
    }

    /// Copy one token column from a [Lyr, 1, H, S, Dh] source into the
    /// paged store at position `pos`.
    fn copy_token_in(
        &mut self,
        id: SeqId,
        pos: usize,
        k: &[f32],
        v: &[f32],
        src_s: usize,
        src_t: usize,
    ) -> Result<()> {
        let g = self.geo;
        let block = self.ensure_writable(id, pos)?;
        let bt = pos % g.block_tokens;
        let be = g.block_elems();
        for l in 0..g.n_layers {
            for h in 0..g.n_heads {
                let src = ((l * g.n_heads + h) * src_s + src_t) * g.head_dim;
                let dst = block * be + ((l * g.n_heads + h) * g.block_tokens + bt) * g.head_dim;
                self.k_data[dst..dst + g.head_dim].copy_from_slice(&k[src..src + g.head_dim]);
                self.v_data[dst..dst + g.head_dim].copy_from_slice(&v[src..src + g.head_dim]);
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Dense gather/scatter
    // -----------------------------------------------------------------

    /// Gather sequences into dense batch tensors [Lyr, B, H, Lmax, Dh]
    /// (lane i <- `lanes[i]`; None lanes stay zero).
    pub fn gather_dense(
        &self,
        lanes: &[Option<SeqId>],
        batch: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let g = self.geo;
        let expect = g.dense_elems(batch);
        if k_out.len() != expect || v_out.len() != expect {
            return Err(Error::KvCache(format!(
                "dense buffer {} != expected {expect}",
                k_out.len()
            )));
        }
        if lanes.len() > batch {
            return Err(Error::KvCache("more lanes than batch".into()));
        }
        k_out.fill(0.0);
        v_out.fill(0.0);
        let be = g.block_elems();
        for (lane, slot) in lanes.iter().enumerate() {
            let Some(id) = *slot else { continue };
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?;
            for t in 0..e.len {
                let block = e.blocks[t / g.block_tokens];
                let bt = t % g.block_tokens;
                for l in 0..g.n_layers {
                    for h in 0..g.n_heads {
                        let src =
                            block * be + ((l * g.n_heads + h) * g.block_tokens + bt) * g.head_dim;
                        let dst = (((l * batch + lane) * g.n_heads + h) * g.max_seq + t)
                            * g.head_dim;
                        k_out[dst..dst + g.head_dim]
                            .copy_from_slice(&self.k_data[src..src + g.head_dim]);
                        v_out[dst..dst + g.head_dim]
                            .copy_from_slice(&self.v_data[src..src + g.head_dim]);
                    }
                }
            }
        }
        Ok(())
    }

    /// Scatter dense batch tensors back into the paged store (after the
    /// device-resident cache advanced by some decode steps). None lanes
    /// are skipped, and so are *shared* blocks (refcount > 1): decode
    /// only appends at fresh positions, so a shared prefix block's
    /// device copy is bit-identical to the paged copy and rewriting it
    /// would either waste work or (worse) mutate shared state.
    pub fn scatter_dense(
        &mut self,
        lanes: &[Option<SeqId>],
        batch: usize,
        k_in: &[f32],
        v_in: &[f32],
    ) -> Result<()> {
        let g = self.geo;
        let expect = g.dense_elems(batch);
        if k_in.len() != expect || v_in.len() != expect {
            return Err(Error::KvCache(format!(
                "dense buffer {} != expected {expect}",
                k_in.len()
            )));
        }
        let be = g.block_elems();
        for (lane, slot) in lanes.iter().enumerate() {
            let Some(id) = *slot else { continue };
            let e = self
                .seqs
                .get(&id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {id}")))?
                .clone();
            for t in 0..e.len {
                let block = e.blocks[t / g.block_tokens];
                if self.refcount[block] > 1 {
                    continue; // shared: immutable, contents already correct
                }
                let bt = t % g.block_tokens;
                for l in 0..g.n_layers {
                    for h in 0..g.n_heads {
                        let dst =
                            block * be + ((l * g.n_heads + h) * g.block_tokens + bt) * g.head_dim;
                        let src = (((l * batch + lane) * g.n_heads + h) * g.max_seq + t)
                            * g.head_dim;
                        self.k_data[dst..dst + g.head_dim]
                            .copy_from_slice(&k_in[src..src + g.head_dim]);
                        self.v_data[dst..dst + g.head_dim]
                            .copy_from_slice(&v_in[src..src + g.head_dim]);
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> KvGeometry {
        KvGeometry {
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            block_tokens: 8,
            max_seq: 32,
        }
    }

    fn prefill_data(g: &KvGeometry, s: usize, seed: f32) -> (Vec<f32>, Vec<f32>) {
        let n = g.n_layers * g.n_heads * s * g.head_dim;
        let k: Vec<f32> = (0..n).map(|i| seed + i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| -seed - i as f32).collect();
        (k, v)
    }

    fn token_col(g: &KvGeometry, seed: f32) -> (Vec<f32>, Vec<f32>) {
        let n = g.token_elems();
        let k: Vec<f32> = (0..n).map(|i| seed + i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| -seed - i as f32).collect();
        (k, v)
    }

    #[test]
    fn alloc_free_accounting() {
        let mut c = KvCache::new(geo(), 8);
        assert_eq!(c.free_blocks(), 8);
        c.alloc_seq(1, 10).unwrap(); // 2 blocks of 8
        assert_eq!(c.used_blocks(), 2);
        c.alloc_seq(2, 1).unwrap();
        assert_eq!(c.used_blocks(), 3);
        c.free_seq(1).unwrap();
        assert_eq!(c.used_blocks(), 1);
        assert!(c.free_seq(1).is_err());
        assert!(c.alloc_seq(2, 4).is_err()); // double alloc
    }

    #[test]
    fn oom_when_exhausted() {
        let mut c = KvCache::new(geo(), 2);
        c.alloc_seq(1, 16).unwrap();
        assert!(c.alloc_seq(2, 1).is_err());
    }

    #[test]
    fn grow_one_crosses_block_boundary() {
        let mut c = KvCache::new(geo(), 4);
        c.alloc_seq(1, 8).unwrap();
        let (k, v) = prefill_data(&geo(), 8, 1.0);
        c.write_prefill(1, &k, &v, 8, 8).unwrap();
        assert_eq!(c.used_blocks(), 1);
        c.grow_one(1).unwrap(); // token 9 -> needs block 2
        assert_eq!(c.used_blocks(), 2);
        assert_eq!(c.seq_len(1), Some(9));
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let g = geo();
        let mut c = KvCache::new(g, 8);
        c.alloc_seq(7, 5).unwrap();
        let (k, v) = prefill_data(&g, 5, 100.0);
        c.write_prefill(7, &k, &v, 5, 5).unwrap();

        let batch = 2;
        let mut kd = vec![0.0; g.dense_elems(batch)];
        let mut vd = vec![0.0; g.dense_elems(batch)];
        c.gather_dense(&[Some(7)], batch, &mut kd, &mut vd).unwrap();
        // spot check: token 3, layer 1, head 0, dim 2
        let (l, h, t, d) = (1usize, 0usize, 3usize, 2usize);
        let src = ((l * g.n_heads + h) * 5 + t) * g.head_dim + d;
        let dst = (((l * batch + 0) * g.n_heads + h) * g.max_seq + t) * g.head_dim + d;
        assert_eq!(kd[dst], k[src]);
        assert_eq!(vd[dst], v[src]);

        // mutate the dense copy and scatter back
        kd[dst] = 9999.0;
        c.scatter_dense(&[Some(7)], batch, &kd, &vd).unwrap();
        let mut kd2 = vec![0.0; g.dense_elems(batch)];
        let mut vd2 = vec![0.0; g.dense_elems(batch)];
        c.gather_dense(&[Some(7)], batch, &mut kd2, &mut vd2).unwrap();
        assert_eq!(kd2[dst], 9999.0);
    }

    #[test]
    fn max_seq_enforced() {
        let mut c = KvCache::new(geo(), 64);
        assert!(c.alloc_seq(1, 33).is_err()); // > max_seq 32
        c.alloc_seq(2, 32).unwrap();
        let (k, v) = prefill_data(&geo(), 32, 0.0);
        c.write_prefill(2, &k, &v, 32, 32).unwrap();
        assert!(c.grow_one(2).is_err());
    }

    #[test]
    fn shared_prefix_attach_and_release() {
        let g = geo();
        let mut c = KvCache::new(g, 8);
        // Donor fills two full blocks (16 tokens).
        c.alloc_seq(1, 16).unwrap();
        let (k, v) = prefill_data(&g, 16, 5.0);
        c.write_prefill(1, &k, &v, 16, 16).unwrap();
        let donor_blocks = c.seq_blocks(1).unwrap();
        assert_eq!(donor_blocks.len(), 2);

        // Second sequence shares the 16-token prefix, gets one fresh block.
        c.alloc_seq_with_prefix(2, 20, &donor_blocks, 16).unwrap();
        assert_eq!(c.seq_len(2), Some(16));
        assert_eq!(c.used_blocks(), 3, "only one fresh block allocated");
        for &b in &donor_blocks {
            assert_eq!(c.block_refcount(b), 2);
        }

        // Shared data visible through the sharer.
        let mut k0 = vec![0.0; g.token_elems()];
        let mut v0 = vec![0.0; g.token_elems()];
        c.read_token(2, 3, &mut k0, &mut v0).unwrap();
        let mut k1 = vec![0.0; g.token_elems()];
        let mut v1 = vec![0.0; g.token_elems()];
        c.read_token(1, 3, &mut k1, &mut v1).unwrap();
        assert_eq!(k0, k1);
        assert_eq!(v0, v1);

        // Freeing the donor keeps the shared blocks alive.
        c.free_seq(1).unwrap();
        for &b in &donor_blocks {
            assert_eq!(c.block_refcount(b), 1);
        }
        assert_eq!(c.used_blocks(), 3);
        // Last reference drops -> everything returns.
        c.free_seq(2).unwrap();
        assert_eq!(c.free_blocks(), 8);
    }

    #[test]
    fn cow_on_shared_partial_tail() {
        let g = geo();
        let mut c = KvCache::new(g, 8);
        // Donor with 12 tokens: block 0 full, block 1 half-filled.
        c.alloc_seq(1, 12).unwrap();
        let (k, v) = prefill_data(&g, 12, 9.0);
        c.write_prefill(1, &k, &v, 12, 12).unwrap();
        let donor_blocks = c.seq_blocks(1).unwrap();

        // Sharer attaches all 12 tokens (partial tail block shared).
        c.alloc_seq_with_prefix(2, 13, &donor_blocks, 12).unwrap();
        assert_eq!(c.seq_blocks(2).unwrap(), donor_blocks);
        assert_eq!(c.used_blocks(), 2, "partial tail covers capacity 13");

        // Appending token 12 must copy the tail block, not mutate it.
        c.grow_one(2).unwrap();
        let sharer_blocks = c.seq_blocks(2).unwrap();
        assert_eq!(sharer_blocks[0], donor_blocks[0], "full block still shared");
        assert_ne!(sharer_blocks[1], donor_blocks[1], "tail must be copied");
        assert_eq!(c.block_refcount(donor_blocks[1]), 1);
        let (kc, vc) = token_col(&g, 777.0);
        c.write_token(2, 12, &kc, &vc).unwrap();

        // Donor's copy of token 8..11 unchanged; sharer sees the copied
        // prefix tokens plus its new token.
        let mut kd = vec![0.0; g.token_elems()];
        let mut vd = vec![0.0; g.token_elems()];
        c.read_token(1, 11, &mut kd, &mut vd).unwrap();
        let mut ks = vec![0.0; g.token_elems()];
        let mut vs = vec![0.0; g.token_elems()];
        c.read_token(2, 11, &mut ks, &mut vs).unwrap();
        assert_eq!(kd, ks, "COW must carry the prefix contents over");
        c.read_token(2, 12, &mut ks, &mut vs).unwrap();
        assert_eq!(ks, kc);

        c.free_seq(1).unwrap();
        c.free_seq(2).unwrap();
        assert_eq!(c.free_blocks(), 8);
    }

    #[test]
    fn scatter_skips_shared_blocks() {
        let g = geo();
        let mut c = KvCache::new(g, 8);
        c.alloc_seq(1, 8).unwrap();
        let (k, v) = prefill_data(&g, 8, 3.0);
        c.write_prefill(1, &k, &v, 8, 8).unwrap();
        let blocks = c.seq_blocks(1).unwrap();
        c.alloc_seq_with_prefix(2, 8, &blocks, 8).unwrap();

        // Scatter garbage through seq 2: the shared block must not change.
        let batch = 1;
        let kd = vec![42.0; g.dense_elems(batch)];
        let vd = vec![42.0; g.dense_elems(batch)];
        c.scatter_dense(&[Some(2)], batch, &kd, &vd).unwrap();
        let mut k1 = vec![0.0; g.token_elems()];
        let mut v1 = vec![0.0; g.token_elems()];
        c.read_token(1, 0, &mut k1, &mut v1).unwrap();
        assert_ne!(k1[0], 42.0, "shared block mutated by scatter");
    }

    #[test]
    fn incref_decref_roundtrip() {
        let mut c = KvCache::new(geo(), 4);
        c.alloc_seq(1, 8).unwrap();
        let blocks = c.seq_blocks(1).unwrap();
        c.incref_blocks(&blocks); // e.g. the prefix cache retains them
        c.free_seq(1).unwrap();
        assert_eq!(c.used_blocks(), 1, "retained by the extra reference");
        c.decref_blocks(&blocks);
        assert_eq!(c.free_blocks(), 4);
    }
}
