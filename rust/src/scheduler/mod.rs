//! Prefill/decode scheduling policy.
//!
//! Decides, each engine iteration, whether to run a prefill (admitting a
//! queued request) or a decode step over the running batch. The policy
//! is prefill-priority up to `max_running` lanes (keeps the decode batch
//! full, which is where FlashDecoding++'s flat-GEMM wins live), with KV
//! headroom checks and preemption of the *youngest* running sequence on
//! KV exhaustion.

/// What the engine should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Admit + prefill the next queued request.
    Prefill,
    /// Run one decode step over the running set.
    Decode,
    /// Nothing to do.
    Idle,
}

/// Scheduler inputs for one decision.
#[derive(Debug, Clone, Copy)]
pub struct SchedState {
    pub queued: usize,
    pub running: usize,
    pub max_running: usize,
    /// Free KV blocks and the blocks a prefill of the next queued request
    /// would need.
    pub free_blocks: usize,
    pub next_prefill_blocks: usize,
}

/// The scheduling policy (pure function — proptest-able).
pub fn decide(s: SchedState) -> Action {
    let can_admit =
        s.queued > 0 && s.running < s.max_running && s.free_blocks >= s.next_prefill_blocks;
    if can_admit {
        Action::Prefill
    } else if s.running > 0 {
        Action::Decode
    } else if s.queued > 0 {
        // Queued but can't admit (KV pressure with nothing running):
        // decode can't help either; the engine must preempt/evict. Treat
        // as Prefill attempt so the engine surfaces the KV error path.
        Action::Prefill
    } else {
        Action::Idle
    }
}

/// Pick the victim for preemption: the *youngest* running sequence
/// (latest admission) loses its lane — it has the least sunk prefill
/// work. Returns its index in `running_ids`.
pub fn preemption_victim(running_ids: &[u64]) -> Option<usize> {
    if running_ids.is_empty() {
        None
    } else {
        // Admission order == lane order (Batcher preserves FIFO), so the
        // youngest is the last lane.
        Some(running_ids.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(queued: usize, running: usize, free: usize, need: usize) -> SchedState {
        SchedState {
            queued,
            running,
            max_running: 4,
            free_blocks: free,
            next_prefill_blocks: need,
        }
    }

    #[test]
    fn prefill_priority_when_room() {
        assert_eq!(decide(st(2, 1, 100, 4)), Action::Prefill);
    }

    #[test]
    fn decode_when_lanes_full() {
        assert_eq!(decide(st(2, 4, 100, 4)), Action::Decode);
    }

    #[test]
    fn decode_when_queue_empty() {
        assert_eq!(decide(st(0, 3, 100, 0)), Action::Decode);
    }

    #[test]
    fn idle_when_nothing() {
        assert_eq!(decide(st(0, 0, 100, 0)), Action::Idle);
    }

    #[test]
    fn kv_pressure_blocks_admission() {
        // Not enough free blocks for the next prefill -> keep decoding
        // (running seqs will finish and free blocks).
        assert_eq!(decide(st(1, 2, 1, 4)), Action::Decode);
    }

    #[test]
    fn kv_pressure_with_empty_running_surfaces_prefill() {
        assert_eq!(decide(st(1, 0, 0, 4)), Action::Prefill);
    }

    #[test]
    fn victim_is_youngest() {
        assert_eq!(preemption_victim(&[5, 9, 12]), Some(2));
        assert_eq!(preemption_victim(&[]), None);
    }
}
