//! Prefill/decode scheduling policy.
//!
//! Decides, each engine iteration, whether to run a prefill (admitting a
//! queued request) or a decode step over the running batch. The policy
//! is prefill-priority up to `max_running` lanes (keeps the decode batch
//! full, which is where FlashDecoding++'s flat-GEMM wins live), with KV
//! headroom checks and preemption on KV exhaustion.
//!
//! The policy is *cache-aware*: admission cost is charged only for the
//! blocks the next request cannot reuse from the prefix cache
//! (`cached_prefill_blocks`), so a request whose prompt is largely
//! cached can be admitted under KV pressure that would stall a cold
//! request. Preemption prefers victims whose blocks stay reusable in
//! the prefix cache — evicting them loses the least recomputation work.
//!
//! This module holds the *pure* decision functions ([`decide`],
//! [`preemption_victim`]); the stateful glue that computes their inputs
//! from the KV/prefix caches — admission, eviction, preemption census —
//! is shared by both engine implementations via [`crate::policy`].

use crate::kvcache::SeqId;

/// What the engine should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Admit + prefill the next queued request.
    Prefill,
    /// Run one decode step over the running set.
    Decode,
    /// Nothing to do.
    Idle,
}

/// Scheduler inputs for one decision.
#[derive(Debug, Clone, Copy)]
pub struct SchedState {
    pub queued: usize,
    pub running: usize,
    pub max_running: usize,
    /// Free KV blocks and the blocks a prefill of the next queued request
    /// would need.
    pub free_blocks: usize,
    pub next_prefill_blocks: usize,
    /// Blocks of the next queued request already resident in the prefix
    /// cache (attached by reference, not allocated): admission only has
    /// to find room for `next_prefill_blocks - cached_prefill_blocks`.
    pub cached_prefill_blocks: usize,
}

impl SchedState {
    /// Fresh blocks the next prefill actually needs to allocate.
    pub fn uncached_prefill_blocks(&self) -> usize {
        self.next_prefill_blocks
            .saturating_sub(self.cached_prefill_blocks)
    }
}

/// The scheduling policy (pure function — proptest-able).
pub fn decide(s: SchedState) -> Action {
    let can_admit = s.queued > 0
        && s.running < s.max_running
        && s.free_blocks >= s.uncached_prefill_blocks();
    if can_admit {
        Action::Prefill
    } else if s.running > 0 {
        Action::Decode
    } else if s.queued > 0 {
        // Queued but can't admit (KV pressure with nothing running):
        // decode can't help either; the engine must preempt/evict. Treat
        // as Prefill attempt so the engine surfaces the KV error path.
        Action::Prefill
    } else {
        Action::Idle
    }
}

/// One preemption candidate: a running or backpressure-paused sequence,
/// its request priority, whether it is currently parked, and how many
/// of its blocks would *stay reusable* (shared with the prefix cache or
/// other sequences) if it were evicted now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PreemptCandidate {
    pub id: SeqId,
    /// Request priority (higher = more important = preempted last).
    pub priority: i32,
    /// Parked by stream backpressure (holds KV but no decode lane).
    /// Within a priority level, parked victims lose before running
    /// ones: a stalled client's work is the cheapest to sacrifice.
    pub paused: bool,
    pub reusable_blocks: usize,
}

/// Pick the victim for preemption and return its *sequence id* (the
/// engine resolves id -> lane; lane order is a batcher detail that
/// preemption must not assume).
///
/// Victims are ordered by `(priority asc, paused first,
/// reusable_blocks desc, recency)`: the lowest-priority candidate
/// always loses first — a request is never preempted while a strictly
/// lower-priority victim exists. Within a priority level, parked
/// (backpressure-paused) sequences lose before running ones — live
/// decode progress is worth more than work a stalled client is not
/// consuming. Then the candidate with the most reusable blocks goes
/// first (its KV largely survives in the prefix cache, so preempting
/// it destroys the least work), and remaining ties go to the
/// *youngest* candidate (largest id — ids are assigned in submit
/// order), which has the least sunk decode progress.
pub fn preemption_victim(candidates: &[PreemptCandidate]) -> Option<SeqId> {
    use std::cmp::Reverse;
    candidates
        .iter()
        .min_by_key(|c| {
            (
                c.priority,
                !c.paused,
                Reverse(c.reusable_blocks),
                Reverse(c.id),
            )
        })
        .map(|c| c.id)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(queued: usize, running: usize, free: usize, need: usize) -> SchedState {
        SchedState {
            queued,
            running,
            max_running: 4,
            free_blocks: free,
            next_prefill_blocks: need,
            cached_prefill_blocks: 0,
        }
    }

    fn cand(id: SeqId, reusable: usize) -> PreemptCandidate {
        PreemptCandidate {
            id,
            priority: 0,
            paused: false,
            reusable_blocks: reusable,
        }
    }

    #[test]
    fn prefill_priority_when_room() {
        assert_eq!(decide(st(2, 1, 100, 4)), Action::Prefill);
    }

    #[test]
    fn decode_when_lanes_full() {
        assert_eq!(decide(st(2, 4, 100, 4)), Action::Decode);
    }

    #[test]
    fn decode_when_queue_empty() {
        assert_eq!(decide(st(0, 3, 100, 0)), Action::Decode);
    }

    #[test]
    fn idle_when_nothing() {
        assert_eq!(decide(st(0, 0, 100, 0)), Action::Idle);
    }

    #[test]
    fn kv_pressure_blocks_admission() {
        // Not enough free blocks for the next prefill -> keep decoding
        // (running seqs will finish and free blocks).
        assert_eq!(decide(st(1, 2, 1, 4)), Action::Decode);
    }

    #[test]
    fn kv_pressure_with_empty_running_surfaces_prefill() {
        assert_eq!(decide(st(1, 0, 0, 4)), Action::Prefill);
    }

    #[test]
    fn cached_prefix_unlocks_admission_under_pressure() {
        // 4 blocks needed, only 1 free: a cold request stalls...
        assert_eq!(decide(st(1, 2, 1, 4)), Action::Decode);
        // ...but with 3 of the 4 blocks cached, 1 free block suffices.
        let s = SchedState {
            cached_prefill_blocks: 3,
            ..st(1, 2, 1, 4)
        };
        assert_eq!(s.uncached_prefill_blocks(), 1);
        assert_eq!(decide(s), Action::Prefill);
    }

    #[test]
    fn victim_is_youngest_on_ties() {
        let c = [cand(5, 0), cand(9, 0), cand(12, 0)];
        assert_eq!(preemption_victim(&c), Some(12));
        assert_eq!(preemption_victim(&[]), None);
    }

    #[test]
    fn victim_prefers_most_reusable_blocks() {
        // Sequence 9's KV survives in the prefix cache: preempt it even
        // though 12 is younger.
        let c = [cand(5, 1), cand(9, 3), cand(12, 0)];
        assert_eq!(preemption_victim(&c), Some(9));
    }

    #[test]
    fn victim_priority_dominates_reusable_blocks() {
        // Sequence 5 has the most reusable blocks, but sequence 9 has
        // strictly lower priority: priority always decides first.
        let c = [
            PreemptCandidate {
                id: 5,
                priority: 2,
                paused: false,
                reusable_blocks: 7,
            },
            PreemptCandidate {
                id: 9,
                priority: -1,
                paused: false,
                reusable_blocks: 0,
            },
            PreemptCandidate {
                id: 12,
                priority: 0,
                paused: false,
                reusable_blocks: 3,
            },
        ];
        assert_eq!(preemption_victim(&c), Some(9));
    }

    fn mk(id: SeqId, priority: i32, paused: bool, reusable: usize) -> PreemptCandidate {
        PreemptCandidate {
            id,
            priority,
            paused,
            reusable_blocks: reusable,
        }
    }

    #[test]
    fn victim_within_priority_level_uses_reusable_then_recency() {
        // Same priority: most reusable blocks loses.
        let c = [mk(5, 1, false, 1), mk(9, 1, false, 3), mk(12, 5, false, 9)];
        assert_eq!(preemption_victim(&c), Some(9));
        // Same priority and reusable count: youngest (largest id) loses.
        let c = [mk(5, 1, false, 2), mk(9, 1, false, 2), mk(12, 5, false, 9)];
        assert_eq!(preemption_victim(&c), Some(9));
    }

    #[test]
    fn victim_prefers_parked_over_running_within_a_level() {
        // Same priority: the parked candidate loses first, even when the
        // running one has more reusable blocks or is younger.
        let c = [mk(5, 1, true, 0), mk(9, 1, false, 4)];
        assert_eq!(preemption_victim(&c), Some(5));
        let c = [mk(5, 1, false, 0), mk(9, 1, true, 0)];
        assert_eq!(preemption_victim(&c), Some(9));
        // But priority still dominates: a running lower-priority victim
        // loses before a parked higher-priority one.
        let c = [mk(5, 0, false, 0), mk(9, 1, true, 0)];
        assert_eq!(preemption_victim(&c), Some(5));
        // Among parked candidates, the usual reusable/recency order.
        let c = [mk(5, 1, true, 3), mk(9, 1, true, 1), mk(12, 1, true, 3)];
        assert_eq!(preemption_victim(&c), Some(12));
    }
}
