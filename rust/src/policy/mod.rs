//! Shared admission / eviction / preemption policy.
//!
//! [`crate::engine::Engine`] and [`crate::simengine::SimEngine`] used
//! to carry verbatim copies of this logic; any fix applied to one could
//! silently miss the other (the drift hazard ROADMAP flagged). Both now
//! call these free functions over the same cache/scheduler state, so
//! the sim twin *cannot* drift from the real engine:
//!
//! - [`admit_kv`]: prefix attach first, then eviction of the uncached
//!   shortfall + retry, then — with nothing running to wait for — a
//!   cold allocation with the cache fully evictable.
//! - [`plan_admission`]: the pre-decision pressure-eviction pass that
//!   feeds [`crate::scheduler::decide`] a [`SchedState`].
//! - [`reclaim_decode_headroom`] + [`preempt_candidates`]: decode-time
//!   block reclamation, preferring cached-block eviction over
//!   preemption, and the reusable-block census the preemption victim
//!   choice ([`crate::scheduler::preemption_victim`]) runs on.
//!
//! The pure decision functions (`decide`, `preemption_victim`) stay in
//! [`crate::scheduler`]; this module owns the stateful glue between
//! them and the KV / prefix caches.

use crate::config::EngineConfig;
use crate::error::Result;
use crate::kvcache::{KvCache, SeqId};
use crate::metrics::EngineMetrics;
use crate::prefixcache::{PrefixCache, PrefixMatch};
use crate::router::Sequence;
use crate::scheduler::{PreemptCandidate, SchedState};

/// Matched prefix usable for reuse: capped so at least the prompt's
/// last token still runs through prefill (its logits row seeds the
/// first generated token), floored to whole blocks.
pub fn usable_prefix(block_tokens: usize, prompt_len: usize, matched: usize) -> usize {
    (matched.min(prompt_len.saturating_sub(1)) / block_tokens) * block_tokens
}

/// Radix-tree lookup for a prompt, truncated to the usable range.
pub fn lookup_prefix(cfg: &EngineConfig, prefix: &mut PrefixCache, prompt: &[u32]) -> PrefixMatch {
    if !cfg.prefix_cache {
        return PrefixMatch::default();
    }
    let m = prefix.match_prefix(prompt);
    let usable = usable_prefix(cfg.kv_block_tokens, prompt.len(), m.tokens);
    if usable == 0 {
        return PrefixMatch::default();
    }
    PrefixMatch {
        blocks: m.blocks[..usable / cfg.kv_block_tokens].to_vec(),
        tokens: usable,
    }
}

/// Admit a sequence's KV: prefix attach first, then eviction of the
/// uncached shortfall + retry, then — with nothing running to wait
/// for — a cold allocation with the cache fully evictable. Returns the
/// attached match, `Ok(None)` when admission should wait for decode to
/// free blocks, or `Err` when truly stuck.
///
/// Attach-before-evict ordering matters throughout: matched blocks are
/// refcount-1 (tree-only) until the alloc increfs them, so eviction
/// must never run between a successful match and its attach; every
/// eviction below is followed by a *fresh* match.
pub fn admit_kv(
    cfg: &EngineConfig,
    kv: &mut KvCache,
    prefix: &mut PrefixCache,
    metrics: &mut EngineMetrics,
    running_empty: bool,
    id: SeqId,
    prompt: &[u32],
) -> Result<Option<PrefixMatch>> {
    let len = prompt.len();
    let need = (len + 1).div_ceil(cfg.kv_block_tokens);
    let matched = lookup_prefix(cfg, prefix, prompt);
    if kv
        .alloc_seq_with_prefix(id, len + 1, &matched.blocks, matched.tokens)
        .is_ok()
    {
        return Ok(Some(matched));
    }
    // Only the *uncached* shortfall needs reclaiming: matched blocks
    // attach by incref, they are not allocated.
    let want = need
        .saturating_sub(matched.blocks.len())
        .saturating_sub(kv.free_blocks());
    let freed = prefix.evict(want, kv);
    metrics.prefix_blocks_evicted += freed as u64;
    let matched = lookup_prefix(cfg, prefix, prompt);
    if kv
        .alloc_seq_with_prefix(id, len + 1, &matched.blocks, matched.tokens)
        .is_ok()
    {
        return Ok(Some(matched));
    }
    if !running_empty {
        return Ok(None);
    }
    // Nothing running will ever free blocks: drop every cache claim and
    // admit cold (or surface the allocator's error).
    let freed = prefix.evict(need, kv);
    metrics.prefix_blocks_evicted += freed as u64;
    kv.alloc_seq(id, len + 1)?;
    Ok(Some(PrefixMatch::default()))
}

/// Record one admission's prefix-cache accounting (lookup, hit, reused
/// vs computed prompt tokens) and the sequence's own usage split.
pub fn note_admission(
    cfg: &EngineConfig,
    metrics: &mut EngineMetrics,
    seq: &mut Sequence,
    matched_tokens: usize,
) {
    if cfg.prefix_cache {
        metrics.prefix_lookups += 1;
        if matched_tokens > 0 {
            metrics.prefix_hits += 1;
        }
    }
    metrics.prefix_tokens_reused += matched_tokens as u64;
    metrics.prefill_tokens_computed += (seq.prompt.len() - matched_tokens) as u64;
    seq.cached_prompt_tokens = matched_tokens;
    seq.admitted = true;
}

/// Blocks the next queued prefill needs and how many are cached (a
/// peek: no LRU touch, no attach).
pub fn admission_outlook(
    cfg: &EngineConfig,
    prefix: &PrefixCache,
    next: Option<&Sequence>,
) -> (usize, usize) {
    match next {
        Some(s) => {
            let bt = cfg.kv_block_tokens;
            let need = (s.prompt.len() + 1).div_ceil(bt);
            let cached = if cfg.prefix_cache {
                usable_prefix(bt, s.prompt.len(), prefix.peek_match_tokens(&s.prompt)) / bt
            } else {
                0
            };
            (need, cached)
        }
        None => (0, 0),
    }
}

/// Build the scheduler's input for one decision, first reclaiming
/// cached (refcount-1) blocks under admission pressure — but only when
/// admission is actually possible (a full running set gets nothing from
/// eviction), and only after refreshing the head request's matched path
/// in the LRU so eviction prefers other entries over the prefix about
/// to be reused.
pub fn plan_admission(
    cfg: &EngineConfig,
    kv: &mut KvCache,
    prefix: &mut PrefixCache,
    metrics: &mut EngineMetrics,
    next: Option<&Sequence>,
    queued: usize,
    running: usize,
) -> SchedState {
    let (next_blocks, mut cached_blocks) = admission_outlook(cfg, prefix, next);
    let uncached = next_blocks.saturating_sub(cached_blocks);
    let admission_possible = next_blocks > 0 && running < cfg.max_running;
    if admission_possible && kv.free_blocks() < uncached {
        if let Some(s) = next {
            let _ = prefix.match_prefix(&s.prompt);
        }
        let want = uncached - kv.free_blocks();
        let freed = prefix.evict(want, kv);
        metrics.prefix_blocks_evicted += freed as u64;
        if freed > 0 {
            // Eviction may still have trimmed blocks the peek counted
            // as cached — re-peek so the policy decides on live state.
            cached_blocks = admission_outlook(cfg, prefix, next).1;
        }
    }
    SchedState {
        queued,
        running,
        max_running: cfg.max_running,
        free_blocks: kv.free_blocks(),
        next_prefill_blocks: next_blocks,
        cached_prefill_blocks: cached_blocks,
    }
}

/// Decode-time KV headroom: each running sequence may need one fresh
/// block this step. Reclaim cached prefix blocks first (even for a lone
/// sequence — tree-held blocks are reclaimable memory). Returns `true`
/// when the caller must preempt a running sequence (still short, and at
/// least two running) and call again.
pub fn reclaim_decode_headroom(
    kv: &mut KvCache,
    prefix: &mut PrefixCache,
    metrics: &mut EngineMetrics,
    running: usize,
) -> bool {
    if kv.free_blocks() >= running {
        return false;
    }
    let want = running - kv.free_blocks();
    let freed = prefix.evict(want, kv);
    metrics.prefix_blocks_evicted += freed as u64;
    kv.free_blocks() < running && running > 1
}

/// The reusable-block census preemption runs on: for every running
/// sequence, how many of its blocks would *stay reusable* (shared with
/// the prefix cache or other sequences) if it were evicted now.
pub fn preempt_candidates(kv: &KvCache, running_ids: &[SeqId]) -> Vec<PreemptCandidate> {
    running_ids
        .iter()
        .map(|&id| {
            let reusable = kv
                .seq_blocks(id)
                .map(|bs| bs.iter().filter(|&&b| kv.block_refcount(b) > 1).count())
                .unwrap_or(0);
            PreemptCandidate {
                id,
                reusable_blocks: reusable,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::InferenceEngine;
    use crate::kvcache::KvGeometry;
    use crate::scheduler::{decide, Action};

    /// Compile-time proof that both engines expose the one shared
    /// surface this policy is written for (the trait bound fails to
    /// resolve if either implementation drifts off it).
    #[test]
    fn both_engines_implement_inference_engine() {
        fn requires_engine<E: InferenceEngine>() {}
        let _real = requires_engine::<crate::engine::Engine>;
        let _sim = requires_engine::<crate::simengine::SimEngine>;
    }

    fn cfg(bt: usize, blocks: usize) -> EngineConfig {
        EngineConfig {
            kv_block_tokens: bt,
            kv_total_blocks: blocks,
            ..EngineConfig::default()
        }
    }

    fn kv(bt: usize, blocks: usize) -> KvCache {
        KvCache::new(
            KvGeometry {
                n_layers: 1,
                n_heads: 1,
                head_dim: 2,
                block_tokens: bt,
                max_seq: 256,
            },
            blocks,
        )
    }

    #[test]
    fn usable_prefix_reserves_last_token_and_floors_to_blocks() {
        // Full-prompt match: last token must still prefill.
        assert_eq!(usable_prefix(4, 8, 8), 4);
        assert_eq!(usable_prefix(4, 9, 8), 8);
        assert_eq!(usable_prefix(4, 9, 3), 0, "sub-block match unusable");
        assert_eq!(usable_prefix(4, 0, 0), 0);
    }

    #[test]
    fn admit_kv_attaches_cached_prefix() {
        let c = cfg(4, 16);
        let mut kv = kv(4, 16);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        // Seed the cache with a donor's prompt blocks.
        let prompt: Vec<u32> = (0..12).collect();
        kv.alloc_seq(1, 12).unwrap();
        let blocks = kv.seq_blocks(1).unwrap();
        pc.insert(&prompt, &blocks, &mut kv);
        kv.free_seq(1).unwrap();

        let got = admit_kv(&c, &mut kv, &mut pc, &mut m, true, 2, &prompt)
            .unwrap()
            .expect("admission must succeed");
        // 12-token prompt: 2 full blocks usable (last token reserved).
        assert_eq!(got.tokens, 8);
        assert_eq!(got.blocks.len(), 2);
        assert!(kv.contains(2));
    }

    #[test]
    fn admit_kv_evicts_cache_for_cold_prompt_when_nothing_runs() {
        let c = cfg(4, 4);
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        // Fill the whole pool with a cached prompt.
        let cached_prompt: Vec<u32> = (100..116).collect();
        kv.alloc_seq(1, 16).unwrap();
        pc.insert(&cached_prompt, &kv.seq_blocks(1).unwrap(), &mut kv);
        kv.free_seq(1).unwrap();
        assert_eq!(kv.free_blocks(), 0);

        // A disjoint cold prompt must still admit: the cache gives its
        // blocks back.
        let cold: Vec<u32> = (200..212).collect();
        let got = admit_kv(&c, &mut kv, &mut pc, &mut m, true, 2, &cold)
            .unwrap()
            .expect("cold admission must evict and succeed");
        assert_eq!(got.tokens, 0);
        assert!(m.prefix_blocks_evicted > 0);
        assert!(kv.contains(2));
    }

    #[test]
    fn admit_kv_waits_when_decode_can_free_blocks() {
        let c = cfg(4, 4);
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        // A running sequence owns the whole pool (nothing cached).
        kv.alloc_seq(1, 16).unwrap();
        let cold: Vec<u32> = (0..12).collect();
        let got = admit_kv(&c, &mut kv, &mut pc, &mut m, false, 2, &cold).unwrap();
        assert!(got.is_none(), "must wait for running work, not error");
        assert!(!kv.contains(2));
    }

    #[test]
    fn plan_admission_reclaims_cached_blocks_under_pressure() {
        let c = cfg(4, 4);
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        let cached_prompt: Vec<u32> = (0..16).collect();
        kv.alloc_seq(1, 16).unwrap();
        pc.insert(&cached_prompt, &kv.seq_blocks(1).unwrap(), &mut kv);
        kv.free_seq(1).unwrap();
        assert_eq!(kv.free_blocks(), 0);

        // Next up: a disjoint 8-token prompt (3 blocks with the +1).
        let (tx, _rx) = std::sync::mpsc::channel();
        let req = crate::api::GenRequest::tokens((50..58).collect());
        let seq = Sequence::queued(7, &req, (50..58).collect(), Vec::new(), 4, tx);
        let state = plan_admission(&c, &mut kv, &mut pc, &mut m, Some(&seq), 1, 0);
        assert!(m.prefix_blocks_evicted > 0, "pressure must evict");
        assert!(state.free_blocks >= state.uncached_prefill_blocks());
        assert_eq!(decide(state), Action::Prefill);
    }

    #[test]
    fn reclaim_decode_headroom_prefers_eviction_over_preemption() {
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        let prompt: Vec<u32> = (0..8).collect();
        kv.alloc_seq(1, 8).unwrap();
        pc.insert(&prompt, &kv.seq_blocks(1).unwrap(), &mut kv);
        kv.free_seq(1).unwrap();
        kv.alloc_seq(2, 8).unwrap();
        assert_eq!(kv.free_blocks(), 0);
        // One running sequence, two cached blocks: eviction suffices.
        assert!(!reclaim_decode_headroom(&mut kv, &mut pc, &mut m, 1));
        assert!(kv.free_blocks() >= 1);
        assert!(m.prefix_blocks_evicted >= 1);
    }

    #[test]
    fn reclaim_decode_headroom_requests_preemption_when_dry() {
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        kv.alloc_seq(1, 8).unwrap();
        kv.alloc_seq(2, 8).unwrap();
        assert_eq!(kv.free_blocks(), 0);
        // Nothing cached, two running: the caller must preempt.
        assert!(reclaim_decode_headroom(&mut kv, &mut pc, &mut m, 2));
        // ... but a lone sequence must never self-preempt.
        assert!(!reclaim_decode_headroom(&mut kv, &mut pc, &mut m, 1));
    }

    #[test]
    fn preempt_candidates_count_shared_blocks() {
        let mut kv = kv(4, 8);
        kv.alloc_seq(1, 8).unwrap();
        let donor_blocks = kv.seq_blocks(1).unwrap();
        // Sharer attaches the donor's first block.
        kv.alloc_seq_with_prefix(2, 8, &donor_blocks[..1], 4).unwrap();
        let cands = preempt_candidates(&kv, &[1, 2]);
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[0].reusable_blocks, 1, "donor shares one block");
        assert_eq!(cands[1].reusable_blocks, 1, "sharer shares one block");
    }
}
