//! Shared admission / eviction / preemption policy.
//!
//! [`crate::engine::Engine`] and [`crate::simengine::SimEngine`] used
//! to carry verbatim copies of this logic; any fix applied to one could
//! silently miss the other (the drift hazard ROADMAP flagged). Today a
//! single orchestrator — [`crate::core::EngineCore`] — calls these free
//! functions over the same cache/scheduler state for every backend, so
//! drift is impossible by construction:
//!
//! - [`admit_kv`]: prefix attach first, then eviction of the uncached
//!   shortfall + retry, then — with nothing running to wait for — a
//!   cold allocation with the cache fully evictable.
//! - [`plan_admission`]: the pre-decision pressure-eviction pass that
//!   feeds [`crate::scheduler::decide`] a [`SchedState`].
//! - [`reclaim_decode_headroom`] + [`preempt_candidates`]: decode-time
//!   block reclamation, preferring cached-block eviction over
//!   preemption, and the reusable-block census the preemption victim
//!   choice ([`crate::scheduler::preemption_victim`]) runs on.
//!
//! The module also owns the *flow-control* decisions shared by both
//! engines ([`stream_verdict`], [`ready_to_resume`], [`resume_order`]):
//! when a running sequence must be paused or dropped because its
//! bounded client stream is out of credit, when a paused sequence may
//! rejoin the batch, and in which order paused sequences resume
//! (priority first). The engine-specific mechanics (lane detach, dense
//! KV invalidation) stay in the engines; the *semantics* live here so
//! the sim twin cannot drift.
//!
//! The pure decision functions (`decide`, `preemption_victim`) stay in
//! [`crate::scheduler`]; this module owns the stateful glue between
//! them and the KV / prefix caches.

use std::collections::HashMap;
use std::time::Duration;

use crate::api::StreamStatus;
use crate::config::{BackpressurePolicy, EngineConfig};
use crate::error::Result;
use crate::kvcache::{KvCache, SeqId};
use crate::metrics::EngineMetrics;
use crate::prefixcache::{PrefixCache, PrefixMatch};
use crate::router::{SeqState, Sequence};
use crate::scheduler::{PreemptCandidate, SchedState};

/// Matched prefix usable for reuse: capped so at least the prompt's
/// last token still runs through prefill (its logits row seeds the
/// first generated token), floored to whole blocks.
pub fn usable_prefix(block_tokens: usize, prompt_len: usize, matched: usize) -> usize {
    (matched.min(prompt_len.saturating_sub(1)) / block_tokens) * block_tokens
}

/// Radix-tree lookup for a prompt, truncated to the usable range.
pub fn lookup_prefix(cfg: &EngineConfig, prefix: &mut PrefixCache, prompt: &[u32]) -> PrefixMatch {
    if !cfg.prefix_cache {
        return PrefixMatch::default();
    }
    let m = prefix.match_prefix(prompt);
    let usable = usable_prefix(cfg.kv_block_tokens, prompt.len(), m.tokens);
    if usable == 0 {
        return PrefixMatch::default();
    }
    PrefixMatch {
        blocks: m.blocks[..usable / cfg.kv_block_tokens].to_vec(),
        tokens: usable,
    }
}

/// Admit a sequence's KV: prefix attach first, then eviction of the
/// uncached shortfall + retry, then — with nothing running to wait
/// for — a cold allocation with the cache fully evictable. Returns the
/// attached match, `Ok(None)` when admission should wait for decode to
/// free blocks, or `Err` when truly stuck.
///
/// Attach-before-evict ordering matters throughout: matched blocks are
/// refcount-1 (tree-only) until the alloc increfs them, so eviction
/// must never run between a successful match and its attach; every
/// eviction below is followed by a *fresh* match.
pub fn admit_kv(
    cfg: &EngineConfig,
    kv: &mut KvCache,
    prefix: &mut PrefixCache,
    metrics: &mut EngineMetrics,
    running_empty: bool,
    id: SeqId,
    prompt: &[u32],
) -> Result<Option<PrefixMatch>> {
    let len = prompt.len();
    let need = (len + 1).div_ceil(cfg.kv_block_tokens);
    let matched = lookup_prefix(cfg, prefix, prompt);
    if kv
        .alloc_seq_with_prefix(id, len + 1, &matched.blocks, matched.tokens)
        .is_ok()
    {
        return Ok(Some(matched));
    }
    // Only the *uncached* shortfall needs reclaiming: matched blocks
    // attach by incref, they are not allocated.
    let want = need
        .saturating_sub(matched.blocks.len())
        .saturating_sub(kv.free_blocks());
    let freed = prefix.evict(want, kv);
    metrics.prefix_blocks_evicted += freed as u64;
    let matched = lookup_prefix(cfg, prefix, prompt);
    if kv
        .alloc_seq_with_prefix(id, len + 1, &matched.blocks, matched.tokens)
        .is_ok()
    {
        return Ok(Some(matched));
    }
    if !running_empty {
        return Ok(None);
    }
    // Nothing running will ever free blocks: drop every cache claim and
    // admit cold (or surface the allocator's error).
    let freed = prefix.evict(need, kv);
    metrics.prefix_blocks_evicted += freed as u64;
    kv.alloc_seq(id, len + 1)?;
    Ok(Some(PrefixMatch::default()))
}

/// Record one admission's prefix-cache accounting (lookup, hit, reused
/// vs computed prompt tokens) and the sequence's own usage split.
pub fn note_admission(
    cfg: &EngineConfig,
    metrics: &mut EngineMetrics,
    seq: &mut Sequence,
    matched_tokens: usize,
) {
    if cfg.prefix_cache {
        metrics.prefix_lookups += 1;
        if matched_tokens > 0 {
            metrics.prefix_hits += 1;
        }
    }
    metrics.prefix_tokens_reused += matched_tokens as u64;
    metrics.prefill_tokens_computed += (seq.prompt.len() - matched_tokens) as u64;
    seq.cached_prompt_tokens = matched_tokens;
    seq.admitted = true;
}

/// Blocks the next queued prefill needs and how many are cached (a
/// peek: no LRU touch, no attach).
pub fn admission_outlook(
    cfg: &EngineConfig,
    prefix: &PrefixCache,
    next: Option<&Sequence>,
) -> (usize, usize) {
    match next {
        Some(s) => {
            let bt = cfg.kv_block_tokens;
            let need = (s.prompt.len() + 1).div_ceil(bt);
            let cached = if cfg.prefix_cache {
                usable_prefix(bt, s.prompt.len(), prefix.peek_match_tokens(&s.prompt)) / bt
            } else {
                0
            };
            (need, cached)
        }
        None => (0, 0),
    }
}

/// Build the scheduler's input for one decision, first reclaiming
/// cached (refcount-1) blocks under admission pressure — but only when
/// admission is actually possible (a full running set gets nothing from
/// eviction), and only after refreshing the head request's matched path
/// in the LRU so eviction prefers other entries over the prefix about
/// to be reused.
pub fn plan_admission(
    cfg: &EngineConfig,
    kv: &mut KvCache,
    prefix: &mut PrefixCache,
    metrics: &mut EngineMetrics,
    next: Option<&Sequence>,
    queued: usize,
    running: usize,
) -> SchedState {
    let (next_blocks, mut cached_blocks) = admission_outlook(cfg, prefix, next);
    let uncached = next_blocks.saturating_sub(cached_blocks);
    let admission_possible = next_blocks > 0 && running < cfg.max_running;
    if admission_possible && kv.free_blocks() < uncached {
        if let Some(s) = next {
            let _ = prefix.match_prefix(&s.prompt);
        }
        let want = uncached - kv.free_blocks();
        let freed = prefix.evict(want, kv);
        metrics.prefix_blocks_evicted += freed as u64;
        if freed > 0 {
            // Eviction may still have trimmed blocks the peek counted
            // as cached — re-peek so the policy decides on live state.
            cached_blocks = admission_outlook(cfg, prefix, next).1;
        }
    }
    SchedState {
        queued,
        running,
        max_running: cfg.max_running,
        free_blocks: kv.free_blocks(),
        next_prefill_blocks: next_blocks,
        cached_prefill_blocks: cached_blocks,
    }
}

/// Decode-time KV headroom: each running sequence may need one fresh
/// block this step. Reclaim cached prefix blocks first (even for a lone
/// sequence — tree-held blocks are reclaimable memory). Returns `true`
/// when the caller must preempt a victim (still short, and at least two
/// in the victim pool) and call again.
///
/// `victims` is the preemptable population: running sequences *plus*
/// backpressure-paused ones (parked sequences hold KV too, and must be
/// takeable — otherwise one stalled client could starve live work). A
/// lone victim is never preempted to feed itself.
pub fn reclaim_decode_headroom(
    kv: &mut KvCache,
    prefix: &mut PrefixCache,
    metrics: &mut EngineMetrics,
    running: usize,
    victims: usize,
) -> bool {
    if kv.free_blocks() >= running {
        return false;
    }
    let want = running - kv.free_blocks();
    let freed = prefix.evict(want, kv);
    metrics.prefix_blocks_evicted += freed as u64;
    kv.free_blocks() < running && victims > 1
}

/// The census preemption runs on: for every running or parked sequence,
/// its request priority, whether it is backpressure-paused, and how
/// many of its blocks would *stay reusable* (shared with the prefix
/// cache or other sequences) if it were evicted now.
/// [`crate::scheduler::preemption_victim`] orders victims by
/// `(priority asc, paused first, reusable desc, recency)`, so a request
/// is never preempted while a strictly lower-priority victim exists,
/// and within a level a stalled client's parked work is sacrificed
/// before live decode progress.
pub fn preempt_candidates(
    kv: &KvCache,
    seqs: &HashMap<SeqId, Sequence>,
    pool_ids: &[SeqId],
) -> Vec<PreemptCandidate> {
    let mut out = Vec::new();
    preempt_candidates_into(kv, seqs, pool_ids, &mut out);
    out
}

/// [`preempt_candidates`] into a caller-owned buffer (cleared first),
/// so the decode hot path's headroom scan allocates nothing.
pub fn preempt_candidates_into(
    kv: &KvCache,
    seqs: &HashMap<SeqId, Sequence>,
    pool_ids: &[SeqId],
    out: &mut Vec<PreemptCandidate>,
) {
    out.clear();
    out.extend(pool_ids.iter().map(|&id| {
        let reusable = kv
            .seq_blocks(id)
            .map(|bs| bs.iter().filter(|&&b| kv.block_refcount(b) > 1).count())
            .unwrap_or(0);
        let seq = seqs.get(&id);
        PreemptCandidate {
            id,
            priority: seq.map(|s| s.priority).unwrap_or(0),
            paused: seq.map(|s| s.state == SeqState::Paused).unwrap_or(false),
            reusable_blocks: reusable,
        }
    }));
}

/// Admission-path relief: when a queued request cannot admit and no
/// decode is running to free blocks, the only KV holders may be
/// sequences parked on backpressure. Pick a parked victim to preempt —
/// the usual (priority asc, reusable desc, recency) choice — but only
/// when it has *strictly lower* priority than the waiting request:
/// parked work keeps its KV against equal-or-lower-priority arrivals
/// (it was admitted first), while a higher-priority waiter is never
/// starved by a stalled lower-priority client.
pub fn admission_relief_victim(
    kv: &KvCache,
    seqs: &HashMap<SeqId, Sequence>,
    paused: &[SeqId],
    waiter_priority: i32,
) -> Option<SeqId> {
    let candidates = preempt_candidates(kv, seqs, paused);
    let victim = crate::scheduler::preemption_victim(&candidates)?;
    let victim_priority = seqs.get(&victim).map(|s| s.priority).unwrap_or(0);
    (victim_priority < waiter_priority).then_some(victim)
}

// ---------------------------------------------------------------------
// Stream flow control (shared backpressure semantics)
// ---------------------------------------------------------------------

/// What the engine must do about one running sequence's stream before
/// decoding it this step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamVerdict {
    /// Credit available: decode normally.
    Flowing,
    /// Token buffer full: apply the configured policy (pause or drop).
    Stalled,
    /// The client dropped its receiver: reclaim the request.
    Disconnected,
}

/// Sample a sequence's stream credit (run *before* decoding it, so a
/// generated token always has a slot and is never dropped).
pub fn stream_verdict(seq: &Sequence) -> StreamVerdict {
    match seq.stream.status() {
        StreamStatus::Ready => StreamVerdict::Flowing,
        StreamStatus::Full => StreamVerdict::Stalled,
        StreamStatus::Closed => StreamVerdict::Disconnected,
    }
}

/// Hysteresis for un-pausing: a paused sequence rejoins the batch only
/// once its client drained to at most half the stream capacity, so a
/// client draining one token at a time does not thrash pause/resume
/// (each resume costs a dense-KV rebuild on the real engine).
pub fn ready_to_resume(seq: &Sequence) -> bool {
    seq.stream.status() != StreamStatus::Closed
        && seq.stream.buffered() * 2 <= seq.stream.capacity()
}

/// The order paused sequences should attempt to resume in: highest
/// priority first, oldest (smallest id) within a level — mirroring the
/// admission queue's ordering.
pub fn resume_order(seqs: &HashMap<SeqId, Sequence>, paused: &[SeqId]) -> Vec<SeqId> {
    let mut order: Vec<SeqId> = paused.to_vec();
    order.sort_by_key(|id| {
        let priority = seqs.get(id).map(|s| s.priority).unwrap_or(0);
        (std::cmp::Reverse(priority), *id)
    });
    order
}

/// Resolve one stalled sequence against the configured policy.
pub fn stalled_action(policy: BackpressurePolicy) -> StalledAction {
    match policy {
        BackpressurePolicy::PauseDecode => StalledAction::Pause,
        BackpressurePolicy::DropSlow => StalledAction::DropOverrun,
    }
}

/// Engine-agnostic resolution of a stalled stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StalledAction {
    Pause,
    DropOverrun,
}

/// One flow-control transition an engine must execute this step.
/// Planned by [`plan_stream_ops`]; the engines supply only the
/// mechanics (lane attach/detach, dense-KV bookkeeping, metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamOp {
    /// Re-admit a drained paused sequence into the decode batch.
    Resume(SeqId),
    /// A paused sequence's client vanished: finish `Cancelled`,
    /// reclaim its KV.
    ReapPaused(SeqId),
    /// A running sequence's client vanished: retire it `Cancelled`.
    ReapRunning(SeqId),
    /// Park a stalled running sequence
    /// ([`BackpressurePolicy::PauseDecode`]).
    Pause(SeqId),
    /// Finish a stalled running sequence with `Overrun`
    /// ([`BackpressurePolicy::DropSlow`]).
    DropOverrun(SeqId),
    /// A parked sequence sat idle past the configured
    /// `stream_idle_timeout` without draining toward resume: demote it
    /// to `Overrun` and reclaim its KV, so parked occupancy is bounded
    /// even with no allocation pressure.
    ExpireIdle(SeqId),
}

/// The per-step flow-control plan, shared verbatim by both engines so
/// the sim twin cannot drift: resume drained paused sequences (highest
/// priority first, bounded by `free_lanes`), reap disconnected clients
/// on both sides, expire parked sequences idle past `idle_timeout`
/// (engine-clock `now` vs the sequence's `paused_at`), and pause or
/// drop stalled running streams per the configured policy. Pure:
/// computes transitions from a snapshot; the caller executes them in
/// order.
///
/// A parked sequence that *has* drained below the resume threshold is
/// never expired, even with no free lane — the client is cooperating;
/// the wait is the engine's.
pub fn plan_stream_ops(
    seqs: &HashMap<SeqId, Sequence>,
    paused: &[SeqId],
    running_ids: &[SeqId],
    policy: BackpressurePolicy,
    free_lanes: usize,
    now: Duration,
    idle_timeout: Option<Duration>,
) -> Vec<StreamOp> {
    let mut ops = Vec::new();
    plan_stream_ops_into(
        seqs,
        paused,
        running_ids,
        policy,
        free_lanes,
        now,
        idle_timeout,
        &mut ops,
    );
    ops
}

/// [`plan_stream_ops`] into a caller-owned plan buffer (cleared
/// first) — the step loop's allocation-free variant. Note the paused
/// resume ordering still allocates (via [`resume_order`]) only when
/// `paused` is non-empty; the steady decode window has no parked
/// sequences and therefore no allocation.
#[allow(clippy::too_many_arguments)]
pub fn plan_stream_ops_into(
    seqs: &HashMap<SeqId, Sequence>,
    paused: &[SeqId],
    running_ids: &[SeqId],
    policy: BackpressurePolicy,
    mut free_lanes: usize,
    now: Duration,
    idle_timeout: Option<Duration>,
    ops: &mut Vec<StreamOp>,
) {
    ops.clear();
    for id in resume_order(seqs, paused) {
        let seq = &seqs[&id];
        if stream_verdict(seq) == StreamVerdict::Disconnected {
            ops.push(StreamOp::ReapPaused(id));
        } else if ready_to_resume(seq) {
            if free_lanes > 0 {
                free_lanes -= 1;
                ops.push(StreamOp::Resume(id));
            }
        } else if let (Some(timeout), Some(at)) = (idle_timeout, seq.paused_at) {
            if now.saturating_sub(at) >= timeout {
                ops.push(StreamOp::ExpireIdle(id));
            }
        }
    }
    for &id in running_ids {
        match stream_verdict(&seqs[&id]) {
            StreamVerdict::Flowing => {}
            StreamVerdict::Disconnected => ops.push(StreamOp::ReapRunning(id)),
            StreamVerdict::Stalled => match stalled_action(policy) {
                StalledAction::Pause => ops.push(StreamOp::Pause(id)),
                StalledAction::DropOverrun => ops.push(StreamOp::DropOverrun(id)),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::InferenceEngine;
    use crate::kvcache::KvGeometry;
    use crate::scheduler::{decide, Action};

    /// Compile-time proof that every engine alias exposes the one
    /// shared surface this policy is written for (the trait bound fails
    /// to resolve if the core drifts off it).
    #[test]
    fn both_engines_implement_inference_engine() {
        fn requires_engine<E: InferenceEngine>() {}
        let _real = requires_engine::<crate::engine::Engine>;
        let _sim = requires_engine::<crate::simengine::SimEngine>;
        let _stub = requires_engine::<crate::core::StubEngine>;
    }

    fn cfg(bt: usize, blocks: usize) -> EngineConfig {
        EngineConfig {
            kv_block_tokens: bt,
            kv_total_blocks: blocks,
            ..EngineConfig::default()
        }
    }

    fn kv(bt: usize, blocks: usize) -> KvCache {
        KvCache::new(
            KvGeometry {
                n_layers: 1,
                n_heads: 1,
                head_dim: 2,
                block_tokens: bt,
                max_seq: 256,
            },
            blocks,
        )
    }

    #[test]
    fn usable_prefix_reserves_last_token_and_floors_to_blocks() {
        // Full-prompt match: last token must still prefill.
        assert_eq!(usable_prefix(4, 8, 8), 4);
        assert_eq!(usable_prefix(4, 9, 8), 8);
        assert_eq!(usable_prefix(4, 9, 3), 0, "sub-block match unusable");
        assert_eq!(usable_prefix(4, 0, 0), 0);
    }

    #[test]
    fn admit_kv_attaches_cached_prefix() {
        let c = cfg(4, 16);
        let mut kv = kv(4, 16);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        // Seed the cache with a donor's prompt blocks.
        let prompt: Vec<u32> = (0..12).collect();
        kv.alloc_seq(1, 12).unwrap();
        let blocks = kv.seq_blocks(1).unwrap();
        pc.insert(&prompt, &blocks, &mut kv);
        kv.free_seq(1).unwrap();

        let got = admit_kv(&c, &mut kv, &mut pc, &mut m, true, 2, &prompt)
            .unwrap()
            .expect("admission must succeed");
        // 12-token prompt: 2 full blocks usable (last token reserved).
        assert_eq!(got.tokens, 8);
        assert_eq!(got.blocks.len(), 2);
        assert!(kv.contains(2));
    }

    #[test]
    fn admit_kv_evicts_cache_for_cold_prompt_when_nothing_runs() {
        let c = cfg(4, 4);
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        // Fill the whole pool with a cached prompt.
        let cached_prompt: Vec<u32> = (100..116).collect();
        kv.alloc_seq(1, 16).unwrap();
        pc.insert(&cached_prompt, &kv.seq_blocks(1).unwrap(), &mut kv);
        kv.free_seq(1).unwrap();
        assert_eq!(kv.free_blocks(), 0);

        // A disjoint cold prompt must still admit: the cache gives its
        // blocks back.
        let cold: Vec<u32> = (200..212).collect();
        let got = admit_kv(&c, &mut kv, &mut pc, &mut m, true, 2, &cold)
            .unwrap()
            .expect("cold admission must evict and succeed");
        assert_eq!(got.tokens, 0);
        assert!(m.prefix_blocks_evicted > 0);
        assert!(kv.contains(2));
    }

    #[test]
    fn admit_kv_waits_when_decode_can_free_blocks() {
        let c = cfg(4, 4);
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        // A running sequence owns the whole pool (nothing cached).
        kv.alloc_seq(1, 16).unwrap();
        let cold: Vec<u32> = (0..12).collect();
        let got = admit_kv(&c, &mut kv, &mut pc, &mut m, false, 2, &cold).unwrap();
        assert!(got.is_none(), "must wait for running work, not error");
        assert!(!kv.contains(2));
    }

    #[test]
    fn plan_admission_reclaims_cached_blocks_under_pressure() {
        let c = cfg(4, 4);
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        let cached_prompt: Vec<u32> = (0..16).collect();
        kv.alloc_seq(1, 16).unwrap();
        pc.insert(&cached_prompt, &kv.seq_blocks(1).unwrap(), &mut kv);
        kv.free_seq(1).unwrap();
        assert_eq!(kv.free_blocks(), 0);

        // Next up: a disjoint 8-token prompt (3 blocks with the +1).
        let (tx, _rx) = crate::api::event_channel(16);
        let req = crate::api::GenRequest::tokens((50..58).collect());
        let seq = Sequence::queued(7, &req, (50..58).collect(), Vec::new(), 4, tx);
        let state = plan_admission(&c, &mut kv, &mut pc, &mut m, Some(&seq), 1, 0);
        assert!(m.prefix_blocks_evicted > 0, "pressure must evict");
        assert!(state.free_blocks >= state.uncached_prefill_blocks());
        assert_eq!(decide(state), Action::Prefill);
    }

    #[test]
    fn reclaim_decode_headroom_prefers_eviction_over_preemption() {
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        let prompt: Vec<u32> = (0..8).collect();
        kv.alloc_seq(1, 8).unwrap();
        pc.insert(&prompt, &kv.seq_blocks(1).unwrap(), &mut kv);
        kv.free_seq(1).unwrap();
        kv.alloc_seq(2, 8).unwrap();
        assert_eq!(kv.free_blocks(), 0);
        // One running sequence, two cached blocks: eviction suffices.
        assert!(!reclaim_decode_headroom(&mut kv, &mut pc, &mut m, 1, 1));
        assert!(kv.free_blocks() >= 1);
        assert!(m.prefix_blocks_evicted >= 1);
    }

    #[test]
    fn reclaim_decode_headroom_requests_preemption_when_dry() {
        let mut kv = kv(4, 4);
        let mut pc = PrefixCache::new(4);
        let mut m = EngineMetrics::default();
        kv.alloc_seq(1, 8).unwrap();
        kv.alloc_seq(2, 8).unwrap();
        assert_eq!(kv.free_blocks(), 0);
        // Nothing cached, two running: the caller must preempt.
        assert!(reclaim_decode_headroom(&mut kv, &mut pc, &mut m, 2, 2));
        // ... but a lone victim must never self-preempt...
        assert!(!reclaim_decode_headroom(&mut kv, &mut pc, &mut m, 1, 1));
        // ... while a lone *runner* with a paused victim available may
        // preempt the parked one.
        assert!(reclaim_decode_headroom(&mut kv, &mut pc, &mut m, 1, 2));
    }

    /// A minimal sequence map for census tests.
    fn seq_map(entries: &[(SeqId, i32)]) -> HashMap<SeqId, Sequence> {
        let mut m = HashMap::new();
        for &(id, priority) in entries {
            let (tx, rx) = crate::api::event_channel(4);
            std::mem::forget(rx); // keep the stream open for the test
            let req = crate::api::GenRequest::tokens(vec![1, 2]).priority(priority);
            m.insert(id, Sequence::queued(id, &req, vec![1, 2], Vec::new(), 4, tx));
        }
        m
    }

    #[test]
    fn preempt_candidates_count_shared_blocks_and_carry_priority() {
        let mut kv = kv(4, 8);
        kv.alloc_seq(1, 8).unwrap();
        let donor_blocks = kv.seq_blocks(1).unwrap();
        // Sharer attaches the donor's first block.
        kv.alloc_seq_with_prefix(2, 8, &donor_blocks[..1], 4).unwrap();
        let seqs = seq_map(&[(1, 5), (2, -3)]);
        let cands = preempt_candidates(&kv, &seqs, &[1, 2]);
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[0].reusable_blocks, 1, "donor shares one block");
        assert_eq!(cands[1].reusable_blocks, 1, "sharer shares one block");
        assert_eq!(cands[0].priority, 5);
        assert_eq!(cands[1].priority, -3);
    }

    #[test]
    fn stream_verdicts_track_credit_and_disconnect() {
        let seqs = seq_map(&[(1, 0)]);
        let seq = &seqs[&1];
        assert_eq!(stream_verdict(seq), StreamVerdict::Flowing);
        // Fill the 4-slot stream: stalled.
        for t in 0..4 {
            assert_eq!(seq.emit_token(t), crate::api::EmitResult::Sent);
        }
        assert_eq!(stream_verdict(seq), StreamVerdict::Stalled);
        assert!(!ready_to_resume(seq), "full stream cannot resume");
    }

    #[test]
    fn resume_order_is_priority_then_age() {
        let seqs = seq_map(&[(1, 0), (2, 5), (3, 5), (4, -1)]);
        assert_eq!(resume_order(&seqs, &[4, 3, 1, 2]), vec![2, 3, 1, 4]);
    }

    #[test]
    fn stalled_action_follows_policy() {
        assert_eq!(
            stalled_action(BackpressurePolicy::PauseDecode),
            StalledAction::Pause
        );
        assert_eq!(
            stalled_action(BackpressurePolicy::DropSlow),
            StalledAction::DropOverrun
        );
    }

    #[test]
    fn plan_stream_ops_resumes_pauses_and_reaps() {
        // Seq 1: paused, drained (empty stream) -> Resume.
        // Seq 2: paused, higher priority, drained -> Resume first.
        // Seq 3: running, stalled (full stream)  -> Pause / DropOverrun.
        // Seq 4: running, flowing               -> untouched.
        let seqs = seq_map(&[(1, 0), (2, 5), (3, 0), (4, 0)]);
        for t in 0..4 {
            assert_eq!(seqs[&3].emit_token(t), crate::api::EmitResult::Sent);
        }
        let ops = plan_stream_ops(
            &seqs,
            &[1, 2],
            &[3, 4],
            BackpressurePolicy::PauseDecode,
            8,
            Duration::ZERO,
            None,
        );
        assert_eq!(
            ops,
            vec![
                StreamOp::Resume(2),
                StreamOp::Resume(1),
                StreamOp::Pause(3)
            ]
        );
        let ops = plan_stream_ops(
            &seqs,
            &[1, 2],
            &[3, 4],
            BackpressurePolicy::DropSlow,
            8,
            Duration::ZERO,
            None,
        );
        assert_eq!(
            ops,
            vec![
                StreamOp::Resume(2),
                StreamOp::Resume(1),
                StreamOp::DropOverrun(3)
            ]
        );
        // No free lanes: nothing resumes, stalls still handled.
        let ops = plan_stream_ops(
            &seqs,
            &[1, 2],
            &[3, 4],
            BackpressurePolicy::PauseDecode,
            0,
            Duration::ZERO,
            None,
        );
        assert_eq!(ops, vec![StreamOp::Pause(3)]);
        // One lane: only the highest-priority paused sequence resumes.
        let ops = plan_stream_ops(
            &seqs,
            &[1, 2],
            &[3, 4],
            BackpressurePolicy::PauseDecode,
            1,
            Duration::ZERO,
            None,
        );
        assert_eq!(ops, vec![StreamOp::Resume(2), StreamOp::Pause(3)]);
    }

    #[test]
    fn plan_stream_ops_expires_long_parked_sequences() {
        // Seq 1: paused at t=0, stream still full -> expires once the
        // timeout elapses. Seq 2: paused but drained (resumable) ->
        // never expired, even with zero free lanes.
        let mut seqs = seq_map(&[(1, 0), (2, 0)]);
        for t in 0..4 {
            assert_eq!(seqs[&1].emit_token(t), crate::api::EmitResult::Sent);
        }
        seqs.get_mut(&1).unwrap().paused_at = Some(Duration::ZERO);
        seqs.get_mut(&2).unwrap().paused_at = Some(Duration::ZERO);
        let timeout = Some(Duration::from_millis(10));
        // Before the deadline: nothing expires (no lanes -> no resume).
        let ops = plan_stream_ops(
            &seqs,
            &[1, 2],
            &[],
            BackpressurePolicy::PauseDecode,
            0,
            Duration::from_millis(9),
            timeout,
        );
        assert_eq!(ops, vec![]);
        // At the deadline: only the stalled one expires.
        let ops = plan_stream_ops(
            &seqs,
            &[1, 2],
            &[],
            BackpressurePolicy::PauseDecode,
            0,
            Duration::from_millis(10),
            timeout,
        );
        assert_eq!(ops, vec![StreamOp::ExpireIdle(1)]);
        // No timeout configured: parked work is never expired.
        let ops = plan_stream_ops(
            &seqs,
            &[1, 2],
            &[],
            BackpressurePolicy::PauseDecode,
            0,
            Duration::from_secs(3600),
            None,
        );
        assert_eq!(ops, vec![]);
    }
}
