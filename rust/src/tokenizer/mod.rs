//! Byte-level tokenizer for the real serving path.
//!
//! The tiny model is trained (synthetically initialized) over a byte
//! vocabulary: ids 0..=255 are raw bytes, followed by special tokens.
//! This keeps the end-to-end path honest (prompt -> ids -> model ->
//! ids -> text) without shipping a BPE training corpus.

/// Special token ids start after the byte range.
pub const BOS: u32 = 256;
pub const EOS: u32 = 257;
pub const PAD: u32 = 258;

/// Number of ids the tokenizer can emit (vocab may be padded above this).
pub const TOKENIZER_VOCAB: usize = 259;

#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    /// Model vocab size (>= TOKENIZER_VOCAB; extra ids are never emitted).
    vocab_size: usize,
}

impl ByteTokenizer {
    pub fn new(vocab_size: usize) -> Self {
        assert!(
            vocab_size >= TOKENIZER_VOCAB,
            "model vocab {vocab_size} smaller than tokenizer range"
        );
        ByteTokenizer { vocab_size }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    /// Encode text as BOS + bytes.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        out.extend(text.bytes().map(|b| b as u32));
        out
    }

    /// Encode raw text as byte ids *without* the BOS marker — the form
    /// stop sequences take so they can match against generated ids.
    pub fn encode_raw(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32).collect()
    }

    /// Decode generated ids back to text (specials and out-of-range ids
    /// are dropped; invalid utf-8 is replaced).
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| t < 256)
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_eos(&self, id: u32) -> bool {
        id == EOS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new(512);
        let ids = t.encode("What is the largest ocean?");
        assert_eq!(ids[0], BOS);
        assert_eq!(t.decode(&ids), "What is the largest ocean?");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new(512);
        let s = "héllo → 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn encode_raw_has_no_bos() {
        let t = ByteTokenizer::new(512);
        assert_eq!(t.encode_raw("hi"), vec![b'h' as u32, b'i' as u32]);
        assert_eq!(t.encode("hi")[1..], t.encode_raw("hi")[..]);
        assert!(t.encode_raw("").is_empty());
    }

    #[test]
    fn specials_dropped_on_decode() {
        let t = ByteTokenizer::new(512);
        assert_eq!(t.decode(&[BOS, b'h' as u32, EOS, b'i' as u32, PAD]), "hi");
    }

    #[test]
    #[should_panic]
    fn vocab_too_small_panics() {
        ByteTokenizer::new(100);
    }
}
