//! C1 analytics — softmax-input statistics and the unified-max policy
//! (paper §3 + Figure 5).
//!
//! Tracks the distribution of x_i (elements of softmax input rows),
//! chooses the unified scaling factor phi, and decides whether the
//! asynchronized scheme is safe for a model (the paper disables it for
//! OPT-6.7B whose range is too wide).

/// Streaming summary of softmax-input values (Welford + extremes).
#[derive(Debug, Clone, Default)]
pub struct SoftmaxInputStats {
    pub count: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl SoftmaxInputStats {
    pub fn new() -> Self {
        SoftmaxInputStats {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        let d = x - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    pub fn std(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / (self.count - 1) as f64).sqrt()
        }
    }

    pub fn range(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max - self.min
        }
    }
}

/// The per-model unified-max policy derived from the statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct UnifiedMaxPolicy {
    /// Enable the asynchronized path at all (false = OPT-6.7B rule).
    pub enabled: bool,
    /// The unified scaling factor.
    pub phi: f64,
    /// Safe window (a, b) for x - phi.
    pub a: f64,
    pub b: f64,
    /// Expected recompute probability per row (from the Gaussian tail).
    pub expected_recompute_rate: f64,
}

/// Safe exponent window for f32 accumulation over rows up to ~32k long:
/// e^b * 32768 must stay << f32::MAX, and e^a must stay above denormals.
pub const SAFE_A: f64 = -25.0;
pub const SAFE_B: f64 = 18.0;

/// Derive the policy from measured stats (paper §3 "Analysis and
/// Insights" + Figure 5 decision).
pub fn derive_policy(stats: &SoftmaxInputStats) -> UnifiedMaxPolicy {
    if stats.count == 0 {
        return UnifiedMaxPolicy {
            enabled: false,
            phi: 0.0,
            a: SAFE_A,
            b: SAFE_B,
            expected_recompute_rate: 1.0,
        };
    }
    // Center the window on the distribution.
    let phi = stats.mean;
    // OPT rule: if the observed range doesn't fit comfortably in the
    // window around phi, disable the asynchronized path.
    let fits = (stats.max - phi) < SAFE_B * 0.9 && (stats.min - phi) > SAFE_A * 0.9;
    // Gaussian tail estimate for the recompute probability of a *row max*;
    // conservatively use the per-element tail at 6 sigma cap.
    let z_hi = if stats.std() > 0.0 {
        ((SAFE_B + phi - stats.max).max(0.0)) / stats.std()
    } else {
        f64::INFINITY
    };
    let expected = if fits { (-z_hi).exp().min(1e-3) } else { 1.0 };
    UnifiedMaxPolicy {
        enabled: fits,
        phi,
        a: SAFE_A,
        b: SAFE_B,
        expected_recompute_rate: expected,
    }
}

// ---------------------------------------------------------------------
// Reference kernels (conformance surface)
// ---------------------------------------------------------------------

/// Synchronized two-pass softmax: find the row max, then normalize.
/// This is the baseline every asynchronized result must match; it is
/// numerically safe for any finite input.
pub fn softmax_reference(xs: &[f32]) -> Vec<f64> {
    if xs.is_empty() {
        return Vec::new();
    }
    let m = xs.iter().fold(f64::NEG_INFINITY, |a, &x| a.max(x as f64));
    let exps: Vec<f64> = xs.iter().map(|&x| ((x as f64) - m).exp()).collect();
    let s: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / s).collect()
}

/// Result of the asynchronized (unified-max) softmax.
#[derive(Debug, Clone, PartialEq)]
pub struct UnifiedSoftmax {
    pub probs: Vec<f64>,
    /// The row forced the synchronized fallback: the policy disabled
    /// the asynchronized path outright (OPT rule), or an element landed
    /// above the safe window `phi + b` (partial sums would overflow)
    /// and the row was recomputed two-pass — the paper's §3 recompute.
    pub fell_back: bool,
}

/// The paper's asynchronized softmax (§3): a *single* pass accumulating
/// `e^(x - phi)` with the per-model unified scaling factor instead of
/// the row max, so partial softmax results can be computed and reduced
/// without synchronizing on a shared max.
///
/// Window semantics, matching the kernel: an exponent below `a` is
/// flushed to zero (denormal-range contribution, harmless); an exponent
/// above `b` would overflow the f32 accumulator in the real kernel, so
/// the row falls back to the synchronized two-pass (`fell_back`). A
/// policy with `enabled == false` (the OPT-6.7B rule) short-circuits to
/// the reference for every row.
pub fn softmax_unified(xs: &[f32], policy: &UnifiedMaxPolicy) -> UnifiedSoftmax {
    if !policy.enabled {
        return UnifiedSoftmax {
            probs: softmax_reference(xs),
            fell_back: true,
        };
    }
    let mut exps = Vec::with_capacity(xs.len());
    let mut sum = 0.0f64;
    for &x in xs {
        let d = (x as f64) - policy.phi;
        if d > policy.b {
            // Out the top of the safe window: recompute synchronized.
            return UnifiedSoftmax {
                probs: softmax_reference(xs),
                fell_back: true,
            };
        }
        let e = if d < policy.a { 0.0 } else { d.exp() };
        sum += e;
        exps.push(e);
    }
    if sum == 0.0 {
        // Every element underflowed the window: nothing to normalize.
        return UnifiedSoftmax {
            probs: softmax_reference(xs),
            fell_back: true,
        };
    }
    UnifiedSoftmax {
        probs: exps.into_iter().map(|e| e / sum).collect(),
        fell_back: false,
    }
}

/// Figure 5 as published: per-model softmax-input ranges the paper reports
/// (approximate extents read off the figure). Used by the fig05 bench to
/// reproduce the enable/disable decision per model.
pub fn paper_figure5_ranges() -> Vec<(&'static str, f64, f64)> {
    vec![
        ("llama2-7b", -16.8, 6.5),
        ("llama2-13b", -15.0, 6.0),
        ("chatglm2-6b", -14.0, 5.5),
        // OPT's range is reported as far wider — the paper disables C1.
        ("opt-6.7b", -60.0, 30.0),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_from(lo: f64, hi: f64, n: usize) -> SoftmaxInputStats {
        let mut s = SoftmaxInputStats::new();
        for i in 0..n {
            s.push(lo + (hi - lo) * i as f64 / (n - 1) as f64);
        }
        s
    }

    #[test]
    fn welford_matches_closed_form() {
        let mut s = SoftmaxInputStats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!((s.min, s.max), (1.0, 4.0));
    }

    #[test]
    fn narrow_range_enables_async() {
        let s = stats_from(-16.8, 6.5, 1000); // Llama2-7B's Figure 5 range
        let p = derive_policy(&s);
        assert!(p.enabled);
        assert!(p.expected_recompute_rate < 0.01);
        // phi centers the distribution
        assert!((p.phi - s.mean).abs() < 1e-9);
    }

    #[test]
    fn wide_range_disables_async_opt_rule() {
        let s = stats_from(-60.0, 30.0, 1000); // OPT-6.7B
        let p = derive_policy(&s);
        assert!(!p.enabled, "OPT-style wide range must disable C1");
    }

    #[test]
    fn paper_ranges_reproduce_decisions() {
        for (name, lo, hi) in paper_figure5_ranges() {
            let p = derive_policy(&stats_from(lo, hi, 512));
            let want = name != "opt-6.7b";
            assert_eq!(p.enabled, want, "{name}");
        }
    }

    #[test]
    fn empty_stats_safe_default() {
        let p = derive_policy(&SoftmaxInputStats::new());
        assert!(!p.enabled);
    }

    #[test]
    fn range_and_std_edge_cases_are_nan_free() {
        // count == 0: both summaries are defined (zero), not NaN/inf.
        let s = SoftmaxInputStats::new();
        assert_eq!(s.range(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert!(s.range().is_finite() && s.std().is_finite());

        // count == 1: a single observation has no spread.
        let mut s = SoftmaxInputStats::new();
        s.push(-3.25);
        assert_eq!(s.count, 1);
        assert_eq!(s.range(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!((s.min, s.max), (-3.25, -3.25));
        assert!(s.mean.is_finite());

        // The derived policy is NaN-free in both degenerate cases.
        let p = derive_policy(&s);
        assert!(p.phi.is_finite());
        assert!(p.expected_recompute_rate.is_finite());
        let p0 = derive_policy(&SoftmaxInputStats::new());
        assert!(p0.phi.is_finite());
        assert!(p0.expected_recompute_rate.is_finite());

        // Identical observations: zero variance, still finite.
        let mut s = SoftmaxInputStats::new();
        for _ in 0..10 {
            s.push(2.5);
        }
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.range(), 0.0);
        assert!(derive_policy(&s).expected_recompute_rate.is_finite());
    }

    #[test]
    fn wide_range_stats_flip_unified_softmax_to_synchronized() {
        // The satellite acceptance: a wide-range input distribution
        // must flip the SoftmaxInputStats-driven policy into
        // synchronized mode, and the unified kernel must then report
        // the fallback and agree with the reference bit-for-bit.
        let narrow = derive_policy(&stats_from(-16.8, 6.5, 512));
        assert!(narrow.enabled);
        let wide = derive_policy(&stats_from(-60.0, 30.0, 512));
        assert!(!wide.enabled, "OPT-style width must disable the path");

        let xs: Vec<f32> = (0..64).map(|i| -60.0 + 90.0 * i as f32 / 63.0).collect();
        let got = softmax_unified(&xs, &wide);
        assert!(got.fell_back);
        assert_eq!(got.probs, softmax_reference(&xs));
    }

    #[test]
    fn unified_softmax_matches_reference_in_window() {
        let policy = derive_policy(&stats_from(-16.8, 6.5, 512));
        let xs: Vec<f32> = (0..256).map(|i| -16.8 + 23.3 * i as f32 / 255.0).collect();
        let got = softmax_unified(&xs, &policy);
        assert!(!got.fell_back, "in-range row must stay asynchronized");
        let want = softmax_reference(&xs);
        let sum: f64 = got.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "probabilities normalize");
        for (u, r) in got.probs.iter().zip(&want) {
            assert!((u - r).abs() <= 1e-9 + 1e-9 * r, "{u} vs {r}");
        }
    }

    #[test]
    fn unified_softmax_window_edges_are_exact() {
        // Hand-built policy with exact window bounds, so the edge
        // arithmetic has no float slack.
        let policy = UnifiedMaxPolicy {
            enabled: true,
            phi: 0.0,
            a: SAFE_A,
            b: SAFE_B,
            expected_recompute_rate: 0.0,
        };
        // Exactly at phi + b: still inside the window.
        let xs = vec![0.0f32, SAFE_B as f32];
        assert!(!softmax_unified(&xs, &policy).fell_back);
        // Just past it: must recompute synchronized.
        let xs = vec![0.0f32, SAFE_B as f32 + 1.0];
        let got = softmax_unified(&xs, &policy);
        assert!(got.fell_back, "overflow edge must trigger the fallback");
        assert_eq!(got.probs, softmax_reference(&xs));
        // Below phi + a: flushed to zero, no fallback, negligible mass.
        let xs = vec![0.0f32, SAFE_A as f32 - 10.0];
        let got = softmax_unified(&xs, &policy);
        assert!(!got.fell_back, "underflow is harmless, not a fallback");
        assert_eq!(got.probs[1], 0.0);
        assert!((got.probs[0] - 1.0).abs() < 1e-9);
    }
}
