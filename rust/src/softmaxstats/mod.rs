//! C1 analytics — softmax-input statistics and the unified-max policy
//! (paper §3 + Figure 5).
//!
//! Tracks the distribution of x_i (elements of softmax input rows),
//! chooses the unified scaling factor phi, and decides whether the
//! asynchronized scheme is safe for a model (the paper disables it for
//! OPT-6.7B whose range is too wide).

/// Streaming summary of softmax-input values (Welford + extremes).
#[derive(Debug, Clone, Default)]
pub struct SoftmaxInputStats {
    pub count: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl SoftmaxInputStats {
    pub fn new() -> Self {
        SoftmaxInputStats {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        let d = x - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    pub fn std(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / (self.count - 1) as f64).sqrt()
        }
    }

    pub fn range(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max - self.min
        }
    }
}

/// The per-model unified-max policy derived from the statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct UnifiedMaxPolicy {
    /// Enable the asynchronized path at all (false = OPT-6.7B rule).
    pub enabled: bool,
    /// The unified scaling factor.
    pub phi: f64,
    /// Safe window (a, b) for x - phi.
    pub a: f64,
    pub b: f64,
    /// Expected recompute probability per row (from the Gaussian tail).
    pub expected_recompute_rate: f64,
}

/// Safe exponent window for f32 accumulation over rows up to ~32k long:
/// e^b * 32768 must stay << f32::MAX, and e^a must stay above denormals.
pub const SAFE_A: f64 = -25.0;
pub const SAFE_B: f64 = 18.0;

/// Derive the policy from measured stats (paper §3 "Analysis and
/// Insights" + Figure 5 decision).
pub fn derive_policy(stats: &SoftmaxInputStats) -> UnifiedMaxPolicy {
    if stats.count == 0 {
        return UnifiedMaxPolicy {
            enabled: false,
            phi: 0.0,
            a: SAFE_A,
            b: SAFE_B,
            expected_recompute_rate: 1.0,
        };
    }
    // Center the window on the distribution.
    let phi = stats.mean;
    // OPT rule: if the observed range doesn't fit comfortably in the
    // window around phi, disable the asynchronized path.
    let fits = (stats.max - phi) < SAFE_B * 0.9 && (stats.min - phi) > SAFE_A * 0.9;
    // Gaussian tail estimate for the recompute probability of a *row max*;
    // conservatively use the per-element tail at 6 sigma cap.
    let z_hi = if stats.std() > 0.0 {
        ((SAFE_B + phi - stats.max).max(0.0)) / stats.std()
    } else {
        f64::INFINITY
    };
    let expected = if fits { (-z_hi).exp().min(1e-3) } else { 1.0 };
    UnifiedMaxPolicy {
        enabled: fits,
        phi,
        a: SAFE_A,
        b: SAFE_B,
        expected_recompute_rate: expected,
    }
}

/// Figure 5 as published: per-model softmax-input ranges the paper reports
/// (approximate extents read off the figure). Used by the fig05 bench to
/// reproduce the enable/disable decision per model.
pub fn paper_figure5_ranges() -> Vec<(&'static str, f64, f64)> {
    vec![
        ("llama2-7b", -16.8, 6.5),
        ("llama2-13b", -15.0, 6.0),
        ("chatglm2-6b", -14.0, 5.5),
        // OPT's range is reported as far wider — the paper disables C1.
        ("opt-6.7b", -60.0, 30.0),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_from(lo: f64, hi: f64, n: usize) -> SoftmaxInputStats {
        let mut s = SoftmaxInputStats::new();
        for i in 0..n {
            s.push(lo + (hi - lo) * i as f64 / (n - 1) as f64);
        }
        s
    }

    #[test]
    fn welford_matches_closed_form() {
        let mut s = SoftmaxInputStats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!((s.min, s.max), (1.0, 4.0));
    }

    #[test]
    fn narrow_range_enables_async() {
        let s = stats_from(-16.8, 6.5, 1000); // Llama2-7B's Figure 5 range
        let p = derive_policy(&s);
        assert!(p.enabled);
        assert!(p.expected_recompute_rate < 0.01);
        // phi centers the distribution
        assert!((p.phi - s.mean).abs() < 1e-9);
    }

    #[test]
    fn wide_range_disables_async_opt_rule() {
        let s = stats_from(-60.0, 30.0, 1000); // OPT-6.7B
        let p = derive_policy(&s);
        assert!(!p.enabled, "OPT-style wide range must disable C1");
    }

    #[test]
    fn paper_ranges_reproduce_decisions() {
        for (name, lo, hi) in paper_figure5_ranges() {
            let p = derive_policy(&stats_from(lo, hi, 512));
            let want = name != "opt-6.7b";
            assert_eq!(p.enabled, want, "{name}");
        }
    }

    #[test]
    fn empty_stats_safe_default() {
        let p = derive_policy(&SoftmaxInputStats::new());
        assert!(!p.enabled);
    }
}
