//! Engine time source: a monotone clock that is either the system's
//! `Instant` (production) or a manually advanced virtual clock (the
//! deterministic simulation path).
//!
//! The serving stack never reads `Instant::now()` directly on the
//! request path; everything flows through a [`Clock`] owned by the
//! engine. The real [`crate::engine::Engine`] uses [`Clock::system`];
//! [`crate::simengine::SimEngine`] uses [`Clock::manual`], advancing a
//! fixed quantum per step, so every latency, idle timeout, and
//! pause/resume decision in a simulation is a pure function of the
//! scenario — byte-identical across runs. The simulation-test harness
//! re-exports this type as `simtest::SimClock`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Monotone time source; timestamps are [`Duration`]s since the clock's
/// creation (epoch zero), so they are plain data and order naturally.
#[derive(Debug, Clone)]
pub struct Clock {
    inner: ClockInner,
}

#[derive(Debug, Clone)]
enum ClockInner {
    /// Wall time relative to the creation instant.
    System(Instant),
    /// Virtual nanoseconds, advanced explicitly. Shared: clones observe
    /// (and may advance) the same timeline.
    Manual(Arc<AtomicU64>),
}

impl Clock {
    /// A real-time clock backed by `Instant` (production engines).
    pub fn system() -> Self {
        Clock {
            inner: ClockInner::System(Instant::now()),
        }
    }

    /// A virtual clock starting at zero that only moves when
    /// [`Clock::advance`] is called (simulation engines and tests).
    pub fn manual() -> Self {
        Clock {
            inner: ClockInner::Manual(Arc::new(AtomicU64::new(0))),
        }
    }

    /// True for manually advanced (virtual) clocks.
    pub fn is_manual(&self) -> bool {
        matches!(self.inner, ClockInner::Manual(_))
    }

    /// Time elapsed since the clock's epoch.
    pub fn now(&self) -> Duration {
        match &self.inner {
            ClockInner::System(base) => base.elapsed(),
            ClockInner::Manual(ns) => Duration::from_nanos(ns.load(Ordering::Acquire)),
        }
    }

    /// Advance a manual clock by `d`. No-op on a system clock (real
    /// time cannot be steered).
    pub fn advance(&self, d: Duration) {
        if let ClockInner::Manual(ns) = &self.inner {
            ns.fetch_add(d.as_nanos() as u64, Ordering::AcqRel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_moves_only_on_advance() {
        let c = Clock::manual();
        assert!(c.is_manual());
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(3));
        assert_eq!(c.now(), Duration::from_millis(3));
        c.advance(Duration::from_micros(500));
        assert_eq!(c.now(), Duration::from_micros(3500));
    }

    #[test]
    fn manual_clones_share_the_timeline() {
        let a = Clock::manual();
        let b = a.clone();
        a.advance(Duration::from_millis(7));
        assert_eq!(b.now(), Duration::from_millis(7));
        b.advance(Duration::from_millis(1));
        assert_eq!(a.now(), Duration::from_millis(8));
    }

    #[test]
    fn system_clock_is_monotone_and_ignores_advance() {
        let c = Clock::system();
        assert!(!c.is_manual());
        let t0 = c.now();
        c.advance(Duration::from_secs(3600)); // must not jump
        let t1 = c.now();
        assert!(t1 >= t0);
        assert!(t1 < Duration::from_secs(600), "advance must be a no-op");
    }
}
