//! Minimal CLI argument parser (in-tree substrate, offline build):
//! `--flag value` / `--flag=value` / boolean `--flag`, with a positional
//! subcommand, typed getters and defaults.

use std::collections::HashMap;

use crate::error::{Error, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.bools.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                return Err(Error::Config(format!("unexpected positional argument '{a}'")));
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not an integer"))),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not a number"))),
        }
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.get(key) == Some("true")
    }

    pub fn required(&self, key: &str) -> Result<String> {
        self.get(key)
            .map(|s| s.to_string())
            .ok_or_else(|| Error::Config(format!("missing required flag --{key}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --addr 0.0.0.0:1 --sync-softmax --reps=5");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("addr"), Some("0.0.0.0:1"));
        assert!(a.bool_flag("sync-softmax"));
        assert_eq!(a.usize_or("reps", 1).unwrap(), 5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        assert!(a.usize_or("n", 0).is_err());
        assert!(a.required("nope").is_err());
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }
}
