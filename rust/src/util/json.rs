//! Minimal JSON parser/serializer (in-tree substrate — this build is
//! fully offline, so the manifest/lookup-table interchange runs on this
//! module instead of serde_json).
//!
//! Supports the full JSON grammar except exotic number forms; numbers
//! are held as f64 (adequate for manifest shapes and profile times).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the path name.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.field(key)?
            .as_usize()
            .ok_or_else(|| Error::Json(format!("field '{key}' not a number")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.field(key)?
            .as_f64()
            .ok_or_else(|| Error::Json(format!("field '{key}' not a number")))
    }

    pub fn req_str(&self, key: &str) -> Result<String> {
        Ok(self
            .field(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("field '{key}' not a string")))?
            .to_string())
    }

    pub fn req_bool(&self, key: &str) -> Result<bool> {
        self.field(key)?
            .as_bool()
            .ok_or_else(|| Error::Json(format!("field '{key}' not a bool")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.field(key)?
            .as_arr()
            .ok_or_else(|| Error::Json(format!("field '{key}' not an array")))
    }

    // ---- construction ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- serialization --------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(Error::Json(format!("trailing data at byte {}", p.i)));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| Error::Json("unexpected end of input".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Json("bad \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Json("bad escape".into())),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let len = utf8_len(c);
                    if len == 1 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(Error::Json("truncated utf-8".into()));
                        }
                        s.push_str(
                            std::str::from_utf8(&self.b[start..end])
                                .map_err(|_| Error::Json("bad utf-8".into()))?,
                        );
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{s}' at byte {start}")))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let j = parse(r#"{"model": {"dim": 256, "name": "tiny"}, "entries": [{"n": 1.5}], "ok": true, "none": null}"#)
            .unwrap();
        assert_eq!(j.field("model").unwrap().req_usize("dim").unwrap(), 256);
        assert_eq!(j.field("model").unwrap().req_str("name").unwrap(), "tiny");
        assert_eq!(j.req_arr("entries").unwrap()[0].req_f64("n").unwrap(), 1.5);
        assert!(j.req_bool("ok").unwrap());
        assert_eq!(j.field("none").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Num(-2.5)])),
            ("s", Json::Str("he\"llo\n→".into())),
            ("b", Json::Bool(false)),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn escapes() {
        let j = parse(r#""aA\t\\""#).unwrap();
        assert_eq!(j.as_str(), Some("aA\t\\"));
    }

    #[test]
    fn errors_are_errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn nested_deep() {
        let j = parse(r#"[[[[1]]], {"x": [{"y": "z"}]}]"#).unwrap();
        assert!(matches!(j, Json::Arr(_)));
    }
}
