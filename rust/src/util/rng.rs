//! Deterministic PRNG (in-tree substrate, offline build): xoshiro256++
//! seeded via splitmix64. Used by the sampler, the workload generator and
//! the property-test harness.

/// One splitmix64 step: add the golden-ratio increment and finalize.
/// The single authoritative copy of the constants — the RNG seeding,
/// the simulation-test fingerprint, and the simtest CLI's entropy mix
/// all call this instead of re-implementing it.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // splitmix64 expansion (reference initialization).
        let mut x = seed;
        let mut next = || {
            let out = splitmix64(x);
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            out
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as usize
    }

    /// Uniform f32 in [lo, hi).
    pub fn gen_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal (Box-Muller).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate lambda (Poisson inter-arrivals).
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-12).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
            let i = r.gen_range(5, 9);
            assert!((5..=9).contains(&i));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::seed_from_u64(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
