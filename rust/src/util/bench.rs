//! Micro-bench harness (in-tree criterion substitute): warmup + timed
//! iterations with mean / median / p95 reporting and a black_box.

use std::time::Instant;

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<42} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt(self.mean_s),
            fmt(self.median_s),
            fmt(self.p95_s),
        );
    }
}

fn fmt(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run a closure with warmup, then measure per-iteration wall time.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let p95_idx = ((times.len() as f64 * 0.95) as usize).min(times.len() - 1);
    let r = BenchResult {
        name: name.to_string(),
        iters: times.len(),
        mean_s: mean,
        median_s: times[times.len() / 2],
        p95_s: times[p95_idx],
        min_s: times[0],
    };
    r.report();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop", 2, 50, || {
            black_box(1 + 1);
        });
        assert!(r.mean_s >= 0.0);
        assert!(r.median_s <= r.p95_s + 1e-9);
        assert_eq!(r.iters, 50);
    }
}
