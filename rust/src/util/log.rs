//! Tiny leveled logger (in-tree substrate): level from `FDPP_LOG`
//! (error|warn|info|debug, default info), timestamps relative to process
//! start.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

/// Initialize from the environment; idempotent.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("FDPP_LOG") {
        let lvl = match v.to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    }
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{:>9.4}s {tag}] {msg}", t.as_secs_f64());
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        assert!(enabled(Level::Error));
        LEVEL.store(Level::Warn as u8, Ordering::Relaxed);
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        LEVEL.store(Level::Info as u8, Ordering::Relaxed);
    }
}
