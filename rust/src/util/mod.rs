//! In-tree substrates for the fully-offline build: JSON, CLI parsing,
//! deterministic RNG, logging, and the micro-bench harness. These stand
//! in for serde_json / clap / rand / tracing / criterion (DESIGN.md §3).

pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod log;
pub mod rng;
