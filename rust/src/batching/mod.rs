//! Continuous batching: decode-bucket selection and *sticky-lane* batch
//! assembly.
//!
//! Decode artifacts exist for fixed batch buckets (1/2/4/8). Lanes are
//! sticky: a sequence keeps its lane for its whole life, finished lanes
//! become holes that later admissions fill. Sticky lanes are what make
//! the device-side KV-insert fast path possible (EXPERIMENTS.md §Perf):
//! joining a batch never shifts other sequences, so the dense device
//! cache stays valid and only the new lane is spliced in on device.
//! Bucket *growth* (more running sequences than lanes) and *shrink*
//! (compaction when occupancy drops to the previous bucket) are the only
//! events that force a host-side dense rebuild.

use crate::error::{Error, Result};
use crate::kvcache::SeqId;

/// Pick the smallest bucket >= n; None if n exceeds the largest bucket.
pub fn pick_bucket(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

/// Pick the smallest prefill sequence bucket >= len.
pub fn pick_prefill_bucket(buckets: &[usize], len: usize) -> Option<usize> {
    pick_bucket(buckets, len)
}

/// The decode batch the engine will execute this step.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DecodeBatch {
    /// `lanes[i]` holds the sequence in lane i; None = padding hole.
    pub lanes: Vec<Option<SeqId>>,
    pub bucket: usize,
}

impl DecodeBatch {
    pub fn occupancy(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }
}

/// What happened to the lane layout on admit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admission {
    pub lane: usize,
    /// The bucket grew — the dense device cache must be rebuilt.
    pub bucket_grew: bool,
}

/// Tracks the running set with sticky lanes.
#[derive(Debug, Default)]
pub struct Batcher {
    buckets: Vec<usize>,
    lanes: Vec<Option<SeqId>>,
    count: usize,
}

impl Batcher {
    pub fn new(buckets: Vec<usize>) -> Self {
        Batcher {
            buckets,
            lanes: Vec::new(),
            count: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    pub fn bucket(&self) -> usize {
        self.lanes.len()
    }

    /// Sequence ids currently running, in lane order.
    pub fn running_ids(&self) -> Vec<SeqId> {
        self.lanes.iter().filter_map(|l| *l).collect()
    }

    /// [`Batcher::running_ids`] into a caller-owned buffer (cleared
    /// first) — the step loop's allocation-free variant; the buffer's
    /// capacity ratchets up to the largest bucket and stays there.
    pub fn running_ids_into(&self, out: &mut Vec<SeqId>) {
        out.clear();
        out.extend(self.lanes.iter().filter_map(|l| *l));
    }

    /// Iterate running ids in lane order without allocating.
    pub fn iter_running(&self) -> impl Iterator<Item = SeqId> + '_ {
        self.lanes.iter().filter_map(|l| *l)
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.lanes.contains(&Some(id))
    }

    /// Admit a sequence: fill the first hole, growing the bucket if full.
    pub fn admit(&mut self, id: SeqId) -> Result<Admission> {
        if self.contains(id) {
            return Err(Error::Schedule(format!("seq {id} already running")));
        }
        if self.count >= self.max_bucket() {
            return Err(Error::Schedule("running set full".into()));
        }
        let mut grew = false;
        if self.count == self.lanes.len() {
            let next = pick_bucket(&self.buckets, self.count + 1)
                .ok_or_else(|| Error::Schedule("no bucket fits".into()))?;
            self.lanes.resize(next, None);
            grew = true;
        }
        let lane = self
            .lanes
            .iter()
            .position(|l| l.is_none())
            .expect("hole must exist after resize");
        self.lanes[lane] = Some(id);
        self.count += 1;
        Ok(Admission {
            lane,
            bucket_grew: grew,
        })
    }

    /// Remove a finished/preempted sequence; its lane becomes a hole.
    /// Returns true when the bucket shrank (compaction -> rebuild).
    pub fn remove(&mut self, id: SeqId) -> Result<bool> {
        let lane = self
            .lanes
            .iter()
            .position(|l| *l == Some(id))
            .ok_or_else(|| Error::Schedule(format!("seq {id} not running")))?;
        self.lanes[lane] = None;
        self.count -= 1;
        // Shrink when occupancy fits the next smaller bucket (hysteresis:
        // exact fit only, so a single finish can't thrash).
        let target = pick_bucket(&self.buckets, self.count.max(1)).unwrap_or(0);
        if self.count == 0 {
            self.lanes.clear();
            return Ok(true);
        }
        if target < self.lanes.len() {
            let survivors: Vec<Option<SeqId>> =
                self.lanes.iter().filter(|l| l.is_some()).cloned().collect();
            self.lanes = survivors;
            self.lanes.resize(target, None);
            return Ok(true);
        }
        Ok(false)
    }

    /// Assemble the decode batch for this step (sticky lane order).
    pub fn assemble(&self) -> Result<DecodeBatch> {
        if self.count == 0 {
            return Err(Error::Schedule("nothing to decode".into()));
        }
        Ok(DecodeBatch {
            lanes: self.lanes.clone(),
            bucket: self.lanes.len(),
        })
    }

    /// [`Batcher::assemble`] into a caller-owned batch (lanes cleared
    /// and refilled) — the step loop's allocation-free variant.
    pub fn assemble_into(&self, out: &mut DecodeBatch) -> Result<()> {
        if self.count == 0 {
            return Err(Error::Schedule("nothing to decode".into()));
        }
        out.lanes.clear();
        out.lanes.extend_from_slice(&self.lanes);
        out.bucket = self.lanes.len();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let b = vec![1, 2, 4, 8];
        assert_eq!(pick_bucket(&b, 1), Some(1));
        assert_eq!(pick_bucket(&b, 3), Some(4));
        assert_eq!(pick_bucket(&b, 8), Some(8));
        assert_eq!(pick_bucket(&b, 9), None);
    }

    #[test]
    fn sticky_lane_admission_and_growth() {
        let mut b = Batcher::new(vec![1, 2, 4]);
        let a0 = b.admit(10).unwrap();
        assert_eq!((a0.lane, a0.bucket_grew), (0, true));
        assert_eq!(b.bucket(), 1);
        let a1 = b.admit(11).unwrap();
        assert_eq!((a1.lane, a1.bucket_grew), (1, true));
        assert_eq!(b.bucket(), 2);
        let a2 = b.admit(12).unwrap();
        assert!(a2.bucket_grew);
        assert_eq!(b.bucket(), 4);
        // lane 3 is a hole; next admit fills it without growth.
        let a3 = b.admit(13).unwrap();
        assert_eq!((a3.lane, a3.bucket_grew), (3, false));
    }

    #[test]
    fn holes_are_reused_without_shifting() {
        let mut b = Batcher::new(vec![1, 2, 4]);
        for id in [1, 2, 3, 4] {
            b.admit(id).unwrap();
        }
        assert_eq!(b.bucket(), 4);
        // Remove one; occupancy 3 still needs bucket 4 -> no shrink, and
        // the others keep their lanes.
        let shrank = b.remove(2).unwrap();
        assert!(!shrank);
        let batch = b.assemble().unwrap();
        assert_eq!(batch.lanes, vec![Some(1), None, Some(3), Some(4)]);
        // The hole is refilled in place.
        let a = b.admit(5).unwrap();
        assert_eq!((a.lane, a.bucket_grew), (1, false));
    }

    #[test]
    fn shrink_compacts_lanes() {
        let mut b = Batcher::new(vec![1, 2, 4]);
        for id in [1, 2, 3] {
            b.admit(id).unwrap();
        }
        b.remove(2).unwrap(); // occupancy 2 -> target bucket 2 -> shrink
        // NOTE: remove(2) leaves occupancy 2 which fits bucket 2 exactly.
        assert_eq!(b.bucket(), 2);
        let batch = b.assemble().unwrap();
        assert_eq!(batch.lanes, vec![Some(1), Some(3)]);
        b.remove(1).unwrap();
        assert_eq!(b.bucket(), 1);
        b.remove(3).unwrap();
        assert!(b.is_empty());
        assert_eq!(b.bucket(), 0);
    }

    #[test]
    fn admit_limits() {
        let mut b = Batcher::new(vec![1, 2]);
        b.admit(1).unwrap();
        assert!(b.admit(1).is_err(), "duplicate admit");
        b.admit(2).unwrap();
        assert!(b.admit(3).is_err(), "over max bucket");
    }

    #[test]
    fn empty_assemble_errors() {
        let b = Batcher::new(vec![1]);
        assert!(b.assemble().is_err());
    }

    #[test]
    fn running_ids_in_lane_order() {
        let mut b = Batcher::new(vec![4]);
        for id in [9, 7, 8] {
            b.admit(id).unwrap();
        }
        assert_eq!(b.running_ids(), vec![9, 7, 8]);
        b.remove(7).unwrap();
        assert_eq!(b.running_ids(), vec![9, 8]);
    }
}
