//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + weight .npy files + manifest.json) and executes them on
//! the PJRT CPU client from the Rust hot path.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use xla::FromRawBytes;

use crate::error::{Error, Result};
use crate::util::json::{parse, Json};

/// Tensor spec in the manifest.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub params: Json,
    pub inputs: Vec<TensorSpec>,
    pub num_outputs: usize,
    pub takes_weights: bool,
}

#[derive(Debug, Clone)]
pub struct WeightMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub file: String,
}

/// Model metadata recorded by aot.py.
#[derive(Debug, Clone)]
pub struct ManifestModel {
    pub name: String,
    pub vocab_size: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn_hidden: usize,
    pub max_seq: usize,
    pub phi: f64,
    pub softmax_a: f64,
    pub softmax_b: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ManifestModel,
    pub softmax_input_stats: Json,
    pub weight_order: Vec<String>,
    pub weights: Vec<WeightMeta>,
    pub entries: Vec<EntryMeta>,
    pub linear_shapes: HashMap<String, (usize, usize)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Artifact(format!("{}: {e}", path.display())))?;
        Self::from_json(&parse(&text)?)
    }

    fn tensor_spec(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: j
                .req_arr("shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            dtype: j.req_str("dtype")?,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let m = j.field("model")?;
        let model = ManifestModel {
            name: m.req_str("name")?,
            vocab_size: m.req_usize("vocab_size")?,
            dim: m.req_usize("dim")?,
            n_layers: m.req_usize("n_layers")?,
            n_heads: m.req_usize("n_heads")?,
            head_dim: m.req_usize("head_dim")?,
            ffn_hidden: m.req_usize("ffn_hidden")?,
            max_seq: m.req_usize("max_seq")?,
            phi: m.req_f64("phi")?,
            softmax_a: m.req_f64("softmax_a")?,
            softmax_b: m.req_f64("softmax_b")?,
        };
        let weight_order = j
            .req_arr("weight_order")?
            .iter()
            .filter_map(Json::as_str)
            .map(str::to_string)
            .collect();
        let mut weights = Vec::new();
        for w in j.req_arr("weights")? {
            weights.push(WeightMeta {
                name: w.req_str("name")?,
                shape: w
                    .req_arr("shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                dtype: w.req_str("dtype")?,
                file: w.req_str("file")?,
            });
        }
        let mut entries = Vec::new();
        for e in j.req_arr("entries")? {
            let mut inputs = Vec::new();
            for i in e.req_arr("inputs")? {
                inputs.push(Self::tensor_spec(i)?);
            }
            entries.push(EntryMeta {
                name: e.req_str("name")?,
                file: e.req_str("file")?,
                kind: e.req_str("kind")?,
                params: e.get("params").cloned().unwrap_or(Json::Null),
                inputs,
                num_outputs: e.req_usize("num_outputs")?,
                takes_weights: e.req_bool("takes_weights")?,
            });
        }
        let mut linear_shapes = HashMap::new();
        if let Some(Json::Obj(ls)) = j.get("linear_shapes") {
            for (k, v) in ls {
                if let Some(arr) = v.as_arr() {
                    if arr.len() == 2 {
                        linear_shapes.insert(
                            k.clone(),
                            (
                                arr[0].as_usize().unwrap_or(0),
                                arr[1].as_usize().unwrap_or(0),
                            ),
                        );
                    }
                }
            }
        }
        Ok(Manifest {
            model,
            softmax_input_stats: j.get("softmax_input_stats").cloned().unwrap_or(Json::Null),
            weight_order,
            weights,
            entries,
            linear_shapes,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::Artifact(format!("no entry {name} in manifest")))
    }

    /// Decode entry name for a batch bucket (async or sync variant).
    pub fn decode_entry_name(batch: usize, sync: bool) -> String {
        if sync {
            format!("decode_b{batch}_sync")
        } else {
            format!("decode_b{batch}")
        }
    }

    pub fn prefill_entry_name(seq: usize) -> String {
        format!("prefill_s{seq}")
    }
}

/// The PJRT execution engine: compiled-executable cache + weights.
///
/// Not `Send`: the engine thread owns it; the server talks to it over
/// channels (vLLM-router style single-owner hot loop).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    /// Weight literals in manifest order (prepended to entry inputs).
    weights: Vec<xla::Literal>,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Compile-time accounting (startup cost, reported by `fdpp inspect`).
    pub compile_seconds: f64,
}

impl Runtime {
    /// Load manifest + weights and initialize the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut weights = Vec::with_capacity(manifest.weights.len());
        for w in &manifest.weights {
            let path = dir.join(&w.file);
            let lit = xla::Literal::read_npy(&path, &())
                .map_err(|e| Error::Artifact(format!("weight {}: {e}", w.name)))?;
            weights.push(lit);
        }
        Ok(Runtime {
            client,
            dir,
            manifest,
            weights,
            execs: HashMap::new(),
            compile_seconds: 0.0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for an entry.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.execs.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.entry(name)?.clone();
        let path = self.dir.join(&entry.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compile_seconds += t0.elapsed().as_secs_f64();
        self.execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry with the given non-weight inputs; returns the
    /// decomposed output tuple as literals. Inputs are borrowed — the
    /// decode hot path passes its device-resident KV literals without
    /// copying them.
    pub fn execute(&mut self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let entry = self.manifest.entry(name)?;
        let takes_weights = entry.takes_weights;
        let expected = entry.inputs.len();
        let exe = self.execs.get(name).unwrap();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.weights.len() + inputs.len());
        if takes_weights {
            args.extend(self.weights.iter());
        }
        args.extend(inputs.iter());
        if args.len() != expected {
            return Err(Error::Artifact(format!(
                "entry {name}: expected {expected} inputs, got {}",
                args.len()
            )));
        }
        let result = exe.execute::<&xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// Number of entries available.
    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.iter().map(|e| e.name.clone()).collect()
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape from a host slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Artifact(format!(
            "literal_f32: {} elements for shape {:?}",
            data.len(),
            shape
        )));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Artifact(format!(
            "literal_i32: {} elements for shape {:?}",
            data.len(),
            shape
        )));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(literal_f32(&[1.0], &[2]).is_err());
    }

    #[test]
    fn entry_name_helpers() {
        assert_eq!(Manifest::decode_entry_name(4, false), "decode_b4");
        assert_eq!(Manifest::decode_entry_name(1, true), "decode_b1_sync");
        assert_eq!(Manifest::prefill_entry_name(32), "prefill_s32");
    }
}
