//! C2 analytics — flat GEMM tiling (paper §4).
//!
//! Implements Eq. (5): the computation/memory ratio of a flat GEMM tiled
//! as (B_N, B_K), the parallelism `N / B_N`, the padding-waste model that
//! motivates pad-to-8, and the B_N chooser the paper derives from the two
//! regimes (small N parallelism-bound, large N memory-bound).

/// Tiling configuration of one flat GEMM launch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Tiling {
    pub b_n: usize,
    pub b_k: usize,
    /// Whether the double-buffering schedule is enabled (large-N regime).
    pub double_buffer: bool,
}

/// Eq. (5): computation/memory ratio of a flat GEMM [M,K]x[K,N] tiled by
/// (B_N, B_K). Simplified closed form: 2*M*K / (K + M*K/B_N + M).
pub fn compute_memory_ratio(m: usize, k: usize, b_n: usize) -> f64 {
    let (m, k, b_n) = (m as f64, k as f64, b_n as f64);
    2.0 * m * k / (k + m * k / b_n + m)
}

/// Thread-block parallelism of the launch: N / B_N (K tiles are
/// sequential within a block to avoid reduction atomics, §4).
pub fn parallelism(n: usize, b_n: usize) -> usize {
    n.div_ceil(b_n)
}

/// Fraction of the MAC array doing useful work when M is padded to
/// `pad_to` (previous designs: 64; FlashDecoding++: 8).
pub fn padding_utilization(m: usize, pad_to: usize) -> f64 {
    let padded = m.div_ceil(pad_to) * pad_to;
    m as f64 / padded as f64
}

/// The paper's B_N heuristic: keep `N / B_N` close to the hardware
/// parallelism (number of SMs) for small N — parallelism-bound regime —
/// and grow B_N (enabling double buffering) once N is large enough that
/// memory latency dominates.
pub fn choose_tiling(n: usize, k: usize, sms: usize) -> Tiling {
    // Target ~1-2 waves of blocks across the SMs.
    let target_blocks = (sms * 2).max(1);
    let mut b_n = 16;
    while n / b_n > target_blocks && b_n < 512 {
        b_n *= 2;
    }
    // Large-N regime: plenty of blocks even at big tiles -> memory-bound;
    // enable double buffering (paper §4 "we apply such a technique when N
    // is large").
    let double_buffer = n / b_n >= sms;
    let b_k = if k >= 4096 { 64 } else { 32.min(k.max(8)) };
    Tiling {
        b_n,
        b_k,
        double_buffer,
    }
}

/// All power-of-two B_N candidates in a sweep range (Figure 7's x-axis).
pub fn bn_candidates() -> Vec<usize> {
    vec![16, 32, 64, 128, 256, 512]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq5_increases_with_bn() {
        // The computation/memory ratio is positively correlated with B_N.
        let mut prev = 0.0;
        for b_n in [16, 32, 64, 128, 256] {
            let r = compute_memory_ratio(8, 4096, b_n);
            assert!(r > prev, "ratio must increase with B_N");
            prev = r;
        }
    }

    #[test]
    fn eq5_closed_form_spot_check() {
        // 2*M*K / (K + M*K/B_N + M) with M=8, K=4096, B_N=128.
        let want = 2.0 * 8.0 * 4096.0 / (4096.0 + 8.0 * 4096.0 / 128.0 + 8.0);
        assert!((compute_memory_ratio(8, 4096, 128) - want).abs() < 1e-9);
    }

    #[test]
    fn parallelism_decreases_with_bn() {
        assert_eq!(parallelism(4096, 32), 128);
        assert_eq!(parallelism(4096, 256), 16);
        assert!(parallelism(4096, 32) > parallelism(4096, 256));
    }

    #[test]
    fn padding_math_matches_paper() {
        // §1: pad-to-64 at batch 8 wastes >87% of the MACs.
        assert!((padding_utilization(8, 64) - 0.125).abs() < 1e-12);
        // FlashDecoding++ pads to 8: fully utilized at batch 8.
        assert!((padding_utilization(8, 8) - 1.0).abs() < 1e-12);
        // and M=3 still wastes less at pad-8 than pad-64.
        assert!(padding_utilization(3, 8) > padding_utilization(3, 64));
    }

    #[test]
    fn tiling_regimes() {
        let sms = 108; // A100
        // Small N: parallelism-bound -> small B_N, N/B_N near 2*SMs.
        let small = choose_tiling(2048, 4096, sms);
        assert!(small.b_n <= 32);
        // Large N: memory-bound -> bigger tiles + double buffering.
        let large = choose_tiling(32768, 4096, sms);
        assert!(large.b_n > small.b_n);
        assert!(large.double_buffer);
    }

    #[test]
    fn choose_tiling_parallelism_near_constant() {
        // Paper insight: N/B_N tends to a constant related to SM count.
        let sms = 108;
        for n in [4096, 8192, 16384, 32768] {
            let t = choose_tiling(n, 4096, sms);
            let par = parallelism(n, t.b_n);
            assert!(
                par >= sms && par <= 4 * sms,
                "N={n}: parallelism {par} strays from SM count"
            );
        }
    }
}
