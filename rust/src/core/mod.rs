//! The generic serving core: one orchestration loop, many backends.
//!
//! [`EngineCore<B>`] owns everything FlashDecoding++ calls the
//! *dataflow* side of serving — admission (via
//! [`crate::policy::plan_admission`]), prefill/decode stepping, stream
//! flow control ([`crate::policy::plan_stream_ops`]), preemption and
//! admission relief, idle expiry, cross-request dedup, per-tenant
//! quotas, finish/usage accounting, [`TraceEvent`] emission, and the
//! [`EngineCore::audit`] snapshot the simulation-test oracles run on.
//! A [`Backend`] supplies only the *compute* side: how prompt and token
//! KV is materialized, where logits come from, and any device-resident
//! state that must track batch composition.
//!
//! Before this module existed, `engine` (PJRT) and `simengine` (the
//! deterministic hash model) each carried a full copy of the step loop;
//! only `policy` was shared, and surfaces like tracing and `audit()`
//! existed on the sim twin alone. Now both are thin [`Backend`] impls —
//! [`crate::engine::Engine`] and [`crate::simengine::SimEngine`] are
//! type aliases over this core — so every orchestration feature lands
//! once and the production path exposes the same trace/audit surface
//! the simulation tests rely on.
//!
//! # Invariant ownership
//!
//! The core, not the backend, is responsible for:
//!
//! - **KV block accounting**: every sequence the core retires goes
//!   through [`EngineCore::finish_seq`]; blocks are freed exactly once
//!   and the prefix cache's retained references are the only other
//!   owners ([`check_kv_conservation`]).
//! - **Stream losslessness**: stream credit is checked *before* a
//!   sequence decodes, so a generated token always has a slot.
//! - **Priority monotonicity**: preemption victims come from the shared
//!   policy census; the trace records the candidate pool so oracles can
//!   verify the choice without trusting it.
//! - **Usage conservation**: per-request cached + prefill partitions
//!   the prompt; finish events carry the record.
//!
//! A backend must uphold only its local contract (see [`Backend`]):
//! write the KV it is asked to write, return one logits row per
//! occupied lane, and keep any device-side state consistent through the
//! batch-membership hooks. It must not touch sequence lifecycle,
//! metrics counters the core owns, or the prefix cache.

pub mod stub;

use std::collections::HashMap;
use std::fmt::Write as _;
use std::time::Duration;

use crate::api::{
    FinishReason, GenRequest, InferenceEngine, RequestId, SubmissionHandle, Usage, Wakeup,
};
use crate::batching::{Admission, Batcher, DecodeBatch};
use crate::config::EngineConfig;
use crate::error::{Error, Result};
use crate::kvcache::{KvAudit, KvCache, KvGeometry, SeqId};
use crate::metrics::EngineMetrics;
use crate::obs::{FlightRecorder, SpanTable};
use crate::policy::{self, StreamOp, StreamVerdict};
use crate::prefixcache::PrefixCache;
use crate::router::{self, Router, SeqState, Sequence, SubmitContext};
use crate::sampling::Sampler;
use crate::scheduler::{decide, preemption_victim, Action, PreemptCandidate};
use crate::tokenizer::{ByteTokenizer, EOS};
use crate::util::clock::Clock;
use crate::util::json::Json;

pub use stub::{StubBackend, StubEngine};

// ---------------------------------------------------------------------
// Trace and audit surface (production and simulation alike)
// ---------------------------------------------------------------------

/// One observable scheduling event, recorded when tracing is enabled
/// ([`EngineCore::enable_trace`]). The simulation-test harness replays
/// scenarios and checks its oracles against this stream; it is also
/// what makes two runs comparably *byte-identical* (equal traces). The
/// real PJRT engine records the same events, so production debugging
/// sees exactly what simtest sees.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A request was admitted (prefill ran); `cached` prompt tokens
    /// were served from the prefix cache.
    Admitted { id: SeqId, cached: usize },
    /// One generated token was emitted to the request's stream.
    Token { id: SeqId, token: u32 },
    /// The sequence was parked by stream backpressure.
    Paused { id: SeqId },
    /// A parked sequence rejoined the decode batch.
    Resumed { id: SeqId },
    /// A parked sequence sat idle past `stream_idle_timeout` and was
    /// demoted to `Overrun`.
    Expired { id: SeqId },
    /// Decode-pressure preemption: the chosen victim, its priority, and
    /// the full candidate pool `(id, priority)` the choice ran over —
    /// recorded so an external oracle can verify priority monotonicity
    /// without trusting the policy it is checking.
    Preempted {
        id: SeqId,
        priority: i32,
        pool: Vec<(SeqId, i32)>,
    },
    /// Admission-relief preemption of a parked victim on behalf of a
    /// blocked higher-priority waiter.
    AdmissionRelief {
        id: SeqId,
        priority: i32,
        waiter_priority: i32,
    },
    /// The request finished; exactly one per request.
    Finished {
        id: SeqId,
        reason: FinishReason,
        usage: Usage,
    },
}

/// One live sequence in an [`EngineAudit`] snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LiveSeq {
    pub id: SeqId,
    pub priority: i32,
    pub paused: bool,
}

/// A full accounting snapshot of an engine's shared state, taken
/// between steps by the simulation-test oracles (and, summarized, by
/// the production `{"stats": true}` reply): the KV allocator's books,
/// the prefix tree's retained block references, and the live sequence
/// set.
#[derive(Debug, Clone)]
pub struct EngineAudit {
    pub kv: KvAudit,
    /// Blocks retained by the prefix tree, one entry per tree-held
    /// reference.
    pub tree_blocks: Vec<usize>,
    pub live: Vec<LiveSeq>,
    pub queued: usize,
}

/// One walk of the allocator's books: the first violation found (the
/// oracle's error) and the count of blocks whose refcount disagrees
/// with their visible owners (the stats gauge). Shared by the oracle
/// entry point and the stats summary so neither walks the pool twice.
fn audit_accounting(audit: &EngineAudit) -> (Option<String>, usize) {
    let total = audit.kv.total_blocks;
    if audit.kv.refcounts.len() != total {
        return (Some("audit refcount table does not cover the pool".into()), 0);
    }
    fn note(e: String, error: &mut Option<String>) {
        if error.is_none() {
            *error = Some(e);
        }
    }
    let mut error: Option<String> = None;
    let mut owners = vec![0u32; total];
    for (id, blocks) in &audit.kv.seq_blocks {
        for &b in blocks {
            if b >= total {
                note(format!("seq {id} references out-of-pool block {b}"), &mut error);
            } else {
                owners[b] += 1;
            }
        }
    }
    for &b in &audit.tree_blocks {
        if b >= total {
            note(format!("prefix tree references out-of-pool block {b}"), &mut error);
        } else {
            owners[b] += 1;
        }
    }
    let mut in_free = vec![false; total];
    for &b in &audit.kv.free_list {
        if b >= total {
            note(format!("free list holds out-of-pool block {b}"), &mut error);
        } else if in_free[b] {
            note(
                format!("block {b} is on the free list twice (double free)"),
                &mut error,
            );
        } else {
            in_free[b] = true;
        }
    }
    let mut allocated = 0usize;
    let mut leaked = 0usize;
    for b in 0..total {
        let rc = audit.kv.refcounts[b];
        if rc != owners[b] {
            leaked += 1;
            note(
                format!(
                    "block {b}: refcount {rc} != {} visible owners (leak or double free)",
                    owners[b]
                ),
                &mut error,
            );
        }
        if (rc == 0) != in_free[b] {
            note(
                format!("block {b}: refcount {rc} but on-free-list={}", in_free[b]),
                &mut error,
            );
        }
        if rc > 0 {
            allocated += 1;
        }
    }
    if allocated + audit.kv.free_list.len() != total {
        note(
            format!(
                "allocated {allocated} + free {} != total {total}",
                audit.kv.free_list.len()
            ),
            &mut error,
        );
    }
    (error, leaked)
}

/// Compact one-line rendering of a [`TraceEvent`], written straight
/// into a flight-recorder entry buffer (human-readable in dumps and
/// violation reports; bounded in size even for large preemption
/// pools). Paired with [`FlightRecorder::record_with`], so a full ring
/// renders into recycled strings and the decode hot path records
/// without allocating.
fn flight_write(buf: &mut String, ev: &TraceEvent) {
    let _ = match ev {
        TraceEvent::Admitted { id, cached } => write!(buf, "admitted id={id} cached={cached}"),
        TraceEvent::Token { id, token } => write!(buf, "token id={id} tok={token}"),
        TraceEvent::Paused { id } => write!(buf, "paused id={id}"),
        TraceEvent::Resumed { id } => write!(buf, "resumed id={id}"),
        TraceEvent::Expired { id } => write!(buf, "expired id={id}"),
        TraceEvent::Preempted { id, priority, pool } => {
            write!(buf, "preempted id={id} prio={priority} pool={}", pool.len())
        }
        TraceEvent::AdmissionRelief {
            id,
            priority,
            waiter_priority,
        } => write!(
            buf,
            "admission_relief id={id} prio={priority} waiter_prio={waiter_priority}"
        ),
        TraceEvent::Finished { id, reason, usage } => write!(
            buf,
            "finished id={id} reason={} gen={}",
            reason.as_str(),
            usage.generated_tokens
        ),
    };
}

/// FNV-1a over a prompt's tokens: the in-flight dedup table's key.
/// Keying by hash instead of by owned prompt removes the per-admission
/// prompt `clone()`; collisions are harmless because every lookup
/// re-verifies the holder's actual prompt against the waiter's.
fn prompt_key(prompt: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in prompt {
        h = (h ^ t as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// KV refcount conservation over a full audit snapshot: every block's
/// refcount equals the owners visible in the audit (sequence block
/// tables + prefix-tree references); a block is on the free list
/// exactly when its refcount is zero; the free list holds no
/// duplicates. This is the simulation harness's oracle 1, shared here
/// so the production stats path can run the same check.
pub fn check_kv_conservation(audit: &EngineAudit) -> std::result::Result<(), String> {
    match audit_accounting(audit).0 {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Condensed audit verdict for the stats snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AuditSummary {
    /// The full refcount-conservation check passed.
    pub refcount_ok: bool,
    /// Blocks whose refcount disagrees with their visible owners.
    pub blocks_leaked: usize,
}

/// Summarize an audit for `{"stats": true}`: whether conservation
/// holds, and how many blocks have a refcount/owner mismatch — one
/// pool walk, shared with [`check_kv_conservation`].
pub fn audit_block_accounting(audit: &EngineAudit) -> AuditSummary {
    let (error, leaked) = audit_accounting(audit);
    AuditSummary {
        refcount_ok: error.is_none(),
        blocks_leaked: leaked,
    }
}

// ---------------------------------------------------------------------
// The backend contract
// ---------------------------------------------------------------------

/// Outcome of a backend prefill: the logits row for the prompt's last
/// real position, accelerator time spent (0 for host-only backends),
/// and an opaque artifact forwarded to [`Backend::on_batch_join`] when
/// the sequence enters the decode batch (the PJRT backend carries the
/// device K/V literals for the sticky-lane splice).
pub struct PrefillRun<A> {
    pub last_logits: Vec<f32>,
    pub exec_time: Duration,
    pub artifact: A,
}

/// Outcome of a backend decode step: one logits row per occupied lane,
/// in the order of the `inputs` slice, plus accelerator time spent.
///
/// Rows are views into one flat backing buffer so the PJRT backend can
/// hand its host logits tensor over without a per-lane copy on the
/// decode hot path; `offsets[i]` locates input i's row of `row_len`
/// elements.
pub struct DecodeRun {
    pub logits: Vec<f32>,
    pub offsets: Vec<usize>,
    pub row_len: usize,
    pub exec_time: Duration,
}

impl DecodeRun {
    /// Input `i`'s logits row.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.logits[self.offsets[i]..self.offsets[i] + self.row_len]
    }
}

/// One occupied decode lane's input for this step.
#[derive(Debug, Clone, Copy)]
pub struct LaneInput {
    pub lane: usize,
    pub id: SeqId,
    /// The input token (last generated token, or the prompt's last
    /// token right after prefill).
    pub token: u32,
    /// Its position: the sequence's current stored KV length.
    pub pos: usize,
}

/// One prefix-sharing group within a decode step, formed by the core
/// when [`crate::config::EngineConfig::grouped_decode`] is on and
/// handed to [`Backend::decode_grouped`]. Members physically share the
/// KV blocks of `prefix_blocks`, so a backend may compute the shared
/// prefix's attention partial once per group and merge it with each
/// member's divergent-suffix partial (unified-max softmax merging, see
/// [`crate::softmaxstats`]) instead of re-attending over the prefix
/// per sequence — the CoDec-style decode-side sibling of prefill
/// prefix reuse.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DecodeGroup {
    /// Stable per-step group id (index into this step's group list,
    /// in ascending first-shared-block order).
    pub id: usize,
    /// Physical KV block ids of the shared prefix, in chain order;
    /// every member's block table starts with exactly this chain.
    pub prefix_blocks: Vec<usize>,
    /// Token length of the shared prefix: always a whole number of
    /// blocks, and at most every member's stored KV length (so every
    /// prefix position is a stored position for every member).
    pub prefix_tokens: usize,
    /// Indices into the step's `inputs` slice, in input (lane) order.
    /// Always at least two — a group of one is not a group.
    pub members: Vec<usize>,
}

/// Form the prefix-sharing groups for one decode step. Deterministic:
/// inputs are bucketed by their first physical KV block (ascending
/// block id), members stay in input order, and the shared prefix is
/// the longest common block chain across all members, clamped down to
/// whole blocks fully stored by every member (the tail block a member
/// may still be filling is never shared compute).
/// Groups need >= 2 members and >= 1 whole shared block; everything
/// else decodes on the per-sequence path unchanged.
pub fn form_decode_groups(kv: &KvCache, inputs: &[LaneInput]) -> Vec<DecodeGroup> {
    let bt = kv.geometry().block_tokens;
    let chains: Vec<Option<Vec<usize>>> =
        inputs.iter().map(|inp| kv.seq_blocks(inp.id)).collect();
    let mut by_first: std::collections::BTreeMap<usize, Vec<usize>> =
        std::collections::BTreeMap::new();
    for (i, chain) in chains.iter().enumerate() {
        if let Some(&first) = chain.as_ref().and_then(|c| c.first()) {
            by_first.entry(first).or_default().push(i);
        }
    }
    let mut groups: Vec<DecodeGroup> = Vec::new();
    for members in by_first.into_values() {
        if members.len() < 2 {
            continue;
        }
        let lead = chains[members[0]].as_ref().unwrap();
        let mut common = lead.len();
        for &m in &members[1..] {
            let mb = chains[m].as_ref().unwrap();
            let mut c = 0;
            while c < common && c < mb.len() && mb[c] == lead[c] {
                c += 1;
            }
            common = c;
        }
        let min_pos = members.iter().map(|&m| inputs[m].pos).min().unwrap();
        let common = common.min(min_pos / bt);
        if common == 0 {
            continue;
        }
        groups.push(DecodeGroup {
            id: groups.len(),
            prefix_blocks: lead[..common].to_vec(),
            prefix_tokens: common * bt,
            members,
        });
    }
    groups
}

/// The compute half of an engine. Implementations supply KV
/// materialization and logits; the [`EngineCore`] supplies everything
/// else (scheduling, flow control, lifecycle, accounting, tracing).
///
/// # Contract
///
/// - [`Backend::prefill`] must write the uncached prompt suffix
///   `[matched_tokens, prompt.len())` into the paged store and leave
///   the sequence's stored length at `prompt.len()`.
/// - [`Backend::decode`] must, for each input **in slice order**,
///   append the input token's KV (`grow_one` + store) and produce that
///   sequence's next-token logits. Lane order matters: the sim backend
///   derives logits from stored KV bytes, so reorderings are
///   observable.
/// - The batch-membership hooks ([`Backend::on_batch_join`],
///   [`Backend::on_batch_leave`], [`Backend::on_pause`],
///   [`Backend::on_resume`]) exist for backends with device-resident
///   state keyed on batch composition; stateless backends take the
///   no-op defaults.
/// - Backends never free sequences, never touch the prefix cache, and
///   never emit stream events — those invariants belong to the core.
pub trait Backend {
    /// Opaque value carried from [`Backend::prefill`] to
    /// [`Backend::on_batch_join`] for the same sequence.
    type PrefillArtifact;

    /// KV geometry the core's paged cache is built with.
    fn geometry(&self, cfg: &EngineConfig) -> KvGeometry;

    /// Model vocab size (also the tokenizer range).
    fn vocab(&self) -> usize;

    /// Validate a submission's prompt length against backend limits
    /// (prefill buckets for PJRT, `max_seq` for the sims).
    fn validate_prompt(&self, cfg: &EngineConfig, prompt_len: usize) -> Result<()>;

    /// Called at the top of every engine step. Simulation backends
    /// advance their manual clock one quantum here; real-time backends
    /// do nothing.
    fn on_step_start(&mut self, _clock: &Clock) {}

    /// Run prefill compute for `seq` (admission already holds its KV):
    /// write the uncached suffix into the paged store and return the
    /// logits row of the prompt's last position.
    fn prefill(
        &mut self,
        cfg: &EngineConfig,
        kv: &mut KvCache,
        seq: &Sequence,
        matched_tokens: usize,
        clock: &Clock,
    ) -> Result<PrefillRun<Self::PrefillArtifact>>;

    /// A freshly prefilled sequence joined the decode batch at
    /// `admission.lane`. Returns any extra accelerator time spent
    /// (device-side KV splice on the PJRT path).
    fn on_batch_join(
        &mut self,
        _kv: &mut KvCache,
        _metrics: &mut EngineMetrics,
        _id: SeqId,
        _admission: Admission,
        _artifact: Self::PrefillArtifact,
        _clock: &Clock,
    ) -> Result<Duration> {
        Ok(Duration::ZERO)
    }

    /// One decode step over the assembled batch: append each input
    /// token's KV and return one logits row per input, in input order.
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        cfg: &EngineConfig,
        kv: &mut KvCache,
        seqs: &HashMap<SeqId, Sequence>,
        batch: &DecodeBatch,
        inputs: &[LaneInput],
        metrics: &mut EngineMetrics,
        clock: &Clock,
    ) -> Result<DecodeRun>;

    /// One decode step over the assembled batch with prefix-sharing
    /// [`DecodeGroup`]s attached. Called instead of [`Backend::decode`]
    /// when [`crate::config::EngineConfig::grouped_decode`] is on.
    ///
    /// The contract is [`Backend::decode`]'s, with one extra freedom:
    /// within a group the backend may compute the shared prefix's
    /// attention once and merge per-member suffix partials (the
    /// unified-max softmax of [`crate::softmaxstats`] makes the merge
    /// order-free), **provided outputs stay byte-identical to the
    /// per-sequence path**. Groups are advisory — this default ignores
    /// them and delegates to [`Backend::decode`], so backends that do
    /// not opt in (the stub, the sharded wrapper, the PJRT engine)
    /// behave identically with the flag on or off. A backend that does
    /// reuse prefix compute records what it saved in
    /// [`crate::metrics::EngineMetrics::decode_attn_positions_saved`]
    /// and friends.
    #[allow(clippy::too_many_arguments)]
    fn decode_grouped(
        &mut self,
        cfg: &EngineConfig,
        kv: &mut KvCache,
        seqs: &HashMap<SeqId, Sequence>,
        batch: &DecodeBatch,
        inputs: &[LaneInput],
        _groups: &[DecodeGroup],
        metrics: &mut EngineMetrics,
        clock: &Clock,
    ) -> Result<DecodeRun> {
        self.decode(cfg, kv, seqs, batch, inputs, metrics, clock)
    }

    /// The decode step's output buffers are done being read; a backend
    /// may take them back for its next step (the sim backend reclaims
    /// its logits/offsets allocations here, closing the last per-round
    /// allocation on the decode hot path). Default: drop them.
    fn recycle_run(&mut self, _run: DecodeRun) {}

    /// A sequence left the decode batch (finished, preempted, dropped,
    /// or disconnected); `shrank` reports bucket compaction.
    fn on_batch_leave(&mut self, _kv: &mut KvCache, _id: SeqId, _shrank: bool) -> Result<()> {
        Ok(())
    }

    /// A running sequence is about to be parked by backpressure (the
    /// PJRT backend persists its device-resident KV first).
    fn on_pause(&mut self, _kv: &mut KvCache) -> Result<()> {
        Ok(())
    }

    /// A parked sequence rejoined the batch at `admission.lane`.
    fn on_resume(&mut self, _kv: &mut KvCache, _admission: &Admission) -> Result<()> {
        Ok(())
    }

    /// The retired sequence's tokens whose KV is valid in the paged
    /// store and may be published to the prefix cache (prompt only on
    /// the PJRT path — generated KV may still be device-resident;
    /// prompt + generated on the sim paths, which write synchronously).
    fn publishable_tokens(&self, kv: &KvCache, seq: &Sequence) -> Vec<u32>;
}

// ---------------------------------------------------------------------
// The core
// ---------------------------------------------------------------------

/// Persistent step-loop scratch owned by the core: every buffer the
/// hot path fills and drains each round lives here, cleared and
/// refilled instead of reallocated, so steady-state decode performs
/// zero heap allocations per token (the invariant
/// `tests/prop_steploop.rs` enforces with a counting allocator).
/// Capacities only ratchet up — to the largest bucket, plan, or pool
/// seen — and stay there for the engine's life.
#[derive(Debug, Default)]
struct StepScratch {
    /// Occupied-lane inputs for the current decode round.
    inputs: Vec<LaneInput>,
    /// Lanes that finished this round, retired after row processing.
    finished: Vec<(SeqId, FinishReason)>,
    /// Tokens emitted this round, traced after row processing.
    emitted: Vec<(SeqId, u32)>,
    /// Lane-ordered running ids for the stream planner.
    running_ids: Vec<SeqId>,
    /// The per-step flow-control plan.
    stream_ops: Vec<StreamOp>,
    /// Preemption victim pool (running + paused).
    pool: Vec<SeqId>,
    /// Preemption census over `pool`.
    candidates: Vec<PreemptCandidate>,
    /// The assembled decode batch.
    batch: DecodeBatch,
    /// Prefix-sharing groups, reused across chunk rounds while the
    /// lane set is unchanged (grouped decode only; reforming allocates,
    /// so the grouped path is outside the zero-alloc claim).
    groups: Vec<DecodeGroup>,
}

/// The serving engine, generic over its compute [`Backend`]. Owns all
/// sequence state; not `Send` for PJRT backends — run it on a dedicated
/// thread and talk to it via [`crate::server::EngineJob`] channels.
///
/// `Engine = EngineCore<PjrtBackend>` and
/// `SimEngine = EngineCore<SimBackend>` are the two production aliases;
/// [`StubEngine`] is the differential-testing third.
pub struct EngineCore<B: Backend> {
    pub cfg: EngineConfig,
    pub(crate) backend: B,
    kv: KvCache,
    prefix: PrefixCache,
    batcher: Batcher,
    router: Router,
    sampler: Sampler,
    seqs: HashMap<SeqId, Sequence>,
    /// Sequences parked by stream backpressure: they stay in `seqs`
    /// (state `Paused`) and keep their KV, but hold no decode lane.
    paused: Vec<SeqId>,
    /// Engine time source: system clock in production, manual virtual
    /// clock on the sim paths. Everything on the request path reads
    /// time through it, never `Instant::now()`.
    clock: Clock,
    /// Engine-loop wakeup each new stream notifies on client drains.
    wakeup: Option<Wakeup>,
    /// Scheduling-event trace (None until [`EngineCore::enable_trace`]).
    trace: Option<Vec<TraceEvent>>,
    /// In-flight prefix table (cross-request dedup): [`prompt_key`]
    /// hash of the full prompt → the admitted, still-decoding sequence
    /// computing its KV. A second admission of an identical uncached
    /// prompt waits for the holder's retirement and shares its blocks
    /// instead of racing it. Hash-keyed so admission never clones the
    /// prompt; lookups verify the holder's real prompt, so a collision
    /// is a missed dedup, never a wrong wait.
    inflight_prompts: HashMap<u64, SeqId>,
    /// Per-tenant in-flight request counts (queued + running + paused),
    /// enforced against [`EngineConfig::tenant_max_inflight`] at
    /// submit.
    tenant_inflight: HashMap<String, usize>,
    /// Request-lifecycle spans (always on; see [`crate::obs`]). A
    /// write-only side structure: it never feeds back into scheduling,
    /// so simulation trace fingerprints are identical with or without
    /// it.
    spans: SpanTable,
    /// Always-on bounded ring of recent scheduling events (the black
    /// box behind `{"admin": {"dump_flight": n}}`), unlike the opt-in
    /// unbounded `trace`.
    flight: FlightRecorder,
    /// Reused step-loop buffers (see [`StepScratch`]).
    scratch: StepScratch,
    pub metrics: EngineMetrics,
    pub tokenizer: ByteTokenizer,
}

impl<B: Backend> EngineCore<B> {
    /// Build a core around a backend, on the given clock.
    pub fn with_backend(backend: B, cfg: EngineConfig, clock: Clock) -> Result<Self> {
        cfg.validate()?;
        let geo = backend.geometry(&cfg);
        let tokenizer = ByteTokenizer::new(backend.vocab());
        Ok(EngineCore {
            kv: KvCache::new(geo, cfg.kv_total_blocks),
            prefix: PrefixCache::new(cfg.kv_block_tokens),
            batcher: Batcher::new(cfg.decode_buckets.clone()),
            router: Router::new(),
            sampler: Sampler::new(cfg.seed),
            seqs: HashMap::new(),
            paused: Vec::new(),
            clock,
            wakeup: None,
            trace: None,
            inflight_prompts: HashMap::new(),
            tenant_inflight: HashMap::new(),
            spans: SpanTable::new(cfg.flight_recorder_capacity),
            flight: FlightRecorder::new(cfg.flight_recorder_capacity),
            scratch: StepScratch::default(),
            metrics: EngineMetrics::default(),
            tokenizer,
            backend,
            cfg,
        })
    }

    pub fn geometry(&self) -> KvGeometry {
        self.kv.geometry()
    }

    /// A handle onto the engine's clock (virtual on the sim paths).
    pub fn clock(&self) -> Clock {
        self.clock.clone()
    }

    /// The compute backend (read-only; lifecycle stays with the core).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// The paged KV store (read-only; invariant checkers — e.g.
    /// [`crate::shard::ShardedBackend::verify_sharding`] — read dense
    /// state back through it without perturbing the engine).
    pub fn kv(&self) -> &KvCache {
        &self.kv
    }

    /// Re-base this core's request-id counter so ids stay globally
    /// unique across a fleet of replicas (replica `k` gets base
    /// `k << 48`). Must be called before the first submission.
    pub fn set_seq_id_base(&mut self, base: RequestId) {
        self.router.set_id_base(base);
    }

    /// Start recording [`TraceEvent`]s (drained with
    /// [`EngineCore::take_trace`]). Available on every backend,
    /// including the production PJRT engine.
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Drain the recorded trace (empty when tracing is disabled).
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        self.trace.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// True between [`EngineCore::enable_trace`] and any future
    /// disable; surfaced in the stats snapshot.
    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some()
    }

    fn push_trace(&mut self, ev: TraceEvent) {
        // Every traceable event also lands in the bounded flight ring,
        // whether or not the unbounded opt-in trace is armed. Rendering
        // goes through the ring's string-recycling path, so a full ring
        // records without allocating.
        self.flight
            .record_with(self.clock.now(), |buf| flight_write(buf, &ev));
        if let Some(t) = self.trace.as_mut() {
            t.push(ev);
        }
    }

    /// The request-lifecycle span store (live + recently finished).
    pub fn spans(&self) -> &SpanTable {
        &self.spans
    }

    /// The always-on flight recorder.
    pub fn flight(&self) -> &FlightRecorder {
        &self.flight
    }

    /// The newest `n` flight-recorder entries as text, for violation
    /// reports and logs.
    pub fn flight_text(&self, n: usize) -> String {
        self.flight.render(n)
    }

    /// Accounting snapshot for the simulation-test oracles and the
    /// stats path.
    pub fn audit(&self) -> EngineAudit {
        let mut live: Vec<LiveSeq> = self
            .seqs
            .values()
            .map(|s| LiveSeq {
                id: s.id,
                priority: s.priority,
                paused: s.state == SeqState::Paused,
            })
            .collect();
        live.sort_by_key(|l| l.id);
        EngineAudit {
            kv: self.kv.audit(),
            tree_blocks: self.prefix.tree_block_refs(),
            live,
            queued: self.router.queued(),
        }
    }

    /// Test-only fault hook: double-free the first KV block of the
    /// oldest live sequence, exactly the class of bug the refcount
    /// oracle exists to catch. Returns `false` when nothing is live.
    #[cfg(test)]
    pub fn inject_double_free(&mut self) -> bool {
        let Some(id) = self.audit().live.first().map(|l| l.id) else {
            return false;
        };
        let Some(blocks) = self.kv.seq_blocks(id) else {
            return false;
        };
        let Some(&b) = blocks.first() else {
            return false;
        };
        self.kv.debug_force_decref(b);
        true
    }

    pub fn kv_free_blocks(&self) -> usize {
        self.kv.free_blocks()
    }

    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.cached_blocks()
    }

    // -----------------------------------------------------------------
    // Prefill
    // -----------------------------------------------------------------

    fn step_prefill(&mut self) -> Result<()> {
        let t0 = self.clock.now();
        let mut seq = match self.router.pop_next() {
            Some(s) => s,
            None => return Ok(()),
        };
        let len = seq.prompt.len();

        // Cross-request dedup: if an identical prompt is mid-flight on
        // a live, still-decoding sequence and the cache cannot yet
        // serve this prompt's reusable prefix, wait for the holder's
        // retirement (which registers its blocks) instead of racing it
        // with duplicate cold prefill compute. A parked holder is not
        // waited on — it may never retire, and racing beats starving.
        // The waiter yields its queue slot (back, not front): it is
        // deferring voluntarily, so same-priority requests with other
        // prompts must keep admitting ahead of it.
        if self.cfg.prefix_cache {
            // The table is hash-keyed: confirm the holder really
            // carries this prompt before deferring behind it (a
            // collision must be a missed dedup, never a wrong wait).
            let holder = self
                .inflight_prompts
                .get(&prompt_key(&seq.prompt))
                .copied()
                .filter(|h| {
                    self.seqs
                        .get(h)
                        .map(|s| s.prompt == seq.prompt)
                        .unwrap_or(false)
                });
            if let Some(holder) = holder {
                let holder_running = self
                    .seqs
                    .get(&holder)
                    .map(|s| s.state == SeqState::Decoding)
                    .unwrap_or(false);
                let bt = self.cfg.kv_block_tokens;
                let best = policy::usable_prefix(bt, len, len);
                let have =
                    policy::usable_prefix(bt, len, self.prefix.peek_match_tokens(&seq.prompt));
                if holder_running && have < best {
                    if !seq.dedup_waited {
                        seq.dedup_waited = true;
                        self.metrics.dedup_hits += 1;
                    }
                    self.router.enqueue(seq);
                    return self.step_decode().map(|_| ());
                }
            }
        }

        // Prefix lookup + KV admission (shared policy; see
        // `policy::admit_kv`). Paused sequences count as pending work:
        // their blocks return when they resume or finish, so admission
        // must wait for them rather than fail the request.
        let matched = match policy::admit_kv(
            &self.cfg,
            &mut self.kv,
            &mut self.prefix,
            &mut self.metrics,
            self.batcher.is_empty() && self.paused.is_empty(),
            seq.id,
            &seq.prompt,
        ) {
            Ok(Some(m)) => m,
            Ok(None) => {
                // Admission must wait for KV. If nothing is decoding,
                // the holders are parked on backpressure and decode
                // will never free blocks — preempt a strictly
                // lower-priority parked victim so a high-priority
                // waiter is not starved by a stalled client.
                if self.batcher.is_empty() {
                    if let Some(victim) = policy::admission_relief_victim(
                        &self.kv,
                        &self.seqs,
                        &self.paused,
                        seq.priority,
                    ) {
                        self.paused.retain(|&p| p != victim);
                        let mut vseq = self.seqs.remove(&victim).unwrap();
                        self.metrics.preemptions += 1;
                        self.push_trace(TraceEvent::AdmissionRelief {
                            id: vseq.id,
                            priority: vseq.priority,
                            waiter_priority: seq.priority,
                        });
                        self.finish_seq(&mut vseq, FinishReason::Preempted)?;
                    }
                }
                self.router.requeue_front(seq);
                return self.step_decode().map(|_| ());
            }
            Err(_) => {
                // Truly stuck: nothing is running and eviction is
                // exhausted, so this request can never be admitted.
                // Fail it (surfaced on its stream) instead of wedging
                // the queue head forever.
                self.finish_seq(&mut seq, FinishReason::Error)?;
                return Ok(());
            }
        };
        let cached = matched.tokens;
        policy::note_admission(&self.cfg, &mut self.metrics, &mut seq, cached);
        self.push_trace(TraceEvent::Admitted { id: seq.id, cached });
        let t_admit = self.clock.now();
        self.metrics.attr_admission.record(t_admit.saturating_sub(t0));
        self.spans.admitted(seq.id, t_admit);

        // Backend compute: write the uncached suffix's KV and return
        // the logits row of the prompt's last real position. The
        // sequence already holds admitted KV, so a backend failure must
        // go through the one finish path — releasing its blocks, quota
        // slot, and the client's terminal event — before the error
        // surfaces to the step loop.
        let run = match self.backend.prefill(&self.cfg, &mut self.kv, &seq, cached, &self.clock)
        {
            Ok(run) => run,
            Err(e) => {
                self.finish_seq(&mut seq, FinishReason::Error)?;
                return Err(e);
            }
        };
        let mut exec_dt = run.exec_time;
        seq.kv_len = len;

        // First generated token. A fresh stream always has credit
        // (capacity >= 1); a client that already hung up is reaped by
        // the next step's stream scan.
        let tok = self.sampler.sample(&run.last_logits, seq.params);
        seq.generated.push(tok);
        let now = self.clock.now();
        seq.first_token_at = Some(now);
        self.spans.first_token(seq.id, now);
        self.metrics.first_token.record(now.saturating_sub(seq.arrived));
        let _ = seq.emit_token(tok);
        self.push_trace(TraceEvent::Token { id: seq.id, token: tok });
        self.metrics.tokens_generated += 1;
        self.metrics.requests_admitted += 1;

        let done_eos = tok == EOS;
        let done_stop = seq.hit_stop();
        if done_eos || done_stop || seq.max_new_tokens <= 1 {
            let reason = if done_eos {
                FinishReason::Eos
            } else if done_stop {
                FinishReason::Stop
            } else {
                FinishReason::MaxTokens
            };
            self.finish_seq(&mut seq, reason)?;
        } else {
            seq.state = SeqState::Decoding;
            let admission = self.batcher.admit(seq.id)?;
            let join = self.backend.on_batch_join(
                &mut self.kv,
                &mut self.metrics,
                seq.id,
                admission,
                run.artifact,
                &self.clock,
            );
            exec_dt += match join {
                Ok(d) => d,
                Err(e) => {
                    // Same cleanup rule as a prefill failure: release
                    // the lane and the sequence's books, then surface.
                    self.batcher.remove(seq.id)?;
                    self.finish_seq(&mut seq, FinishReason::Error)?;
                    return Err(e);
                }
            };
            // The dedup table is only ever read under prefix_cache, so
            // don't pay the hash without it.
            if self.cfg.prefix_cache {
                self.inflight_prompts.insert(prompt_key(&seq.prompt), seq.id);
            }
            self.seqs.insert(seq.id, seq);
        }
        self.metrics.prefill_steps += 1;
        let dt = self.clock.now().saturating_sub(t0);
        self.metrics.step.record(dt);
        self.metrics.step_overhead.record(dt.saturating_sub(exec_dt));
        Ok(())
    }

    // -----------------------------------------------------------------
    // Decode
    // -----------------------------------------------------------------

    /// One decode step: up to `decode_chunk` rounds of the classic
    /// one-token-per-lane loop, fused behind a single pass of the
    /// per-step policy work (stream scan, admission planning,
    /// scheduling) — the Kernel-Looping move applied to orchestration.
    /// Rounds after the first run only while chunking is provably
    /// invisible ([`EngineCore::chunk_can_continue`]); KV headroom and
    /// preemption still run every round, and stream credit still gates
    /// every token, so the lossless-stream and conservation oracles
    /// hold unchanged at any chunk size. Returns the number of tokens
    /// emitted — the weight [`EngineCore::step`] feeds the chunk-aware
    /// `attr_decode` attribution.
    fn step_decode(&mut self) -> Result<usize> {
        let t0 = self.clock.now();
        let mut total_rows = 0usize;
        let mut exec_dt = Duration::ZERO;
        // Decode-group formation is reused across rounds while the
        // lane set is unchanged; finishes and preemptions mark it
        // dirty.
        let mut lanes_dirty = true;
        for round in 0..self.cfg.decode_chunk.max(1) {
            // The stream scan (or an earlier round) may have drained
            // every running sequence; there is nothing to decode then.
            if self.batcher.is_empty() {
                break;
            }
            if round > 0 && !self.chunk_can_continue() {
                break;
            }
            // KV headroom via the shared policy, every round: reclaim
            // cached blocks first, preempt last. The victim pool spans
            // running *and* backpressure-paused sequences (parked work
            // holds KV too).
            while policy::reclaim_decode_headroom(
                &mut self.kv,
                &mut self.prefix,
                &mut self.metrics,
                self.batcher.len(),
                self.batcher.len() + self.paused.len(),
            ) {
                self.preempt_one()?;
                lanes_dirty = true;
            }
            if self.batcher.is_empty() {
                break; // preemption may have taken the last runner
            }
            self.batcher.assemble_into(&mut self.scratch.batch)?;
            let max_seq = self.kv.geometry().max_seq;
            self.scratch.inputs.clear();
            for (lane, slot) in self.scratch.batch.lanes.iter().enumerate() {
                let Some(id) = slot else { continue };
                let s = &self.seqs[id];
                self.scratch.inputs.push(LaneInput {
                    lane,
                    id: *id,
                    token: s.last_token(),
                    pos: s.kv_len,
                });
            }
            // Logical attention span of this round (every row attends
            // over its full stored prefix + the new token), recorded
            // for every backend so grouped runs can report their
            // measured savings as a fraction of the same denominator an
            // ungrouped run has.
            self.metrics.decode_attn_positions_total += self
                .scratch
                .inputs
                .iter()
                .map(|inp| (inp.pos + 1) as u64)
                .sum::<u64>();
            let run = if self.cfg.grouped_decode {
                if lanes_dirty {
                    // Group membership depends only on lane composition
                    // and whole *stored* blocks; with the lane set
                    // stable, a previous round's (possibly shorter)
                    // prefix is still a valid advisory group — stored
                    // coverage only grows and full shared blocks are
                    // never copy-on-written — so reforming every round
                    // buys nothing.
                    self.scratch.groups = form_decode_groups(&self.kv, &self.scratch.inputs);
                }
                if !self.scratch.groups.is_empty() {
                    self.metrics.grouped_decode_steps += 1;
                    self.metrics.grouped_groups_formed += self.scratch.groups.len() as u64;
                    self.metrics.grouped_rows += self
                        .scratch
                        .groups
                        .iter()
                        .map(|g| g.members.len() as u64)
                        .sum::<u64>();
                }
                self.backend.decode_grouped(
                    &self.cfg,
                    &mut self.kv,
                    &self.seqs,
                    &self.scratch.batch,
                    &self.scratch.inputs,
                    &self.scratch.groups,
                    &mut self.metrics,
                    &self.clock,
                )?
            } else {
                self.backend.decode(
                    &self.cfg,
                    &mut self.kv,
                    &self.seqs,
                    &self.scratch.batch,
                    &self.scratch.inputs,
                    &mut self.metrics,
                    &self.clock,
                )?
            };
            if run.offsets.len() != self.scratch.inputs.len() {
                return Err(Error::Schedule(format!(
                    "backend returned {} logits rows for {} lanes",
                    run.offsets.len(),
                    self.scratch.inputs.len()
                )));
            }
            self.scratch.finished.clear();
            self.scratch.emitted.clear();
            for i in 0..self.scratch.inputs.len() {
                let inp = self.scratch.inputs[i];
                let logits = run.row(i);
                let seq = self.seqs.get_mut(&inp.id).unwrap();
                seq.kv_len += 1;
                let new_tok = self.sampler.sample(logits, seq.params);
                seq.generated.push(new_tok);
                // Cannot be Full: the pre-round credit check guaranteed
                // at least one slot and this is the round's only token
                // for this lane. A mid-step disconnect is reaped by the
                // next stream scan.
                let _ = seq.emit_token(new_tok);
                self.scratch.emitted.push((inp.id, new_tok));
                self.metrics.tokens_generated += 1;
                self.metrics.decode_rows += 1;
                let done_eos = new_tok == EOS;
                let done_stop = seq.hit_stop();
                let done_len =
                    seq.generated.len() >= seq.max_new_tokens || seq.kv_len + 1 >= max_seq;
                if done_eos || done_stop || done_len {
                    let reason = if done_eos {
                        FinishReason::Eos
                    } else if done_stop {
                        FinishReason::Stop
                    } else {
                        FinishReason::MaxTokens
                    };
                    self.scratch.finished.push((inp.id, reason));
                }
            }
            total_rows += self.scratch.inputs.len();
            exec_dt += run.exec_time;
            // Rows are consumed; hand the run's buffers back for reuse.
            self.backend.recycle_run(run);
            for i in 0..self.scratch.emitted.len() {
                let (id, token) = self.scratch.emitted[i];
                self.push_trace(TraceEvent::Token { id, token });
            }
            lanes_dirty = !self.scratch.finished.is_empty();
            for i in 0..self.scratch.finished.len() {
                let (id, reason) = self.scratch.finished[i];
                let mut seq = self.seqs.remove(&id).unwrap();
                self.remove_from_batch(id)?;
                self.finish_seq(&mut seq, reason)?;
            }
            self.metrics.decode_steps += 1;
        }
        if total_rows > 0 {
            let dt = self.clock.now().saturating_sub(t0);
            self.metrics.step.record(dt);
            self.metrics.step_overhead.record(dt.saturating_sub(exec_dt));
            self.metrics.per_token.record(dt / total_rows as u32);
        }
        Ok(total_rows)
    }

    /// Whether a later chunk round may run without being observable:
    /// nothing queued that the skipped admission pass could admit,
    /// nothing parked that the skipped stream scan could resume, reap,
    /// or expire, and every running stream still holding credit (so
    /// that scan would plan zero transitions). In exactly this state
    /// the between-token policy passes of an unchunked run are provable
    /// no-ops, so skipping them is invisible; any other state ends the
    /// chunk early and returns control to the full per-step path — the
    /// run then behaves like one with a smaller chunk.
    fn chunk_can_continue(&self) -> bool {
        self.router.queued() == 0
            && self.paused.is_empty()
            && self
                .batcher
                .iter_running()
                .all(|id| policy::stream_verdict(&self.seqs[&id]) == StreamVerdict::Flowing)
    }

    /// Remove a sequence from the decode batch, keeping any
    /// backend-side batch state consistent.
    fn remove_from_batch(&mut self, id: SeqId) -> Result<()> {
        let shrank = self.batcher.remove(id)?;
        self.backend.on_batch_leave(&mut self.kv, id, shrank)
    }

    /// Preempt one victim under KV pressure: the shared census spans
    /// running *and* paused sequences (a parked slow client's KV is
    /// reclaimable like any other), ordered by the scheduler's
    /// (priority asc, parked first, reusable desc, recency) rule.
    fn preempt_one(&mut self) -> Result<()> {
        self.batcher.running_ids_into(&mut self.scratch.pool);
        self.scratch.pool.extend(self.paused.iter().copied());
        policy::preempt_candidates_into(
            &self.kv,
            &self.seqs,
            &self.scratch.pool,
            &mut self.scratch.candidates,
        );
        let id = preemption_victim(&self.scratch.candidates)
            .ok_or_else(|| Error::Schedule("no preemption victim".into()))?;
        let mut seq = self.seqs.remove(&id).unwrap();
        self.metrics.preemptions += 1;
        // The flight line carries only the pool *size*, so it renders
        // through the ring's recycling path without materializing the
        // pool; the full `(id, priority)` copy exists for oracles to
        // audit the victim choice, and is built only when the unbounded
        // trace is armed to record it.
        let pool_len = self.scratch.candidates.len();
        let priority = seq.priority;
        self.flight.record_with(self.clock.now(), |buf| {
            let _ = write!(buf, "preempted id={id} prio={priority} pool={pool_len}");
        });
        if self.trace.is_some() {
            let pool = self
                .scratch
                .candidates
                .iter()
                .map(|c| (c.id, c.priority))
                .collect();
            if let Some(t) = self.trace.as_mut() {
                t.push(TraceEvent::Preempted { id, priority, pool });
            }
        }
        if self.paused.contains(&id) {
            // Paused sequences hold no lane and no backend batch slot.
            self.paused.retain(|&p| p != id);
        } else {
            self.remove_from_batch(id)?;
        }
        self.finish_seq(&mut seq, FinishReason::Preempted)
    }

    // -----------------------------------------------------------------
    // Stream flow control
    // -----------------------------------------------------------------

    /// Apply backpressure at the top of every step. The *decisions*
    /// (resume order, hysteresis, policy) are the shared
    /// [`policy::plan_stream_ops`]; this method supplies the mechanics
    /// for each transition, delegating backend-specific bookkeeping
    /// (dense KV persistence on the PJRT path) to the batch hooks.
    /// Running *before* the scheduling decision keeps the scheduler's
    /// view of the running set accurate, and checking credit before
    /// decode means a generated token always has a slot — backpressure
    /// halts generation, it never loses data.
    fn service_streams(&mut self) -> Result<()> {
        let free_lanes = self.cfg.max_running.saturating_sub(self.batcher.len());
        let now = self.clock.now();
        self.batcher.running_ids_into(&mut self.scratch.running_ids);
        policy::plan_stream_ops_into(
            &self.seqs,
            &self.paused,
            &self.scratch.running_ids,
            self.cfg.backpressure,
            free_lanes,
            now,
            self.cfg.stream_idle_timeout(),
            &mut self.scratch.stream_ops,
        );
        // Drain the plan by index: ops are Copy and no transition below
        // re-enters the planner, so the buffer is stable across the
        // loop.
        for i in 0..self.scratch.stream_ops.len() {
            match self.scratch.stream_ops[i] {
                StreamOp::Resume(id) => {
                    let admission = self.batcher.admit(id)?;
                    self.backend.on_resume(&mut self.kv, &admission)?;
                    self.paused.retain(|&p| p != id);
                    let seq = self.seqs.get_mut(&id).unwrap();
                    seq.state = SeqState::Decoding;
                    seq.paused_at = None;
                    self.metrics.backpressure_resumes += 1;
                    self.push_trace(TraceEvent::Resumed { id });
                    self.spans.resumed(id, now);
                }
                StreamOp::ReapPaused(id) => {
                    self.paused.retain(|&p| p != id);
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.metrics.client_disconnects += 1;
                    self.finish_seq(&mut seq, FinishReason::Cancelled)?;
                }
                StreamOp::ReapRunning(id) => {
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.remove_from_batch(id)?;
                    self.metrics.client_disconnects += 1;
                    self.finish_seq(&mut seq, FinishReason::Cancelled)?;
                }
                StreamOp::Pause(id) => {
                    // Backend first: the PJRT path persists the parked
                    // sequence's device-resident KV before the lane is
                    // released.
                    self.backend.on_pause(&mut self.kv)?;
                    self.batcher.remove(id)?;
                    let seq = self.seqs.get_mut(&id).unwrap();
                    seq.state = SeqState::Paused;
                    seq.paused_at = Some(now);
                    self.paused.push(id);
                    self.metrics.backpressure_pauses += 1;
                    self.push_trace(TraceEvent::Paused { id });
                    self.spans.paused(id, now);
                }
                StreamOp::DropOverrun(id) => {
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.remove_from_batch(id)?;
                    self.metrics.backpressure_drops += 1;
                    self.finish_seq(&mut seq, FinishReason::Overrun)?;
                }
                StreamOp::ExpireIdle(id) => {
                    // A long-parked client: demote to overrun so its KV
                    // is bounded even with no allocation pressure.
                    // Paused sequences hold no lane and no batch slot.
                    self.paused.retain(|&p| p != id);
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.metrics.stream_idle_drops += 1;
                    self.push_trace(TraceEvent::Expired { id });
                    self.finish_seq(&mut seq, FinishReason::Overrun)?;
                }
            }
        }
        Ok(())
    }

    /// Register the retired sequence's publishable KV in the prefix
    /// cache. Which tokens are publishable is the backend's call: the
    /// sims write synchronously, so prompt *and* generated tokens
    /// publish; the PJRT path publishes the prompt only (generated KV
    /// may still be device-resident).
    fn register_prefix(&mut self, seq: &Sequence) {
        if !self.cfg.prefix_cache || !self.kv.contains(seq.id) {
            return;
        }
        let Some(blocks) = self.kv.seq_blocks(seq.id) else {
            return;
        };
        let toks = self.backend.publishable_tokens(&self.kv, seq);
        if toks.is_empty() {
            return;
        }
        self.prefix.insert(&toks, &blocks, &mut self.kv);
    }

    fn finish_seq(&mut self, seq: &mut Sequence, reason: FinishReason) -> Result<()> {
        seq.state = SeqState::Finished(reason);
        // Close the span before the terminal event goes out, so by the
        // time a client sees `Finished` the breakdown is readable on
        // its stream.
        if let Some(b) = self.spans.finished(seq.id, self.clock.now(), reason) {
            let m = &mut self.metrics;
            m.span_queue_wait.record(Duration::from_micros(b.queue_wait_us));
            m.span_prefill.record(Duration::from_micros(b.prefill_us));
            m.span_decode.record(Duration::from_micros(b.decode_us));
            m.span_paused.record(Duration::from_micros(b.paused_us));
            seq.stream.set_breakdown(b);
        }
        let usage = seq.usage();
        seq.emit_finish(reason, usage);
        self.push_trace(TraceEvent::Finished {
            id: seq.id,
            reason,
            usage,
        });
        self.metrics.record_finish(&seq.tenant, usage);
        self.register_prefix(seq);
        if self.kv.contains(seq.id) {
            self.kv.free_seq(seq.id)?;
        }
        // Holder-id match suffices for removal: a key mapping to this
        // sequence's id can only have been inserted by this sequence.
        let key = prompt_key(&seq.prompt);
        if self.inflight_prompts.get(&key) == Some(&seq.id) {
            self.inflight_prompts.remove(&key);
        }
        let tenant_drained = match self.tenant_inflight.get_mut(&seq.tenant) {
            Some(n) => {
                *n = n.saturating_sub(1);
                *n == 0
            }
            None => false,
        };
        if tenant_drained {
            self.tenant_inflight.remove(&seq.tenant);
        }
        self.metrics.requests_finished += 1;
        Ok(())
    }
}

impl<B: Backend> InferenceEngine for EngineCore<B> {
    /// Queue a typed request; the prompt must fit the backend's limits
    /// and the KV pool, and the tenant must be under its concurrency
    /// quota (when one is configured).
    fn submit(&mut self, req: GenRequest) -> Result<SubmissionHandle> {
        let prompt_tokens = router::encode_prompt(&self.tokenizer, &req.prompt)?;
        self.backend.validate_prompt(&self.cfg, prompt_tokens.len())?;
        let need = (prompt_tokens.len() + 1).div_ceil(self.cfg.kv_block_tokens);
        if need > self.cfg.kv_total_blocks {
            return Err(Error::Request(format!(
                "prompt needs {need} KV blocks, pool has {}",
                self.cfg.kv_total_blocks
            )));
        }
        let tenant = if req.tenant.is_empty() {
            "default"
        } else {
            req.tenant.as_str()
        };
        if self.cfg.tenant_max_inflight > 0 {
            let inflight = self.tenant_inflight.get(tenant).copied().unwrap_or(0);
            if inflight >= self.cfg.tenant_max_inflight {
                self.metrics.quota_rejections += 1;
                return Err(Error::Quota(format!(
                    "tenant {tenant:?} already has {inflight} requests in flight \
                     (limit {})",
                    self.cfg.tenant_max_inflight
                )));
            }
        }
        let tenant = tenant.to_string();
        let handle = router::enqueue_request(
            &mut self.router,
            &self.tokenizer,
            &req,
            prompt_tokens,
            &SubmitContext {
                max_new_cap: self.cfg.max_new_tokens,
                stream_capacity: self.cfg.stream_capacity,
                now: self.clock.now(),
                wakeup: self.wakeup.as_ref(),
            },
        )?;
        *self.tenant_inflight.entry(tenant).or_default() += 1;
        let now = self.clock.now();
        self.spans.submitted(handle.id, now);
        let id = handle.id;
        self.flight.record_with(now, |buf| {
            let _ = write!(buf, "submitted id={id}");
        });
        Ok(handle)
    }

    fn set_wakeup(&mut self, wakeup: Wakeup) {
        self.wakeup = Some(wakeup);
    }

    /// Run one scheduling iteration: let the backend observe the step
    /// start (sims advance virtual time), service stream flow control,
    /// then prefill/decode/idle. Returns the action taken.
    fn step(&mut self) -> Result<Action> {
        self.backend.on_step_start(&self.clock);
        // Step-time attribution: bucket this step's wall time into
        // stream-service / policy / prefill / decode histograms (the
        // admission slice inside a prefill step has its own bucket).
        // Under a manual clock, time only moves in `on_step_start`, so
        // every bucket records a deterministic zero — reading the clock
        // here cannot perturb a simulation.
        let t0 = self.clock.now();
        self.service_streams()?;
        let t1 = self.clock.now();
        self.metrics.attr_stream_service.record(t1.saturating_sub(t0));
        let state = policy::plan_admission(
            &self.cfg,
            &mut self.kv,
            &mut self.prefix,
            &mut self.metrics,
            self.router.peek_next(),
            self.router.queued(),
            self.batcher.len(),
        );
        let action = decide(state);
        let t2 = self.clock.now();
        self.metrics.attr_policy.record(t2.saturating_sub(t1));
        match action {
            Action::Prefill => {
                self.step_prefill()?;
                self.metrics
                    .attr_prefill
                    .record(self.clock.now().saturating_sub(t2));
            }
            Action::Decode => {
                // Weight the decode slice by tokens emitted, so the
                // span partition and per-token attribution stay exact
                // when one step carries a whole chunk.
                let tokens = self.step_decode()?;
                self.metrics.attr_decode.record_weighted(
                    self.clock.now().saturating_sub(t2),
                    tokens.max(1) as u64,
                );
            }
            Action::Idle => {}
        }
        Ok(action)
    }

    /// Cancel a queued, running, or paused request; its KV blocks are
    /// released (publishable tokens may survive in the prefix cache,
    /// held by the tree alone).
    fn cancel(&mut self, id: RequestId) -> Result<bool> {
        if let Some(mut seq) = self.router.take(id) {
            self.metrics.cancellations += 1;
            self.finish_seq(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        if self.paused.contains(&id) {
            self.paused.retain(|&p| p != id);
            let mut seq = self.seqs.remove(&id).unwrap();
            self.metrics.cancellations += 1;
            // Paused sequences hold no lane and no backend batch slot:
            // finish directly, no batch bookkeeping.
            self.finish_seq(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        if let Some(mut seq) = self.seqs.remove(&id) {
            self.metrics.cancellations += 1;
            self.remove_from_batch(id)?;
            self.finish_seq(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        Ok(false)
    }

    fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// True when no work remains.
    fn is_idle(&self) -> bool {
        self.router.queued() == 0 && self.batcher.is_empty() && self.paused.is_empty()
    }

    fn queued(&self) -> usize {
        self.router.queued()
    }

    fn running(&self) -> usize {
        self.batcher.len()
    }

    fn paused(&self) -> usize {
        self.paused.len()
    }

    fn queue_depths(&self) -> Vec<(i32, usize)> {
        self.router.depths_by_priority()
    }

    /// The `{"stats": true}` snapshot: cumulative metrics, gauges, and
    /// — on every backend, real engine included — the audit verdict the
    /// simulation oracles check (`kv_refcount_ok`, `blocks_leaked`) and
    /// whether tracing is armed, so production debugging sees what
    /// simtest sees.
    fn stats_json(&self) -> Json {
        let mut j = self.metrics.to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("queued".to_string(), Json::Num(self.queued() as f64));
            map.insert("running".to_string(), Json::Num(self.running() as f64));
            map.insert("paused".to_string(), Json::Num(self.paused() as f64));
            let depths = self
                .queue_depths()
                .into_iter()
                .map(|(p, n)| (p.to_string(), Json::Num(n as f64)))
                .collect();
            map.insert("queue_depths".to_string(), Json::Obj(depths));
            let summary = audit_block_accounting(&self.audit());
            map.insert(
                "kv_refcount_ok".to_string(),
                Json::Bool(summary.refcount_ok),
            );
            map.insert(
                "blocks_leaked".to_string(),
                Json::Num(summary.blocks_leaked as f64),
            );
            map.insert(
                "trace_enabled".to_string(),
                Json::Bool(self.trace_enabled()),
            );
            map.insert(
                "spans_active".to_string(),
                Json::Num(self.spans.active_len() as f64),
            );
            map.insert(
                "flight_recorder".to_string(),
                Json::obj(vec![
                    ("capacity", Json::Num(self.flight.capacity() as f64)),
                    ("len", Json::Num(self.flight.len() as f64)),
                    ("dropped", Json::Num(self.flight.dropped() as f64)),
                ]),
            );
        }
        j
    }

    /// The newest `n` flight-recorder entries (the engine's always-on
    /// black box), served to `{"admin": {"dump_flight": n}}`.
    fn dump_flight(&self, n: usize) -> Json {
        self.flight.to_json(n)
    }

    fn encode(&self, text: &str) -> Vec<u32> {
        self.tokenizer.encode(text)
    }

    fn decode(&self, tokens: &[u32]) -> String {
        self.tokenizer.decode(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conservation_check_and_summary_agree() {
        // Consistent audit: one allocated block, one free.
        let audit = EngineAudit {
            kv: KvAudit {
                total_blocks: 2,
                free_list: vec![1],
                refcounts: vec![1, 0],
                seq_blocks: vec![(1, vec![0])],
            },
            tree_blocks: vec![],
            live: vec![],
            queued: 0,
        };
        assert!(check_kv_conservation(&audit).is_ok());
        let s = audit_block_accounting(&audit);
        assert!(s.refcount_ok);
        assert_eq!(s.blocks_leaked, 0);

        // A leak: refcount without a visible owner.
        let audit = EngineAudit {
            kv: KvAudit {
                total_blocks: 2,
                free_list: vec![1],
                refcounts: vec![1, 0],
                seq_blocks: vec![],
            },
            tree_blocks: vec![],
            live: vec![],
            queued: 0,
        };
        assert!(check_kv_conservation(&audit).is_err());
        let s = audit_block_accounting(&audit);
        assert!(!s.refcount_ok);
        assert_eq!(s.blocks_leaked, 1);
    }
}
