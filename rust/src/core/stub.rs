//! A deterministic no-PJRT compute stub for differential testing of
//! the serving core.
//!
//! [`StubBackend`] serves the *same* hash model as
//! [`crate::simengine::SimBackend`] — identical K/V values, identical
//! logits — through deliberately different mechanics:
//!
//! - **Prefill** materializes the uncached prompt suffix token by token
//!   (`grow_one` + `write_token`) instead of the sim's bulk
//!   `write_prefill_range`, exercising the incremental allocation and
//!   copy-on-write path during admission.
//! - **Logits** are recomputed analytically from the sequence's token
//!   history instead of being digested from the paged store's bytes.
//!   The values agree exactly *iff* the paged store faithfully holds
//!   what was written, so a lockstep run against the sim backend is a
//!   real differential: any store corruption, mis-sized write, or
//!   read-path bug makes the two engines' token streams — and therefore
//!   their [`crate::core::TraceEvent`] fingerprints — diverge.
//!
//! `tests/differential_backends.rs` drives the same seeded scenarios
//! through `EngineCore<SimBackend>` and `EngineCore<StubBackend>` and
//! asserts byte-identical scenario reports.

use std::collections::HashMap;
use std::time::Duration;

use crate::batching::DecodeBatch;
use crate::config::EngineConfig;
use crate::core::{Backend, DecodeRun, EngineCore, LaneInput, PrefillRun};
use crate::error::{Error, Result};
use crate::kvcache::{KvCache, KvGeometry, SeqId};
use crate::metrics::EngineMetrics;
use crate::router::Sequence;
use crate::simengine::{
    hash_f32, mix, sim_publishable_tokens, sim_token_cols, LOGITS_DIGEST_SEED, SIM_STEP, SimSpec,
};
use crate::util::clock::Clock;

/// Logits from first principles: fold the hash-model K/V values for
/// `tokens[pos]` at each position — the exact bytes the sim backend
/// reads back out of the paged store — then mix in the current input
/// token. Bit-for-bit equal to the sim's cache digest when the store
/// is healthy.
fn logits_analytic(geo: &KvGeometry, vocab: usize, tokens: &[u32], cur_tok: u32) -> Vec<f32> {
    let mut digest: u64 = LOGITS_DIGEST_SEED;
    for (pos, &tok) in tokens.iter().enumerate() {
        let (kc, vc) = sim_token_cols(geo, tok, pos);
        for f in kc.iter().chain(vc.iter()) {
            digest = mix(digest ^ f.to_bits() as u64);
        }
    }
    digest = mix(digest ^ ((cur_tok as u64) << 32));
    (0..vocab).map(|c| hash_f32(digest ^ c as u64)).collect()
}

/// The stub compute backend (see module docs).
pub struct StubBackend {
    spec: SimSpec,
}

impl StubBackend {
    pub fn new(spec: SimSpec) -> Self {
        StubBackend { spec }
    }
}

impl Backend for StubBackend {
    type PrefillArtifact = ();

    fn geometry(&self, cfg: &EngineConfig) -> KvGeometry {
        KvGeometry {
            n_layers: self.spec.n_layers,
            n_heads: self.spec.n_heads,
            head_dim: self.spec.head_dim,
            block_tokens: cfg.kv_block_tokens,
            max_seq: self.spec.max_seq,
        }
    }

    fn vocab(&self) -> usize {
        self.spec.vocab
    }

    fn validate_prompt(&self, _cfg: &EngineConfig, prompt_len: usize) -> Result<()> {
        if prompt_len + 1 > self.spec.max_seq {
            return Err(Error::Request(format!(
                "prompt of {prompt_len} tokens exceeds stub max_seq {}",
                self.spec.max_seq
            )));
        }
        Ok(())
    }

    /// Same virtual-time quantum as the sim backend, so timeout and
    /// latency decisions line up step for step in lockstep runs.
    fn on_step_start(&mut self, clock: &Clock) {
        clock.advance(SIM_STEP);
    }

    /// Token-by-token materialization of the uncached suffix. The
    /// matched prefix is block-aligned and the fresh blocks were
    /// allocated at admission, so each `grow_one` lands in an owned
    /// block and the final stored length equals the prompt length —
    /// the same post-state the sim's bulk range write produces.
    fn prefill(
        &mut self,
        _cfg: &EngineConfig,
        kv: &mut KvCache,
        seq: &Sequence,
        matched_tokens: usize,
        _clock: &Clock,
    ) -> Result<PrefillRun<()>> {
        let geo = kv.geometry();
        for (t, &tok) in seq.prompt.iter().enumerate().skip(matched_tokens) {
            kv.grow_one(seq.id)?;
            let (kc, vc) = sim_token_cols(&geo, tok, t);
            kv.write_token(seq.id, t, &kc, &vc)?;
        }
        let last = *seq.prompt.last().unwrap();
        let logits = logits_analytic(&geo, self.spec.vocab, &seq.prompt, last);
        Ok(PrefillRun {
            last_logits: logits,
            exec_time: Duration::ZERO,
            artifact: (),
        })
    }

    /// Same KV mechanics as the sim (grow + write, preserving COW
    /// behavior and block accounting); only the logits source differs.
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        _cfg: &EngineConfig,
        kv: &mut KvCache,
        seqs: &HashMap<SeqId, Sequence>,
        _batch: &DecodeBatch,
        inputs: &[LaneInput],
        _metrics: &mut EngineMetrics,
        _clock: &Clock,
    ) -> Result<DecodeRun> {
        let geo = kv.geometry();
        let mut logits = Vec::with_capacity(inputs.len() * self.spec.vocab);
        let mut offsets = Vec::with_capacity(inputs.len());
        for inp in inputs {
            kv.grow_one(inp.id)?;
            let (kc, vc) = sim_token_cols(&geo, inp.token, inp.pos);
            kv.write_token(inp.id, inp.pos, &kc, &vc)?;
            let seq = seqs
                .get(&inp.id)
                .ok_or_else(|| Error::Schedule(format!("unknown decoding seq {}", inp.id)))?;
            let stored = kv
                .seq_len(inp.id)
                .ok_or_else(|| Error::KvCache(format!("unknown seq {}", inp.id)))?;
            let tokens: Vec<u32> = seq
                .prompt
                .iter()
                .chain(seq.generated.iter())
                .copied()
                .take(stored)
                .collect();
            offsets.push(logits.len());
            logits.extend(logits_analytic(&geo, self.spec.vocab, &tokens, inp.token));
        }
        Ok(DecodeRun {
            logits,
            offsets,
            row_len: self.spec.vocab,
            exec_time: Duration::ZERO,
        })
    }

    /// Identical publication rule to the sim backend (one shared
    /// definition): the prefix-cache contents must match for lockstep
    /// traces to stay equal.
    fn publishable_tokens(&self, kv: &KvCache, seq: &Sequence) -> Vec<u32> {
        sim_publishable_tokens(kv, seq)
    }
}

/// The differential-testing engine: the shared serving core over the
/// stub backend.
pub type StubEngine = EngineCore<StubBackend>;

impl EngineCore<StubBackend> {
    /// Build a stub engine on a fresh virtual clock.
    pub fn new(cfg: EngineConfig, spec: SimSpec) -> Result<Self> {
        EngineCore::with_backend(StubBackend::new(spec), cfg, Clock::manual())
    }
}
