//! Shared helpers for the figure-reproduction benches: fixed-width table
//! printing in the shape of the paper's tables/series, simple timing
//! utilities for the real-CPU measurement paths, and the
//! [`perf_trajectory_report`] harness behind `benches/perf_trajectory.rs`
//! and the CI `perf-trajectory` job.

use std::collections::HashMap;
use std::time::Instant;

use crate::api::{GenEvent, GenRequest, InferenceEngine};
use crate::config::{EngineConfig, FleetConfig, RoutePolicy};
use crate::core::EngineCore;
use crate::fleet::Fleet;
use crate::shard::ShardedBackend;
use crate::simengine::{SimBackend, SimEngine, SimSpec, SIM_STEP};
use crate::util::clock::Clock;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{shared_prefix_trace, tenant_prompts, SharedPrefixSpec};
use crate::{Error, Result};

/// Print a header band for one reproduced figure/table.
pub fn banner(id: &str, title: &str) {
    println!();
    println!("==================================================================");
    println!("{id}: {title}");
    println!("==================================================================");
}

/// Print a row of labeled values with a fixed-width first column.
pub fn row(label: &str, values: &[String]) {
    print!("{label:<28}");
    for v in values {
        print!("{v:>14}");
    }
    println!();
}

/// Format seconds adaptively (s / ms / us).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Median-of-N wall-clock timing of a closure (real-CPU benches).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Geometric mean (the paper's "average speedup" aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

// ---------------------------------------------------------------------
// Perf-trajectory harness (BENCH_serving.json)
// ---------------------------------------------------------------------

/// The pinned seed `benches/perf_trajectory.rs` and the CI
/// `perf-trajectory` job run. Changing it invalidates the perf
/// trajectory history, so don't.
pub const PERF_TRAJECTORY_SEED: u64 = 2311;

/// Deterministic nearest-rank percentile over a sorted sample
/// (microseconds). Zero on an empty sample.
fn pct(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run the pinned serving workload on the deterministic sim engine and
/// return the `BENCH_serving.json` report object.
///
/// The workload is a pure function of `seed`: 24 requests over two
/// shared prompt prefixes and three tenants, mixed priorities and
/// budgets, submitted up front against a decode pool of 8 lanes (so
/// queue wait is real), drained eagerly every step. All rates are in
/// *virtual* time (the sim clock advances [`SIM_STEP`] per engine
/// step), which is what makes the report byte-identical across runs —
/// the determinism CI asserts by diffing two consecutive runs.
///
/// Latency percentiles come from the engine's completed request spans
/// ([`crate::obs::RequestSpan`]): TTFT directly, inter-token as each
/// span's decode time over its emitted-token gaps. The `step_overhead`
/// object carries the step-time attribution sums; under the manual sim
/// clock intra-step deltas are structurally zero, so the *keys* are
/// the contract here — real-clock engines fill the same fields with
/// wall time (see `docs/OBSERVABILITY.md`).
pub fn perf_trajectory_report(seed: u64) -> Result<Json> {
    const REQUESTS: usize = 24;
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 256,
        max_new_tokens: 32,
        max_running: 8,
        prefix_cache: true,
        stream_capacity: 64,
        flight_recorder_capacity: 4096,
        seed,
        ..EngineConfig::default()
    };
    let mut engine = SimEngine::new(cfg, SimSpec::default())?;
    let mut rng = Rng::seed_from_u64(seed);
    let prefixes = [
        "sys: shared serving preamble for the perf trajectory. ",
        "ctx: common retrieval context for half the pool. ",
    ];
    let tenants = ["acme", "globex", "initech"];
    let mut handles = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS {
        let prompt = format!("{}request {i:02}", prefixes[i % prefixes.len()]);
        let req = GenRequest::text(&prompt)
            .tenant(tenants[i % tenants.len()])
            .priority(rng.gen_range(0, 5) as i32 - 2)
            .max_new_tokens(4 + rng.gen_range(0, 28));
        handles.push(engine.submit(req)?);
    }

    let mut token_counts = vec![0usize; handles.len()];
    let mut steps = 0u64;
    while !engine.is_idle() {
        if steps > 100_000 {
            return Err(Error::Request(
                "perf trajectory workload did not drain".into(),
            ));
        }
        engine.step()?;
        steps += 1;
        for (i, h) in handles.iter().enumerate() {
            while let Ok(ev) = h.events.try_recv() {
                if matches!(ev, GenEvent::Token(_)) {
                    token_counts[i] += 1;
                }
            }
        }
    }

    let by_id: HashMap<_, _> = handles.iter().enumerate().map(|(i, h)| (h.id, i)).collect();
    let mut ttfts = Vec::new();
    let mut inter = Vec::new();
    for s in engine.spans().completed() {
        if let Some(t) = s.ttft() {
            ttfts.push(t.as_micros() as u64);
        }
        let tokens = by_id.get(&s.id).map(|&i| token_counts[i]).unwrap_or(0);
        if tokens > 1 {
            inter.push(s.decode_time().as_micros() as u64 / (tokens as u64 - 1));
        }
    }
    ttfts.sort_unstable();
    inter.sort_unstable();

    let m = &engine.metrics;
    let virtual_s = steps as f64 * SIM_STEP.as_secs_f64();
    let tokens = m.tokens_generated as f64;
    let hit_rate = if m.prefix_lookups > 0 {
        m.prefix_hits as f64 / m.prefix_lookups as f64
    } else {
        0.0
    };
    Ok(Json::obj(vec![
        ("seed", Json::Num(seed as f64)),
        ("requests", Json::Num(handles.len() as f64)),
        ("steps", Json::Num(steps as f64)),
        ("virtual_ms", Json::Num(virtual_s * 1e3)),
        ("tokens_generated", Json::Num(tokens)),
        ("tokens_per_sec", Json::Num(tokens / virtual_s)),
        ("steps_per_sec", Json::Num(steps as f64 / virtual_s)),
        ("ttft_p50_us", Json::Num(pct(&ttfts, 50.0) as f64)),
        ("ttft_p99_us", Json::Num(pct(&ttfts, 99.0) as f64)),
        ("inter_token_p50_us", Json::Num(pct(&inter, 50.0) as f64)),
        ("inter_token_p99_us", Json::Num(pct(&inter, 99.0) as f64)),
        ("prefix_hit_rate", Json::Num(hit_rate)),
        (
            "step_overhead",
            Json::obj(vec![
                (
                    "stream_service_us",
                    Json::Num(m.attr_stream_service.sum_us() as f64),
                ),
                ("policy_us", Json::Num(m.attr_policy.sum_us() as f64)),
                ("admission_us", Json::Num(m.attr_admission.sum_us() as f64)),
                ("prefill_us", Json::Num(m.attr_prefill.sum_us() as f64)),
                ("decode_us", Json::Num(m.attr_decode.sum_us() as f64)),
            ]),
        ),
    ]))
}

// ---------------------------------------------------------------------
// Fleet-routing harness (BENCH_fleet.json)
// ---------------------------------------------------------------------

/// The pinned seed `benches/fleet_routing.rs` and the CI
/// `perf-trajectory` job run. Changing it invalidates the fleet
/// routing history, so don't.
pub const FLEET_ROUTING_SEED: u64 = 2324;

/// Replicas in the pinned fleet-routing comparison.
const FLEET_ROUTING_REPLICAS: usize = 4;

/// The Zipf shared-prefix workload every policy replays: 8 tenants,
/// a 128-char system prompt each, 96 requests, all arriving up front
/// so placement is the only degree of freedom.
fn fleet_routing_spec(seed: u64) -> SharedPrefixSpec {
    SharedPrefixSpec {
        seed,
        ..SharedPrefixSpec::default()
    }
}

/// Run the pinned shared-prefix workload through a fleet under one
/// routing policy and report its cache economics.
///
/// The KV budget is sized so one replica can hold only a few tenants'
/// system prompts: a policy that scatters a tenant across replicas
/// pays a cold prefill *per replica* and thrashes each replica's
/// prefix cache, while a cache-affine policy concentrates tenants and
/// pays roughly one cold prefill per tenant. `prefix_hit_rate` is the
/// engine-side truth (summed over replicas); `router.cache_hits` is
/// the router's own mirror-predicted hit count.
fn fleet_policy_run(seed: u64, policy: RoutePolicy) -> Result<Json> {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 64,
        max_new_tokens: 16,
        max_running: 4,
        prefix_cache: true,
        seed,
        ..EngineConfig::default()
    };
    let fcfg = FleetConfig {
        n_replicas: FLEET_ROUTING_REPLICAS,
        policy,
        cache_vs_balance: 0.8,
        ..FleetConfig::default()
    };
    let mut fleet = Fleet::sim(cfg, fcfg, SimSpec::default())?;
    let trace = shared_prefix_trace(&fleet_routing_spec(seed));
    let mut handles = Vec::with_capacity(trace.len());
    for r in trace {
        let req = GenRequest::text(r.prompt)
            .tenant(r.tenant.as_str())
            .max_new_tokens(r.max_new_tokens);
        handles.push(fleet.submit(req)?);
    }
    let mut steps = 0u64;
    while !fleet.is_idle() {
        if steps > 200_000 {
            return Err(Error::Request("fleet routing workload did not drain".into()));
        }
        fleet.step()?;
        steps += 1;
        for h in &handles {
            while h.events.try_recv().is_ok() {}
        }
    }

    let m = fleet.metrics();
    let hit_rate = if m.prefix_lookups > 0 {
        m.prefix_hits as f64 / m.prefix_lookups as f64
    } else {
        0.0
    };
    let (decisions, cache_hits) = fleet.routing_counts();
    let routed: Vec<Json> = (0..fleet.n_replicas())
        .map(|k| {
            let s = fleet.replica_stats(k).expect("replica exists");
            Json::Num(s.routed as f64)
        })
        .collect();
    Ok(Json::obj(vec![
        ("policy", Json::Str(policy.as_str().into())),
        ("steps", Json::Num(steps as f64)),
        ("requests_finished", Json::Num(m.requests_finished as f64)),
        ("tokens_generated", Json::Num(m.tokens_generated as f64)),
        ("prefix_lookups", Json::Num(m.prefix_lookups as f64)),
        ("prefix_hits", Json::Num(m.prefix_hits as f64)),
        ("prefix_hit_rate", Json::Num(hit_rate)),
        (
            "prefix_tokens_reused",
            Json::Num(m.prefix_tokens_reused as f64),
        ),
        (
            "prefill_tokens_computed",
            Json::Num(m.prefill_tokens_computed as f64),
        ),
        (
            "router",
            Json::obj(vec![
                ("decisions", Json::Num(decisions as f64)),
                ("cache_hits", Json::Num(cache_hits as f64)),
            ]),
        ),
        ("replica_routed", Json::Arr(routed)),
    ]))
}

/// Run the pinned Zipf shared-prefix workload under all three routing
/// policies on identical 4-replica sim fleets and return the
/// `BENCH_fleet.json` report object. Everything is a pure function of
/// `seed` (manual sim clock, seeded workload), so the report is
/// byte-identical across runs — the bench and CI assert it by diffing
/// two consecutive runs.
pub fn fleet_routing_report(seed: u64) -> Result<Json> {
    let spec = fleet_routing_spec(seed);
    Ok(Json::obj(vec![
        ("seed", Json::Num(seed as f64)),
        ("replicas", Json::Num(FLEET_ROUTING_REPLICAS as f64)),
        (
            "workload",
            Json::obj(vec![
                ("n_tenants", Json::Num(spec.n_tenants as f64)),
                ("zipf_s", Json::Num(spec.zipf_s)),
                (
                    "system_prompt_len",
                    Json::Num(spec.system_prompt_len as f64),
                ),
                ("n_requests", Json::Num(spec.n_requests as f64)),
            ]),
        ),
        ("round_robin", fleet_policy_run(seed, RoutePolicy::RoundRobin)?),
        ("least_loaded", fleet_policy_run(seed, RoutePolicy::LeastLoaded)?),
        ("cache_aware", fleet_policy_run(seed, RoutePolicy::CacheAware)?),
    ]))
}

// ---------------------------------------------------------------------
// Sharded-decode harness (BENCH_sharded.json)
// ---------------------------------------------------------------------

/// The pinned seed `benches/sharded_decode.rs` and the CI
/// `perf-trajectory` job run. Changing it invalidates the sharded
/// decode history, so don't.
pub const SHARDED_DECODE_SEED: u64 = 2397;

/// Shard counts the pinned sharded-decode grid sweeps.
const SHARDED_DECODE_SHARDS: [usize; 4] = [1, 2, 4, 8];

/// Batch sizes the pinned sharded-decode grid sweeps.
const SHARDED_DECODE_BATCHES: [usize; 3] = [1, 8, 32];

/// One cell of the sharded-decode grid: drain a seeded `batch`-request
/// workload on `EngineCore<ShardedBackend<SimBackend>>` with `shards`
/// lanes and report the shard accounting.
///
/// The workload is a pure function of `(seed, batch)` — deliberately
/// *independent of the shard count* — so every M in a column decodes
/// the exact same rows and the sweep compares like for like. Scheduling
/// is also shard-invariant (the differential matrix proves it), so the
/// only thing that moves across M is the modeled budget: per-lane
/// compute shrinks while collective time grows.
fn sharded_cell_run(seed: u64, shards: usize, batch: usize) -> Result<Json> {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 512,
        max_new_tokens: 24,
        max_running: batch,
        decode_buckets: vec![1, 2, 4, 8, 16, 32],
        prefix_cache: false,
        seed,
        ..EngineConfig::default()
    };
    let mut engine = EngineCore::with_backend(
        ShardedBackend::new(SimBackend::new(SimSpec::default()), shards),
        cfg,
        Clock::manual(),
    )?;
    let mut rng = Rng::seed_from_u64(seed ^ ((batch as u64) << 16));
    let mut handles = Vec::with_capacity(batch);
    for i in 0..batch {
        let words = 2 + rng.gen_range(0, 12);
        let mut prompt = format!("shard cell {i:02}");
        for w in 0..words {
            prompt.push_str(&format!(" tok{w}"));
        }
        let req = GenRequest::text(&prompt).max_new_tokens(8 + rng.gen_range(0, 16));
        handles.push(engine.submit(req)?);
    }
    let mut steps = 0u64;
    while !engine.is_idle() {
        if steps > 100_000 {
            return Err(Error::Request(
                "sharded decode workload did not drain".into(),
            ));
        }
        engine.step()?;
        steps += 1;
        for h in &handles {
            while h.events.try_recv().is_ok() {}
        }
    }
    let sm = engine.backend().shard_metrics();
    let decode_s = sm.decode_compute_s + sm.decode_collective_s;
    let tokens_per_sec = if decode_s > 0.0 {
        sm.decode_rows as f64 / decode_s
    } else {
        0.0
    };
    let overhead = if decode_s > 0.0 {
        sm.decode_collective_s / decode_s
    } else {
        0.0
    };
    let m = &engine.metrics;
    Ok(Json::obj(vec![
        ("shards", Json::Num(shards as f64)),
        ("batch", Json::Num(batch as f64)),
        ("steps", Json::Num(steps as f64)),
        ("requests_finished", Json::Num(m.requests_finished as f64)),
        ("tokens_generated", Json::Num(m.tokens_generated as f64)),
        ("decode_rows", Json::Num(sm.decode_rows as f64)),
        ("allgather_ops", Json::Num(sm.allgather_ops as f64)),
        ("allgather_bytes", Json::Num(sm.allgather_bytes as f64)),
        ("allreduce_ops", Json::Num(sm.allreduce_ops as f64)),
        ("allreduce_bytes", Json::Num(sm.allreduce_bytes as f64)),
        ("decode_compute_ms", Json::Num(sm.decode_compute_s * 1e3)),
        (
            "decode_collective_ms",
            Json::Num(sm.decode_collective_s * 1e3),
        ),
        ("modeled_decode_tokens_per_sec", Json::Num(tokens_per_sec)),
        ("collective_overhead", Json::Num(overhead)),
    ]))
}

/// Sweep the pinned M×batch grid (M∈{1,2,4,8} × batch∈{1,8,32}) on the
/// sharded sim backend and return the `BENCH_sharded.json` report
/// object: modeled decode tokens/s and collective overhead per cell.
/// Everything is a pure function of `seed` (manual sim clock, seeded
/// workload, fixed-order f64 accumulation), so the report is
/// byte-identical across runs — the bench and CI assert it by diffing
/// two consecutive runs.
pub fn sharded_decode_report(seed: u64) -> Result<Json> {
    let mut grid = Vec::new();
    for &shards in &SHARDED_DECODE_SHARDS {
        for &batch in &SHARDED_DECODE_BATCHES {
            grid.push(sharded_cell_run(seed, shards, batch)?);
        }
    }
    Ok(Json::obj(vec![
        ("seed", Json::Num(seed as f64)),
        ("shard_counts", Json::arr_usize(&SHARDED_DECODE_SHARDS)),
        ("batch_sizes", Json::arr_usize(&SHARDED_DECODE_BATCHES)),
        ("grid", Json::Arr(grid)),
    ]))
}

// ---------------------------------------------------------------------
// Grouped-decode harness (BENCH_grouped_decode.json)
// ---------------------------------------------------------------------

/// The pinned seed `benches/grouped_decode.rs` and the CI
/// `perf-trajectory` job run. Changing it invalidates the grouped
/// decode history, so don't.
pub const GROUPED_DECODE_SEED: u64 = 2408;

fn grouped_decode_spec(seed: u64) -> SharedPrefixSpec {
    SharedPrefixSpec {
        seed,
        ..SharedPrefixSpec::default()
    }
}

/// FNV-1a fold for the output fingerprint (stable, dependency-free).
fn fp_fold(acc: u64, x: u64) -> u64 {
    (acc ^ x).wrapping_mul(0x100_0000_01b3)
}

/// One arm of the grouped-decode comparison: warm every tenant's
/// system prompt into the prefix cache (one retirement per tenant
/// publishes its blocks — the steady serving state), then drain the
/// Zipf shared-prefix workload with grouping on or off. Reports the
/// concatenated output-token fingerprint next to the attention-reuse
/// accounting; `attn_positions_total` excludes the warm phase so both
/// arms divide savings by the same measured span.
fn grouped_arm_run(seed: u64, grouped: bool) -> Result<Json> {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 1024,
        max_new_tokens: 16,
        max_running: 16,
        prefix_cache: true,
        grouped_decode: grouped,
        seed,
        ..EngineConfig::default()
    };
    let spec = grouped_decode_spec(seed);
    let mut engine = SimEngine::new(cfg, SimSpec::default())?;
    for prompt in tenant_prompts(&spec) {
        let h = engine.submit(GenRequest::text(&prompt).max_new_tokens(2))?;
        engine.run_to_completion()?;
        let _ = h.drain();
    }
    let warm_total = engine.metrics.decode_attn_positions_total;

    let trace = shared_prefix_trace(&spec);
    let mut handles = Vec::with_capacity(trace.len());
    for r in &trace {
        let req = GenRequest::text(&r.prompt)
            .tenant(r.tenant.as_str())
            .max_new_tokens(r.max_new_tokens);
        handles.push(engine.submit(req)?);
    }
    let mut outs: Vec<Vec<u32>> = vec![Vec::new(); handles.len()];
    let mut steps = 0u64;
    while !engine.is_idle() {
        if steps > 200_000 {
            return Err(Error::Request(
                "grouped decode workload did not drain".into(),
            ));
        }
        engine.step()?;
        steps += 1;
        for (i, h) in handles.iter().enumerate() {
            while let Ok(ev) = h.events.try_recv() {
                if let GenEvent::Token(t) = ev {
                    outs[i].push(t);
                }
            }
        }
    }

    let mut fp = 0xcbf2_9ce4_8422_2325u64;
    for toks in &outs {
        fp = fp_fold(fp, 0x9e37_79b9_7f4a_7c15);
        for &t in toks {
            fp = fp_fold(fp, t as u64);
        }
    }

    let m = &engine.metrics;
    let total = m.decode_attn_positions_total - warm_total;
    let te = engine.geometry().token_elems() as u64;
    let flops_total = 4 * te * total;
    let reduction = if flops_total > 0 {
        m.decode_attn_flops_saved as f64 / flops_total as f64
    } else {
        0.0
    };
    Ok(Json::obj(vec![
        ("grouped", Json::Bool(grouped)),
        ("steps", Json::Num(steps as f64)),
        ("requests_finished", Json::Num(m.requests_finished as f64)),
        ("tokens_generated", Json::Num(m.tokens_generated as f64)),
        ("output_fingerprint", Json::Str(format!("{fp:016x}"))),
        (
            "grouped_decode_steps",
            Json::Num(m.grouped_decode_steps as f64),
        ),
        ("groups_formed", Json::Num(m.grouped_groups_formed as f64)),
        ("grouped_rows", Json::Num(m.grouped_rows as f64)),
        ("attn_positions_total", Json::Num(total as f64)),
        (
            "attn_positions_saved",
            Json::Num(m.decode_attn_positions_saved as f64),
        ),
        (
            "attn_flops_saved",
            Json::Num(m.decode_attn_flops_saved as f64),
        ),
        (
            "attn_bytes_saved",
            Json::Num(m.decode_attn_bytes_saved as f64),
        ),
        ("attn_flop_reduction", Json::Num(reduction)),
    ]))
}

/// Run the pinned Zipf shared-prefix workload twice — grouped decode
/// off, then on — and return the `BENCH_grouped_decode.json` report
/// object. Everything is a pure function of `seed` (manual sim clock,
/// seeded workload), so the report is byte-identical across runs — the
/// bench and CI assert it by diffing two consecutive runs. The
/// headline claims: identical output fingerprints on both arms, and
/// ≥30% of the decode attention FLOPs saved on the grouped arm.
pub fn grouped_decode_report(seed: u64) -> Result<Json> {
    let spec = grouped_decode_spec(seed);
    let ungrouped = grouped_arm_run(seed, false)?;
    let grouped = grouped_arm_run(seed, true)?;
    let fp_of = |j: &Json| {
        j.get("output_fingerprint")
            .and_then(Json::as_str)
            .map(str::to_string)
    };
    let fingerprints_match = fp_of(&grouped).is_some() && fp_of(&grouped) == fp_of(&ungrouped);
    let reduction = grouped
        .get("attn_flop_reduction")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    Ok(Json::obj(vec![
        ("seed", Json::Num(seed as f64)),
        (
            "workload",
            Json::obj(vec![
                ("n_tenants", Json::Num(spec.n_tenants as f64)),
                ("zipf_s", Json::Num(spec.zipf_s)),
                (
                    "system_prompt_len",
                    Json::Num(spec.system_prompt_len as f64),
                ),
                ("n_requests", Json::Num(spec.n_requests as f64)),
            ]),
        ),
        ("ungrouped", ungrouped),
        ("grouped", grouped),
        ("fingerprints_match", Json::Bool(fingerprints_match)),
        ("attn_flop_reduction", Json::Num(reduction)),
    ]))
}

// ---------------------------------------------------------------------
// Step-loop harness (BENCH_steploop.json)
// ---------------------------------------------------------------------

/// The pinned seed `benches/steploop.rs` and the CI `perf-trajectory`
/// job run. Changing it invalidates the step-loop history, so don't.
pub const STEPLOOP_SEED: u64 = 2419;

/// Decode chunk sizes the pinned step-loop grid sweeps.
const STEPLOOP_CHUNKS: [usize; 4] = [1, 2, 4, 8];

/// Batch sizes the pinned step-loop grid sweeps.
const STEPLOOP_BATCHES: [usize; 3] = [1, 4, 8];

/// One cell of the step-loop grid: drain a seeded `batch`-request
/// decode-heavy workload with `decode_chunk = chunk` and report how
/// the orchestration economics move.
///
/// The workload is a pure function of `(seed, batch)` — deliberately
/// *independent of the chunk size* — so every chunk in a column decodes
/// the exact same token stream (the differential matrix proves the
/// stronger behavior-identity claim) and the sweep compares like for
/// like.
///
/// Under the manual sim clock every intra-step time delta is
/// deterministically zero, so the overhead share is computed from the
/// attribution histogram *counts*, which the chunk-aware weighting
/// makes meaningful: `attr_stream_service` and `attr_policy` record
/// once per engine step (the per-step policy work chunking amortizes),
/// while `attr_decode` records once per *token*
/// (`record_weighted`). Tokens are constant across a column, steps
/// shrink as the chunk grows, so the share of samples spent on
/// orchestration strictly falls — the count-domain image of the
/// wall-time claim a real-clock engine would show.
///
/// `alloc_count` is an optional hook into a counting global allocator
/// (the bench binary installs one; in-crate tests pass `None` and get
/// `-1`): it is sampled around the drain loop and reported as
/// allocations per generated token. `tests/prop_steploop.rs` holds the
/// stronger per-step zero-allocation claim.
fn steploop_cell_run(
    seed: u64,
    chunk: usize,
    batch: usize,
    alloc_count: Option<&dyn Fn() -> u64>,
) -> Result<Json> {
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 256,
        max_new_tokens: 192,
        max_running: batch,
        decode_buckets: vec![1, 2, 4, 8],
        prefix_cache: false,
        stream_capacity: 64,
        flight_recorder_capacity: 64,
        decode_chunk: chunk,
        seed,
        ..EngineConfig::default()
    };
    let mut engine = SimEngine::new(cfg, SimSpec::default())?;
    let mut rng = Rng::seed_from_u64(seed ^ ((batch as u64) << 16));
    let mut handles = Vec::with_capacity(batch);
    for i in 0..batch {
        let words = 2 + rng.gen_range(0, 10);
        let mut prompt = format!("steploop cell {i:02}");
        for w in 0..words {
            prompt.push_str(&format!(" tok{w}"));
        }
        let req = GenRequest::text(&prompt).max_new_tokens(128 + rng.gen_range(0, 64));
        handles.push(engine.submit(req)?);
    }

    let allocs_before = alloc_count.map(|f| f());
    let mut steps = 0u64;
    while !engine.is_idle() {
        if steps > 100_000 {
            return Err(Error::Request("step-loop workload did not drain".into()));
        }
        engine.step()?;
        steps += 1;
        for h in &handles {
            while h.events.try_recv().is_ok() {}
        }
    }
    let allocs = alloc_count
        .zip(allocs_before)
        .map(|(f, before)| f().saturating_sub(before));

    let m = &engine.metrics;
    let stream = m.attr_stream_service.count() as f64;
    let policy = m.attr_policy.count() as f64;
    let admission = m.attr_admission.count() as f64;
    let prefill = m.attr_prefill.count() as f64;
    let decode = m.attr_decode.count() as f64;
    let samples = stream + policy + admission + prefill + decode;
    let overhead_share = if samples > 0.0 {
        (stream + policy) / samples
    } else {
        0.0
    };
    let tokens = m.tokens_generated as f64;
    let virtual_s = steps as f64 * SIM_STEP.as_secs_f64();
    let allocs_per_token = match allocs {
        Some(a) if tokens > 0.0 => a as f64 / tokens,
        Some(_) => 0.0,
        None => -1.0,
    };
    Ok(Json::obj(vec![
        ("chunk", Json::Num(chunk as f64)),
        ("batch", Json::Num(batch as f64)),
        ("steps", Json::Num(steps as f64)),
        ("requests_finished", Json::Num(m.requests_finished as f64)),
        ("tokens_generated", Json::Num(tokens)),
        ("tokens_per_sec", Json::Num(tokens / virtual_s)),
        ("steps_per_sec", Json::Num(steps as f64 / virtual_s)),
        ("overhead_share", Json::Num(overhead_share)),
        ("allocs_per_token", Json::Num(allocs_per_token)),
        (
            "attr_counts",
            Json::obj(vec![
                ("stream_service", Json::Num(stream)),
                ("policy", Json::Num(policy)),
                ("admission", Json::Num(admission)),
                ("prefill", Json::Num(prefill)),
                ("decode_tokens", Json::Num(decode)),
            ]),
        ),
    ]))
}

/// Sweep the pinned chunk×batch grid (chunk∈{1,2,4,8} × batch∈{1,4,8})
/// on the deterministic sim engine and return the `BENCH_steploop.json`
/// report object: virtual-time throughput, per-step orchestration
/// overhead share, and allocations per token per cell. Everything is a
/// pure function of `seed` (manual sim clock, seeded workload, and an
/// allocation sequence that is itself deterministic), so the report is
/// byte-identical across runs *and processes* — the bench and CI assert
/// it by diffing two consecutive runs. The headline claims, asserted by
/// `benches/steploop.rs` and mirrored in-crate: the overhead share
/// strictly decreases as the chunk grows, and chunk 4 clears chunk 1's
/// tokens/s by ≥20%, at every batch size.
pub fn steploop_report(seed: u64, alloc_count: Option<&dyn Fn() -> u64>) -> Result<Json> {
    let mut grid = Vec::new();
    for &chunk in &STEPLOOP_CHUNKS {
        for &batch in &STEPLOOP_BATCHES {
            grid.push(steploop_cell_run(seed, chunk, batch, alloc_count)?);
        }
    }
    Ok(Json::obj(vec![
        ("seed", Json::Num(seed as f64)),
        ("chunk_sizes", Json::arr_usize(&STEPLOOP_CHUNKS)),
        ("batch_sizes", Json::arr_usize(&STEPLOOP_BATCHES)),
        ("grid", Json::Arr(grid)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.000s");
        assert_eq!(fmt_time(0.002), "2.000ms");
        assert_eq!(fmt_time(2e-6), "2.0us");
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn pct_is_nearest_rank_and_total_on_empty() {
        assert_eq!(pct(&[], 50.0), 0);
        assert_eq!(pct(&[10], 99.0), 10);
        assert_eq!(pct(&[1, 2, 3, 4], 0.0), 1);
        assert_eq!(pct(&[1, 2, 3, 4], 50.0), 3, "idx 1.5 rounds up");
        assert_eq!(pct(&[1, 2, 3, 4], 100.0), 4);
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }

    #[test]
    fn fleet_routing_report_is_byte_identical_and_cache_aware_wins() {
        let a = fleet_routing_report(FLEET_ROUTING_SEED).unwrap();
        let b = fleet_routing_report(FLEET_ROUTING_SEED).unwrap();
        assert_eq!(a.to_string(), b.to_string(), "report must reproduce");
        let hit = |policy: &str| {
            a.get(policy)
                .and_then(|p| p.get("prefix_hit_rate"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        let (rr, ll, ca) = (hit("round_robin"), hit("least_loaded"), hit("cache_aware"));
        assert!(ca > ll, "cache-aware {ca} must beat least-loaded {ll}");
        assert!(ca > rr, "cache-aware {ca} must beat round-robin {rr}");
        // Every policy finishes the whole workload.
        for policy in ["round_robin", "least_loaded", "cache_aware"] {
            let fin = a
                .get(policy)
                .and_then(|p| p.get("requests_finished"))
                .and_then(Json::as_f64)
                .unwrap();
            assert_eq!(fin, 96.0, "{policy} finished all requests");
        }
    }

    #[test]
    fn grouped_decode_report_is_byte_identical_and_saves_flops() {
        let a = grouped_decode_report(GROUPED_DECODE_SEED).unwrap();
        let b = grouped_decode_report(GROUPED_DECODE_SEED).unwrap();
        assert_eq!(a.to_string(), b.to_string(), "report must reproduce");
        assert_eq!(
            a.get("fingerprints_match").and_then(Json::as_bool),
            Some(true),
            "grouping must not change any output token"
        );
        let r = a
            .get("attn_flop_reduction")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(r >= 0.30, "attention FLOP reduction {r} under the 30% bar");
        let arm = |key: &str, field: &str| {
            a.get(key)
                .and_then(|j| j.get(field))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(arm("ungrouped", "attn_positions_saved"), 0.0);
        assert_eq!(arm("ungrouped", "groups_formed"), 0.0);
        assert!(arm("grouped", "groups_formed") > 0.0);
        assert_eq!(
            arm("ungrouped", "attn_positions_total"),
            arm("grouped", "attn_positions_total"),
            "both arms must decode the same logical attention span"
        );
        assert_eq!(arm("ungrouped", "requests_finished"), 104.0, "96 + 8 warm");
        assert_eq!(
            arm("ungrouped", "requests_finished"),
            arm("grouped", "requests_finished")
        );
    }

    #[test]
    fn sharded_decode_report_is_byte_identical_and_overhead_scales() {
        let a = sharded_decode_report(SHARDED_DECODE_SEED).unwrap();
        let b = sharded_decode_report(SHARDED_DECODE_SEED).unwrap();
        assert_eq!(a.to_string(), b.to_string(), "report must reproduce");
        let cells = a.get("grid").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 12, "4 shard counts x 3 batch sizes");
        let cell = |shards: f64, batch: f64| {
            cells
                .iter()
                .find(|c| {
                    c.get("shards").and_then(Json::as_f64) == Some(shards)
                        && c.get("batch").and_then(Json::as_f64) == Some(batch)
                })
                .expect("grid cell present")
        };
        let num = |shards: f64, batch: f64, key: &str| {
            cell(shards, batch).get(key).and_then(Json::as_f64).unwrap()
        };
        // M=1 runs no collectives; at batch 1 the overhead share is
        // strictly increasing in M (the acceptance headline).
        assert_eq!(num(1.0, 1.0, "collective_overhead"), 0.0);
        let (o2, o4, o8) = (
            num(2.0, 1.0, "collective_overhead"),
            num(4.0, 1.0, "collective_overhead"),
            num(8.0, 1.0, "collective_overhead"),
        );
        assert!(o2 > 0.0, "M=2 pays for collectives");
        assert!(o4 > o2 && o8 > o4, "overhead not increasing: {o2} {o4} {o8}");
        // The workload is shard-invariant: every M decodes the same
        // rows, so only the modeled budget moves across a column.
        for &b in &[1.0, 8.0, 32.0] {
            let r1 = num(1.0, b, "decode_rows");
            assert!(r1 > 0.0);
            for &s in &[2.0, 4.0, 8.0] {
                assert_eq!(num(s, b, "decode_rows"), r1, "rows depend on M at batch {b}");
            }
        }
    }

    #[test]
    fn steploop_report_is_byte_identical_and_overhead_scales() {
        let a = steploop_report(STEPLOOP_SEED, None).unwrap();
        let b = steploop_report(STEPLOOP_SEED, None).unwrap();
        assert_eq!(a.to_string(), b.to_string(), "report must reproduce");
        let cells = a.get("grid").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 12, "4 chunk sizes x 3 batch sizes");
        let num = |chunk: f64, batch: f64, key: &str| {
            cells
                .iter()
                .find(|c| {
                    c.get("chunk").and_then(Json::as_f64) == Some(chunk)
                        && c.get("batch").and_then(Json::as_f64) == Some(batch)
                })
                .expect("grid cell present")
                .get(key)
                .and_then(Json::as_f64)
                .unwrap()
        };
        for &batch in &[1.0, 4.0, 8.0] {
            // The workload is chunk-invariant: every chunk size in a
            // column generates the exact same tokens.
            let t1 = num(1.0, batch, "tokens_generated");
            assert!(t1 > 0.0);
            for &c in &[2.0, 4.0, 8.0] {
                assert_eq!(
                    num(c, batch, "tokens_generated"),
                    t1,
                    "tokens depend on chunk at batch {batch}"
                );
            }
            // The acceptance headlines: orchestration overhead share
            // strictly falls as the chunk grows, and chunk 4 clears
            // chunk 1's throughput by >= 20%.
            let (o1, o2, o4, o8) = (
                num(1.0, batch, "overhead_share"),
                num(2.0, batch, "overhead_share"),
                num(4.0, batch, "overhead_share"),
                num(8.0, batch, "overhead_share"),
            );
            assert!(
                o1 > o2 && o2 > o4 && o4 > o8,
                "overhead share not strictly decreasing at batch {batch}: {o1} {o2} {o4} {o8}"
            );
            let (tps1, tps4) = (
                num(1.0, batch, "tokens_per_sec"),
                num(4.0, batch, "tokens_per_sec"),
            );
            assert!(
                tps4 >= 1.2 * tps1,
                "chunk-4 tokens/s {tps4} under 1.2x chunk-1 {tps1} at batch {batch}"
            );
        }
    }
}
