//! Shared helpers for the figure-reproduction benches: fixed-width table
//! printing in the shape of the paper's tables/series, and simple timing
//! utilities for the real-CPU measurement paths.

use std::time::Instant;

/// Print a header band for one reproduced figure/table.
pub fn banner(id: &str, title: &str) {
    println!();
    println!("==================================================================");
    println!("{id}: {title}");
    println!("==================================================================");
}

/// Print a row of labeled values with a fixed-width first column.
pub fn row(label: &str, values: &[String]) {
    print!("{label:<28}");
    for v in values {
        print!("{v:>14}");
    }
    println!();
}

/// Format seconds adaptively (s / ms / us).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Median-of-N wall-clock timing of a closure (real-CPU benches).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Geometric mean (the paper's "average speedup" aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.000s");
        assert_eq!(fmt_time(0.002), "2.000ms");
        assert_eq!(fmt_time(2e-6), "2.0us");
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}
