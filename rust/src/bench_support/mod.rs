//! Shared helpers for the figure-reproduction benches: fixed-width table
//! printing in the shape of the paper's tables/series, simple timing
//! utilities for the real-CPU measurement paths, and the
//! [`perf_trajectory_report`] harness behind `benches/perf_trajectory.rs`
//! and the CI `perf-trajectory` job.

use std::collections::HashMap;
use std::time::Instant;

use crate::api::{GenEvent, GenRequest, InferenceEngine};
use crate::config::EngineConfig;
use crate::simengine::{SimEngine, SimSpec, SIM_STEP};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Print a header band for one reproduced figure/table.
pub fn banner(id: &str, title: &str) {
    println!();
    println!("==================================================================");
    println!("{id}: {title}");
    println!("==================================================================");
}

/// Print a row of labeled values with a fixed-width first column.
pub fn row(label: &str, values: &[String]) {
    print!("{label:<28}");
    for v in values {
        print!("{v:>14}");
    }
    println!();
}

/// Format seconds adaptively (s / ms / us).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Median-of-N wall-clock timing of a closure (real-CPU benches).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Geometric mean (the paper's "average speedup" aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

// ---------------------------------------------------------------------
// Perf-trajectory harness (BENCH_serving.json)
// ---------------------------------------------------------------------

/// The pinned seed `benches/perf_trajectory.rs` and the CI
/// `perf-trajectory` job run. Changing it invalidates the perf
/// trajectory history, so don't.
pub const PERF_TRAJECTORY_SEED: u64 = 2311;

/// Deterministic nearest-rank percentile over a sorted sample
/// (microseconds). Zero on an empty sample.
fn pct(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run the pinned serving workload on the deterministic sim engine and
/// return the `BENCH_serving.json` report object.
///
/// The workload is a pure function of `seed`: 24 requests over two
/// shared prompt prefixes and three tenants, mixed priorities and
/// budgets, submitted up front against a decode pool of 8 lanes (so
/// queue wait is real), drained eagerly every step. All rates are in
/// *virtual* time (the sim clock advances [`SIM_STEP`] per engine
/// step), which is what makes the report byte-identical across runs —
/// the determinism CI asserts by diffing two consecutive runs.
///
/// Latency percentiles come from the engine's completed request spans
/// ([`crate::obs::RequestSpan`]): TTFT directly, inter-token as each
/// span's decode time over its emitted-token gaps. The `step_overhead`
/// object carries the step-time attribution sums; under the manual sim
/// clock intra-step deltas are structurally zero, so the *keys* are
/// the contract here — real-clock engines fill the same fields with
/// wall time (see `docs/OBSERVABILITY.md`).
pub fn perf_trajectory_report(seed: u64) -> Result<Json> {
    const REQUESTS: usize = 24;
    let cfg = EngineConfig {
        kv_block_tokens: 8,
        kv_total_blocks: 256,
        max_new_tokens: 32,
        max_running: 8,
        prefix_cache: true,
        stream_capacity: 64,
        flight_recorder_capacity: 4096,
        seed,
        ..EngineConfig::default()
    };
    let mut engine = SimEngine::new(cfg, SimSpec::default())?;
    let mut rng = Rng::seed_from_u64(seed);
    let prefixes = [
        "sys: shared serving preamble for the perf trajectory. ",
        "ctx: common retrieval context for half the pool. ",
    ];
    let tenants = ["acme", "globex", "initech"];
    let mut handles = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS {
        let prompt = format!("{}request {i:02}", prefixes[i % prefixes.len()]);
        let req = GenRequest::text(&prompt)
            .tenant(tenants[i % tenants.len()])
            .priority(rng.gen_range(0, 5) as i32 - 2)
            .max_new_tokens(4 + rng.gen_range(0, 28));
        handles.push(engine.submit(req)?);
    }

    let mut token_counts = vec![0usize; handles.len()];
    let mut steps = 0u64;
    while !engine.is_idle() {
        if steps > 100_000 {
            return Err(Error::Request(
                "perf trajectory workload did not drain".into(),
            ));
        }
        engine.step()?;
        steps += 1;
        for (i, h) in handles.iter().enumerate() {
            while let Ok(ev) = h.events.try_recv() {
                if matches!(ev, GenEvent::Token(_)) {
                    token_counts[i] += 1;
                }
            }
        }
    }

    let by_id: HashMap<_, _> = handles.iter().enumerate().map(|(i, h)| (h.id, i)).collect();
    let mut ttfts = Vec::new();
    let mut inter = Vec::new();
    for s in engine.spans().completed() {
        if let Some(t) = s.ttft() {
            ttfts.push(t.as_micros() as u64);
        }
        let tokens = by_id.get(&s.id).map(|&i| token_counts[i]).unwrap_or(0);
        if tokens > 1 {
            inter.push(s.decode_time().as_micros() as u64 / (tokens as u64 - 1));
        }
    }
    ttfts.sort_unstable();
    inter.sort_unstable();

    let m = &engine.metrics;
    let virtual_s = steps as f64 * SIM_STEP.as_secs_f64();
    let tokens = m.tokens_generated as f64;
    let hit_rate = if m.prefix_lookups > 0 {
        m.prefix_hits as f64 / m.prefix_lookups as f64
    } else {
        0.0
    };
    Ok(Json::obj(vec![
        ("seed", Json::Num(seed as f64)),
        ("requests", Json::Num(handles.len() as f64)),
        ("steps", Json::Num(steps as f64)),
        ("virtual_ms", Json::Num(virtual_s * 1e3)),
        ("tokens_generated", Json::Num(tokens)),
        ("tokens_per_sec", Json::Num(tokens / virtual_s)),
        ("steps_per_sec", Json::Num(steps as f64 / virtual_s)),
        ("ttft_p50_us", Json::Num(pct(&ttfts, 50.0) as f64)),
        ("ttft_p99_us", Json::Num(pct(&ttfts, 99.0) as f64)),
        ("inter_token_p50_us", Json::Num(pct(&inter, 50.0) as f64)),
        ("inter_token_p99_us", Json::Num(pct(&inter, 99.0) as f64)),
        ("prefix_hit_rate", Json::Num(hit_rate)),
        (
            "step_overhead",
            Json::obj(vec![
                (
                    "stream_service_us",
                    Json::Num(m.attr_stream_service.sum_us() as f64),
                ),
                ("policy_us", Json::Num(m.attr_policy.sum_us() as f64)),
                ("admission_us", Json::Num(m.attr_admission.sum_us() as f64)),
                ("prefill_us", Json::Num(m.attr_prefill.sum_us() as f64)),
                ("decode_us", Json::Num(m.attr_decode.sum_us() as f64)),
            ]),
        ),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.000s");
        assert_eq!(fmt_time(0.002), "2.000ms");
        assert_eq!(fmt_time(2e-6), "2.0us");
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn pct_is_nearest_rank_and_total_on_empty() {
        assert_eq!(pct(&[], 50.0), 0);
        assert_eq!(pct(&[10], 99.0), 10);
        assert_eq!(pct(&[1, 2, 3, 4], 0.0), 1);
        assert_eq!(pct(&[1, 2, 3, 4], 50.0), 3, "idx 1.5 rounds up");
        assert_eq!(pct(&[1, 2, 3, 4], 100.0), 4);
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}
