//! Real-CPU profiling backend for the §5 decision flow: times the
//! AOT-compiled microkernel artifacts (`micro_{impl}_m{M}_{op}`) through
//! the PJRT runtime and feeds the measurements to `find_inflections`.

use crate::util::rng::Rng;

use super::{find_inflections, ImplKind, LookupTable, OpInflection};
use crate::bench_support::time_median;
use crate::error::{Error, Result};
use crate::runtime::{literal_f32, Runtime};

fn impl_tag(ik: ImplKind) -> &'static str {
    match ik {
        ImplKind::A => "gemv",
        ImplKind::B => "flat",
        ImplKind::C => "conv",
    }
}

/// Microkernel entry name convention from aot.py.
pub fn micro_entry_name(ik: ImplKind, m: usize, op: &str) -> String {
    format!("micro_{}_m{}_{}", impl_tag(ik), m, op)
}

/// Time one microkernel artifact (median of `reps` runs), seconds.
pub fn time_micro(
    rt: &mut Runtime,
    ik: ImplKind,
    m: usize,
    n: usize,
    k: usize,
    op: &str,
    reps: usize,
) -> Result<f64> {
    let name = micro_entry_name(ik, m, op);
    rt.ensure_compiled(&name)?;
    let mut rng = Rng::seed_from_u64(0xF1A5);
    let x: Vec<f32> = (0..m * k).map(|_| rng.gen_f32(-1.0, 1.0)).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.gen_f32(-1.0, 1.0)).collect();
    let x = literal_f32(&x, &[m, k])?;
    let w = literal_f32(&w, &[k, n])?;
    // One warmup execution outside the timed region.
    rt.execute(&name, &[&x, &w])?;
    let mut err = None;
    let t = time_median(reps, || {
        if let Err(e) = rt.execute(&name, &[&x, &w]) {
            err = Some(e);
        }
    });
    match err {
        Some(e) => Err(e),
        None => Ok(t),
    }
}

/// Run the full decision flow over every micro op in the manifest,
/// producing the runtime lookup table (Figure 9(b) offline pass).
pub fn build_lookup_table(rt: &mut Runtime, reps: usize) -> Result<LookupTable> {
    // Discover (op, [ms], n, k) from manifest micro entries.
    let mut ops: Vec<(String, usize, usize, Vec<usize>)> = Vec::new();
    for e in rt.manifest.entries.clone() {
        if e.kind != "micro" {
            continue;
        }
        let op = e
            .params
            .get("op")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string();
        let m = e.params.get("m").and_then(|v| v.as_usize()).unwrap_or(0);
        let n = e.params.get("n").and_then(|v| v.as_usize()).unwrap_or(0);
        let k = e.params.get("k").and_then(|v| v.as_usize()).unwrap_or(0);
        match ops.iter_mut().find(|(o, ..)| *o == op) {
            Some((_, _, _, ms)) => {
                if !ms.contains(&m) {
                    ms.push(m);
                }
            }
            None => ops.push((op, n, k, vec![m])),
        }
    }
    if ops.is_empty() {
        return Err(Error::Artifact(
            "no micro entries in manifest (rebuild artifacts without --skip-micro)".into(),
        ));
    }
    let mut entries: Vec<OpInflection> = Vec::new();
    for (op, n, k, mut ms) in ops {
        ms.sort_unstable();
        let mut profiler = |ik: ImplKind, m: usize| time_micro(rt, ik, m, n, k, &op, reps);
        entries.push(find_inflections(&op, n, k, &ms, &mut profiler)?);
    }
    Ok(LookupTable {
        model: rt.manifest.model.name.clone(),
        hardware: format!("pjrt-{}", rt.platform()),
        entries,
    })
}
