//! C3 — Heuristic dataflow with hardware resource adaptation (paper §5).
//!
//! For each of the four [N, K] linear shapes of a model, an *offline*
//! decision flow profiles three implementations while sweeping M:
//!   ImplA — FastGEMV-style vector kernel (CUDA core / VPU),
//!   ImplB — the paper's flat GEMM (pad-to-8, §4),
//!   ImplC — conventionally tiled GEMM (cuBLAS/CUTLASS-style),
//! finds the inflection points M1 (A->B) and M2 (B->C), and persists a
//! lookup table. At runtime, dispatch is a table lookup — zero cost on
//! the hot path (Figure 9).

pub mod profile;

use crate::error::{Error, Result};
use crate::util::json::{parse, Json};

/// The three implementation families of Figure 9(b).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ImplKind {
    /// FastGEMV-style (CUDA core / VPU).
    A,
    /// FlashDecoding++ flat GEMM (Tensor Core / MXU, pad-to-8).
    B,
    /// Conventional tiled GEMM (Tensor Core / MXU, M tiled to 64).
    C,
}

impl ImplKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ImplKind::A => "ImplA/gemv",
            ImplKind::B => "ImplB/flat",
            ImplKind::C => "ImplC/conv",
        }
    }
}

/// One profiled point: implementation time at a given M for one [N, K].
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    pub m: usize,
    pub impl_kind: ImplKind,
    pub seconds: f64,
}

/// Inflection points for one [N, K] shape.
#[derive(Debug, Clone)]
pub struct OpInflection {
    pub op: String,
    pub n: usize,
    pub k: usize,
    /// Smallest profiled M where ImplB beats ImplA.
    pub m1: usize,
    /// Smallest profiled M where ImplC beats ImplB.
    pub m2: usize,
}

impl OpInflection {
    /// Runtime dispatch (Figure 9(c)): table lookup by M.
    pub fn dispatch(&self, m: usize) -> ImplKind {
        if m < self.m1 {
            ImplKind::A
        } else if m < self.m2 {
            ImplKind::B
        } else {
            ImplKind::C
        }
    }
}

/// The per-model lookup table: one entry per [N, K] shape.
#[derive(Debug, Clone, Default)]
pub struct LookupTable {
    pub model: String,
    pub hardware: String,
    pub entries: Vec<OpInflection>,
}

impl LookupTable {
    pub fn dispatch(&self, op: &str, m: usize) -> Result<ImplKind> {
        self.entries
            .iter()
            .find(|e| e.op == op)
            .map(|e| e.dispatch(m))
            .ok_or_else(|| Error::Config(format!("no lookup entry for op {op}")))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("hardware", Json::Str(self.hardware.clone())),
            (
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("op", Json::Str(e.op.clone())),
                                ("n", Json::Num(e.n as f64)),
                                ("k", Json::Num(e.k as f64)),
                                ("m1", Json::Num(e.m1 as f64)),
                                ("m2", Json::Num(e.m2 as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut entries = Vec::new();
        for e in j.req_arr("entries")? {
            entries.push(OpInflection {
                op: e.req_str("op")?,
                n: e.req_usize("n")?,
                k: e.req_usize("k")?,
                m1: e.req_usize("m1")?,
                m2: e.req_usize("m2")?,
            });
        }
        Ok(LookupTable {
            model: j.req_str("model")?,
            hardware: j.req_str("hardware")?,
            entries,
        })
    }

    pub fn save_json(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load_json(path: &str) -> Result<Self> {
        Self::from_json(&parse(&std::fs::read_to_string(path)?)?)
    }
}

/// A profiler maps (impl, M) -> seconds for a fixed [N, K].
pub trait GemmProfiler {
    fn time(&mut self, impl_kind: ImplKind, m: usize) -> Result<f64>;
}

impl<F> GemmProfiler for F
where
    F: FnMut(ImplKind, usize) -> Result<f64>,
{
    fn time(&mut self, impl_kind: ImplKind, m: usize) -> Result<f64> {
        self(impl_kind, m)
    }
}

/// The decision flow of Figure 9(b): sweep M over `ms` (ascending),
/// profile the three implementations, and locate M1 and M2.
///
/// Robustness: real profiles are noisy, so an inflection is declared at
/// the first M where the challenger wins and *never loses again* at any
/// larger profiled M (monotone suffix rule). This guarantees
/// A-before-B-before-C monotone dispatch even on noisy data.
pub fn find_inflections(
    op: &str,
    n: usize,
    k: usize,
    ms: &[usize],
    profiler: &mut dyn GemmProfiler,
) -> Result<OpInflection> {
    if ms.is_empty() {
        return Err(Error::Config("decision flow needs at least one M".into()));
    }
    let mut wins_b = vec![false; ms.len()]; // B beats A at ms[i]
    let mut wins_c = vec![false; ms.len()]; // C beats B at ms[i]
    for (i, &m) in ms.iter().enumerate() {
        let ta = profiler.time(ImplKind::A, m)?;
        let tb = profiler.time(ImplKind::B, m)?;
        let tc = profiler.time(ImplKind::C, m)?;
        wins_b[i] = tb < ta;
        wins_c[i] = tc < tb;
    }
    let m1 = first_stable_win(ms, &wins_b);
    let m2 = first_stable_win(ms, &wins_c).max(m1);
    Ok(OpInflection {
        op: op.to_string(),
        n,
        k,
        m1,
        m2,
    })
}

/// Smallest `ms[i]` from which `wins` stays true; `usize::MAX`-like
/// sentinel (beyond the last M) when the challenger never stabilizes.
fn first_stable_win(ms: &[usize], wins: &[bool]) -> usize {
    let mut idx = ms.len();
    for i in (0..ms.len()).rev() {
        if wins[i] {
            idx = i;
        } else {
            break;
        }
    }
    if idx == ms.len() {
        ms.last().unwrap() + 1
    } else {
        ms[idx]
    }
}

/// Standard M sweep for the decision flow.
pub fn default_m_sweep() -> Vec<usize> {
    vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic profiler with known crossovers: A wins below 8,
    /// B wins in [8, 64), C wins from 64.
    fn synthetic(impl_kind: ImplKind, m: usize) -> Result<f64> {
        let t = match impl_kind {
            ImplKind::A => m as f64,               // linear in M
            ImplKind::B => 4.0 + m as f64 * 0.45,  // flat + slope
            ImplKind::C => 28.0 + m as f64 * 0.05, // big constant, tiny slope
        };
        Ok(t)
    }

    #[test]
    fn finds_known_inflections() {
        let ms = default_m_sweep();
        let inf = find_inflections("qkv", 12288, 4096, &ms, &mut synthetic).unwrap();
        // A: t=m; B: 4+0.45m -> B wins from m=8 (8 vs 7.6). C beats B from
        // 28+0.05m < 4+0.45m -> m >= 60 -> first profiled M = 64.
        assert_eq!(inf.m1, 8);
        assert_eq!(inf.m2, 64);
    }

    #[test]
    fn dispatch_monotone() {
        let inf = OpInflection {
            op: "x".into(),
            n: 1,
            k: 1,
            m1: 8,
            m2: 64,
        };
        assert_eq!(inf.dispatch(1), ImplKind::A);
        assert_eq!(inf.dispatch(7), ImplKind::A);
        assert_eq!(inf.dispatch(8), ImplKind::B);
        assert_eq!(inf.dispatch(63), ImplKind::B);
        assert_eq!(inf.dispatch(64), ImplKind::C);
        assert_eq!(inf.dispatch(10_000), ImplKind::C);
    }

    #[test]
    fn never_winning_challenger_stays_out() {
        // B never beats A -> m1 beyond the sweep -> always A below m2.
        let mut prof = |ik: ImplKind, m: usize| -> Result<f64> {
            Ok(match ik {
                ImplKind::A => 1.0,
                ImplKind::B => 2.0,
                ImplKind::C => 3.0 - 0.001 * m as f64,
            })
        };
        let ms = vec![1, 8, 64];
        let inf = find_inflections("x", 1, 1, &ms, &mut prof).unwrap();
        assert!(inf.m1 > 64);
        assert!(inf.m2 >= inf.m1);
        assert_eq!(inf.dispatch(64), ImplKind::A);
    }

    #[test]
    fn noisy_profile_keeps_monotonicity() {
        // B wins at m=2 by noise, loses at 4, then wins from 8 onward.
        let mut prof = |ik: ImplKind, m: usize| -> Result<f64> {
            Ok(match ik {
                ImplKind::A => match m {
                    2 => 10.0,
                    _ => m as f64,
                },
                ImplKind::B => 4.0 + 0.45 * m as f64,
                ImplKind::C => 1e9,
            })
        };
        let ms = vec![1, 2, 4, 8, 16, 32];
        let inf = find_inflections("x", 1, 1, &ms, &mut prof).unwrap();
        assert_eq!(inf.m1, 8, "noise blip at m=2 must not set m1");
    }

    #[test]
    fn lookup_table_roundtrip() {
        let table = LookupTable {
            model: "tiny".into(),
            hardware: "cpu".into(),
            entries: vec![OpInflection {
                op: "qkv_proj".into(),
                n: 768,
                k: 256,
                m1: 4,
                m2: 32,
            }],
        };
        let dir = std::env::temp_dir().join("fdpp_table_test.json");
        let path = dir.to_str().unwrap();
        table.save_json(path).unwrap();
        let back = LookupTable::load_json(path).unwrap();
        assert_eq!(back.entries[0].m1, 4);
        assert_eq!(back.dispatch("qkv_proj", 2).unwrap(), ImplKind::A);
        assert_eq!(back.dispatch("qkv_proj", 8).unwrap(), ImplKind::B);
        assert!(back.dispatch("nope", 8).is_err());
        std::fs::remove_file(path).ok();
    }
}
