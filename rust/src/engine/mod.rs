//! The serving engine: single-owner hot loop tying together the PJRT
//! runtime, paged KV cache, continuous batcher, scheduler and sampler.
//!
//! Per iteration: the scheduler picks prefill-vs-decode; prefill runs a
//! single sequence through a bucketed prefill executable and admits it
//! into the running set; decode assembles the bucketed batch, executes
//! one step for every running sequence, samples, streams tokens, and
//! retires finished sequences.
//!
//! KV residency (perf pass, EXPERIMENTS.md §Perf): the dense KV tensors
//! persist on device across decode steps. Lanes are sticky, so a newly
//! prefilled sequence is spliced into the running batch *on device* via
//! the `insert_b{B}_s{S}` artifact — no host round trip. Only bucket
//! growth/shrink forces a host-side rebuild through the paged store.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

use crate::batching::{pick_prefill_bucket, Batcher};
use crate::config::EngineConfig;
use crate::error::{Error, Result};
use crate::kvcache::{KvCache, KvGeometry, SeqId};
use crate::metrics::EngineMetrics;
use crate::prefixcache::{PrefixCache, PrefixMatch};
use crate::router::{FinishReason, Request, Router, SeqState, Sequence, TokenEvent};
use crate::runtime::{literal_f32, literal_i32, to_vec_f32, Manifest, Runtime};
use crate::sampling::{Sampler, SamplingParams};
use crate::scheduler::{decide, preemption_victim, Action, PreemptCandidate, SchedState};
use crate::tokenizer::{ByteTokenizer, EOS};

/// Device-resident dense KV state for the current batch composition.
struct DenseState {
    bucket: usize,
    /// Mirrors the batcher's sticky lanes at the time of the last sync.
    lanes: Vec<Option<SeqId>>,
    k: xla::Literal,
    v: xla::Literal,
}

/// The engine. Owns all sequence state; not Send — run it on a dedicated
/// thread and talk to it via `Request` channels.
pub struct Engine {
    pub rt: Runtime,
    pub cfg: EngineConfig,
    kv: KvCache,
    prefix: PrefixCache,
    batcher: Batcher,
    router: Router,
    sampler: Sampler,
    seqs: HashMap<SeqId, Sequence>,
    dense: Option<DenseState>,
    pub metrics: EngineMetrics,
    pub tokenizer: ByteTokenizer,
    vocab: usize,
}

impl Engine {
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let m = &rt.manifest.model;
        let geo = KvGeometry {
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.head_dim,
            block_tokens: cfg.kv_block_tokens,
            max_seq: m.max_seq,
        };
        let kv = KvCache::new(geo, cfg.kv_total_blocks);
        let tokenizer = ByteTokenizer::new(m.vocab_size);
        let vocab = m.vocab_size;
        Ok(Engine {
            prefix: PrefixCache::new(cfg.kv_block_tokens),
            batcher: Batcher::new(cfg.decode_buckets.clone()),
            sampler: Sampler::new(cfg.seed),
            router: Router::new(),
            seqs: HashMap::new(),
            dense: None,
            metrics: EngineMetrics::default(),
            kv,
            rt,
            cfg,
            tokenizer,
            vocab,
        })
    }

    /// Pre-compile the executables the serving loop will need (moves the
    /// compile cost out of the first request's latency).
    pub fn warmup(&mut self) -> Result<()> {
        for &b in &self.cfg.decode_buckets.clone() {
            self.rt
                .ensure_compiled(&Manifest::decode_entry_name(b, !self.cfg.async_softmax))?;
        }
        for &s in &self.cfg.prefill_buckets.clone() {
            self.rt.ensure_compiled(&Manifest::prefill_entry_name(s))?;
        }
        Ok(())
    }

    /// Submit a text prompt; returns (seq id, token stream).
    pub fn submit_text(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<(SeqId, mpsc::Receiver<TokenEvent>)> {
        let toks = self.tokenizer.encode(prompt);
        self.submit_tokens(toks, max_new_tokens, params)
    }

    /// Submit pre-tokenized input.
    pub fn submit_tokens(
        &mut self,
        prompt_tokens: Vec<u32>,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<(SeqId, mpsc::Receiver<TokenEvent>)> {
        let max_prefill = *self.cfg.prefill_buckets.last().unwrap();
        if prompt_tokens.is_empty() {
            return Err(Error::Request("empty prompt".into()));
        }
        if prompt_tokens.len() > max_prefill {
            return Err(Error::Request(format!(
                "prompt of {} tokens exceeds the largest prefill bucket {max_prefill}",
                prompt_tokens.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let id = self.router.submit(Request {
            prompt_tokens,
            max_new_tokens: max_new_tokens.min(self.cfg.max_new_tokens),
            params,
            stream: tx,
            arrived: Instant::now(),
        });
        Ok((id, rx))
    }

    /// True when no work remains.
    pub fn is_idle(&self) -> bool {
        self.router.queued() == 0 && self.batcher.is_empty()
    }

    pub fn running(&self) -> usize {
        self.batcher.len()
    }

    pub fn queued(&self) -> usize {
        self.router.queued()
    }

    /// Matched prefix usable for reuse: capped so at least the prompt's
    /// last token still runs through prefill (its logits row seeds the
    /// first generated token), floored to whole blocks.
    fn usable_prefix(&self, prompt_len: usize, matched: usize) -> usize {
        let bt = self.cfg.kv_block_tokens;
        (matched.min(prompt_len.saturating_sub(1)) / bt) * bt
    }

    /// Radix-tree lookup for a prompt, truncated to the usable range.
    fn lookup_prefix(&mut self, prompt: &[u32]) -> PrefixMatch {
        if !self.cfg.prefix_cache {
            return PrefixMatch::default();
        }
        let m = self.prefix.match_prefix(prompt);
        let usable = self.usable_prefix(prompt.len(), m.tokens);
        if usable == 0 {
            return PrefixMatch::default();
        }
        PrefixMatch {
            blocks: m.blocks[..usable / self.cfg.kv_block_tokens].to_vec(),
            tokens: usable,
        }
    }

    /// Admit a sequence's KV: prefix attach first, then eviction of the
    /// uncached shortfall + retry, then — with nothing running to wait
    /// for — a cold allocation with the cache fully evictable. Returns
    /// the attached match, `Ok(None)` when admission should wait for
    /// decode to free blocks, or `Err` when truly stuck.
    ///
    /// Attach-before-evict ordering matters throughout: matched blocks
    /// are refcount-1 (tree-only) until the alloc increfs them, so
    /// eviction must never run between a successful match and its
    /// attach; every eviction below is followed by a *fresh* match.
    fn admit_kv(&mut self, id: SeqId, prompt: &[u32]) -> Result<Option<PrefixMatch>> {
        let len = prompt.len();
        let need = (len + 1).div_ceil(self.cfg.kv_block_tokens);
        let matched = self.lookup_prefix(prompt);
        if self
            .kv
            .alloc_seq_with_prefix(id, len + 1, &matched.blocks, matched.tokens)
            .is_ok()
        {
            return Ok(Some(matched));
        }
        // Only the *uncached* shortfall needs reclaiming: matched blocks
        // attach by incref, they are not allocated.
        let want = need
            .saturating_sub(matched.blocks.len())
            .saturating_sub(self.kv.free_blocks());
        let freed = self.prefix.evict(want, &mut self.kv);
        self.metrics.prefix_blocks_evicted += freed as u64;
        let matched = self.lookup_prefix(prompt);
        if self
            .kv
            .alloc_seq_with_prefix(id, len + 1, &matched.blocks, matched.tokens)
            .is_ok()
        {
            return Ok(Some(matched));
        }
        if !self.batcher.is_empty() {
            return Ok(None);
        }
        // Nothing running will ever free blocks: drop every cache claim
        // and admit cold (or surface the allocator's error).
        let freed = self.prefix.evict(need, &mut self.kv);
        self.metrics.prefix_blocks_evicted += freed as u64;
        self.kv.alloc_seq(id, len + 1)?;
        Ok(Some(PrefixMatch::default()))
    }

    /// Blocks the next queued prefill needs and how many are cached
    /// (a peek: no LRU touch, no attach).
    fn admission_outlook(&self) -> (usize, usize) {
        match self.router.queue.front() {
            Some(s) => {
                let bt = self.cfg.kv_block_tokens;
                let need = (s.prompt.len() + 1).div_ceil(bt);
                let cached = if self.cfg.prefix_cache {
                    let matched = self.prefix.peek_match_tokens(&s.prompt);
                    self.usable_prefix(s.prompt.len(), matched) / bt
                } else {
                    0
                };
                (need, cached)
            }
            None => (0, 0),
        }
    }

    /// Run one scheduling iteration. Returns the action taken.
    pub fn step(&mut self) -> Result<Action> {
        let (next_blocks, mut cached_blocks) = self.admission_outlook();
        // Under admission pressure, reclaim cached (refcount-1) blocks
        // before the policy sees the free count — but only when
        // admission is actually possible (a full running set gets
        // nothing from eviction), and only after refreshing the head
        // request's matched path in the LRU so eviction prefers other
        // entries over the prefix about to be reused.
        let uncached = next_blocks.saturating_sub(cached_blocks);
        let admission_possible = next_blocks > 0 && self.batcher.len() < self.cfg.max_running;
        if admission_possible && self.kv.free_blocks() < uncached {
            if let Some(prompt) = self.router.queue.front().map(|s| s.prompt.clone()) {
                let _ = self.prefix.match_prefix(&prompt);
            }
            let want = uncached - self.kv.free_blocks();
            let freed = self.prefix.evict(want, &mut self.kv);
            self.metrics.prefix_blocks_evicted += freed as u64;
            if freed > 0 {
                // Eviction may still have trimmed blocks the peek
                // counted as cached — re-peek so the policy decides on
                // live state.
                cached_blocks = self.admission_outlook().1;
            }
        }
        let action = decide(SchedState {
            queued: self.router.queued(),
            running: self.batcher.len(),
            max_running: self.cfg.max_running,
            free_blocks: self.kv.free_blocks(),
            next_prefill_blocks: next_blocks,
            cached_prefill_blocks: cached_blocks,
        });
        match action {
            Action::Prefill => self.step_prefill()?,
            Action::Decode => self.step_decode()?,
            Action::Idle => {}
        }
        Ok(action)
    }

    /// Run until all submitted work is finished (batch/offline mode).
    pub fn run_to_completion(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Prefill
    // -----------------------------------------------------------------

    fn step_prefill(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let mut seq = match self.router.pop_next() {
            Some(s) => s,
            None => return Ok(()),
        };
        let len = seq.prompt.len();
        let bucket = match pick_prefill_bucket(&self.cfg.prefill_buckets, len) {
            Some(b) => b,
            None => {
                seq.emit(TokenEvent::Finished {
                    reason: FinishReason::Error,
                    n_generated: 0,
                });
                return Err(Error::Request(format!("prompt {len} exceeds prefill buckets")));
            }
        };
        // Prefix-cache lookup + KV admission (+1 for the first generated
        // token). (The fixed-shape prefill artifact still runs over the
        // whole padded prompt — compute skipping needs suffix-shaped
        // artifacts — but the matched blocks are shared, not
        // re-allocated, and the accounting below drives the cache-aware
        // scheduler.)
        let matched = match self.admit_kv(seq.id, &seq.prompt) {
            Ok(Some(m)) => m,
            Ok(None) => {
                // No room yet: requeue and let decode drain blocks.
                self.router.requeue_front(seq);
                return self.step_decode();
            }
            Err(e) => {
                // Truly stuck — surface it.
                self.router.requeue_front(seq);
                return Err(e);
            }
        };
        if self.cfg.prefix_cache {
            self.metrics.prefix_lookups += 1;
            if matched.tokens > 0 {
                self.metrics.prefix_hits += 1;
            }
        }
        self.metrics.prefix_tokens_reused += matched.tokens as u64;
        self.metrics.prefill_tokens_computed += (len - matched.tokens) as u64;

        // Pad prompt to the bucket.
        let mut toks: Vec<i32> = seq.prompt.iter().map(|&t| t as i32).collect();
        toks.resize(bucket, 0);
        let tokens_lit = literal_i32(&toks, &[1, bucket])?;
        let entry = Manifest::prefill_entry_name(bucket);
        let exec_t0 = Instant::now();
        let outs = self.rt.execute(&entry, &[&tokens_lit])?;
        let mut exec_dt = exec_t0.elapsed();
        let [logits, k, v]: [xla::Literal; 3] = outs
            .try_into()
            .map_err(|_| Error::Artifact("prefill must return 3 outputs".into()))?;

        // Persist KV to the paged backing store (needed for rebuilds and
        // preemption; off the per-decode-step path). Positions covered
        // by the attached prefix are already resident and shared — only
        // the uncached suffix is written.
        let k_host = to_vec_f32(&k)?;
        let v_host = to_vec_f32(&v)?;
        self.kv
            .write_prefill_range(seq.id, &k_host, &v_host, bucket, matched.tokens, len)?;
        seq.kv_len = len;

        // First token from the logits row of the last real position.
        let logits_host = to_vec_f32(&logits)?;
        let row = &logits_host[(len - 1) * self.vocab..len * self.vocab];
        let tok = self.sampler.sample(row, seq.params);
        seq.generated.push(tok);
        seq.first_token_at = Some(Instant::now());
        self.metrics.first_token.record(seq.arrived.elapsed());
        seq.emit(TokenEvent::Token(tok));
        self.metrics.tokens_generated += 1;
        self.metrics.requests_admitted += 1;

        if self.tokenizer.is_eos(tok) || seq.max_new_tokens <= 1 {
            let reason = if self.tokenizer.is_eos(tok) {
                FinishReason::Eos
            } else {
                FinishReason::MaxTokens
            };
            self.finish_seq(&mut seq, reason)?;
        } else {
            seq.state = SeqState::Decoding;
            let admission = self.batcher.admit(seq.id)?;
            if admission.bucket_grew {
                // Bucket changed: the dense tensor shape no longer fits.
                // Persist and drop; the next decode step rebuilds.
                self.invalidate_dense()?;
            } else if let Some(mut dense) = self.dense.take() {
                // Fast path: splice this sequence's KV into the running
                // dense cache on device (no host round trip).
                let ins_entry = format!("insert_b{}_s{}", dense.bucket, bucket);
                let lane_lit = literal_i32(&[admission.lane as i32], &[1])?;
                let ins_t0 = Instant::now();
                let mut outs = self
                    .rt
                    .execute(&ins_entry, &[&dense.k, &dense.v, &k, &v, &lane_lit])?;
                exec_dt += ins_t0.elapsed();
                if outs.len() != 2 {
                    return Err(Error::Artifact(format!(
                        "{ins_entry}: expected 2 outputs, got {}",
                        outs.len()
                    )));
                }
                dense.v = outs.pop().unwrap();
                dense.k = outs.pop().unwrap();
                dense.lanes[admission.lane] = Some(seq.id);
                self.dense = Some(dense);
                self.metrics.kv_inserts += 1;
            }
            self.seqs.insert(seq.id, seq);
        }
        self.metrics.prefill_steps += 1;
        let dt = t0.elapsed();
        self.metrics.step.record(dt);
        self.metrics.step_overhead.record(dt.saturating_sub(exec_dt));
        Ok(())
    }

    // -----------------------------------------------------------------
    // Decode
    // -----------------------------------------------------------------

    fn step_decode(&mut self) -> Result<()> {
        let t0 = Instant::now();
        // KV headroom: each running sequence may need one fresh block.
        // Reclaim cached prefix blocks first (even for a lone sequence —
        // tree-held blocks are reclaimable memory); preempt only as a
        // last resort, which needs at least two running sequences.
        while self.kv.free_blocks() < self.batcher.len() {
            let want = self.batcher.len() - self.kv.free_blocks();
            let freed = self.prefix.evict(want, &mut self.kv);
            self.metrics.prefix_blocks_evicted += freed as u64;
            if self.kv.free_blocks() >= self.batcher.len() || self.batcher.len() <= 1 {
                break;
            }
            self.preempt_one()?;
        }
        let batch = self.batcher.assemble()?;
        let bucket = batch.bucket;
        let geo = self.kv.geometry();

        let stale = match &self.dense {
            None => true,
            Some(d) => d.bucket != bucket || d.lanes != batch.lanes,
        };
        if stale {
            self.rebuild_dense(&batch.lanes, bucket)?;
            self.metrics.kv_rebuilds += 1;
        }

        // Assemble token/pos lanes (holes: token 0, pos 0).
        let mut toks = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        for (i, slot) in batch.lanes.iter().enumerate() {
            if let Some(id) = slot {
                let s = &self.seqs[id];
                toks[i] = s.last_token() as i32;
                pos[i] = s.kv_len as i32;
            }
        }
        let toks_lit = literal_i32(&toks, &[bucket])?;
        let pos_lit = literal_i32(&pos, &[bucket])?;

        let entry = Manifest::decode_entry_name(bucket, !self.cfg.async_softmax);
        let exec_t0 = Instant::now();
        let outs = {
            let d = self.dense.take().expect("dense state after rebuild");
            let r = self.rt.execute(&entry, &[&toks_lit, &pos_lit, &d.k, &d.v]);
            self.dense = Some(d);
            r?
        };
        let exec_dt = exec_t0.elapsed();
        let mut outs = outs;
        if outs.len() != 4 {
            return Err(Error::Artifact(format!(
                "decode entry returned {} outputs, want 4",
                outs.len()
            )));
        }
        let flags = outs.pop().unwrap();
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();

        // The updated caches become the new device state.
        self.dense = Some(DenseState {
            bucket,
            lanes: batch.lanes.clone(),
            k: k_new,
            v: v_new,
        });

        let logits_host = to_vec_f32(&logits)?;
        let flags_host = to_vec_f32(&flags)?;
        let mut finished: Vec<SeqId> = Vec::new();
        for (i, slot) in batch.lanes.iter().enumerate() {
            let Some(id) = slot else { continue };
            let seq = self.seqs.get_mut(id).unwrap();
            let row = &logits_host[i * self.vocab..(i + 1) * self.vocab];
            let tok = self.sampler.sample(row, seq.params);
            self.kv.grow_one(*id)?;
            seq.kv_len += 1;
            seq.generated.push(tok);
            seq.emit(TokenEvent::Token(tok));
            self.metrics.tokens_generated += 1;
            self.metrics.decode_rows += 1;
            if flags_host[i] > 0.5 {
                self.metrics.recompute_rows += 1;
            }
            let done_eos = tok == EOS;
            let done_len =
                seq.generated.len() >= seq.max_new_tokens || seq.kv_len + 1 >= geo.max_seq;
            if done_eos || done_len {
                finished.push(*id);
            }
        }
        // Retire finished sequences (their lanes become holes; the dense
        // tensor stays valid — holes are masked by pos/kv_len).
        for id in finished {
            let mut seq = self.seqs.remove(&id).unwrap();
            let reason = if seq.generated.last() == Some(&EOS) {
                FinishReason::Eos
            } else {
                FinishReason::MaxTokens
            };
            self.retire(&mut seq, reason)?;
        }
        self.metrics.decode_steps += 1;
        let dt = t0.elapsed();
        self.metrics.step.record(dt);
        self.metrics.step_overhead.record(dt.saturating_sub(exec_dt));
        let lanes = batch.occupancy().max(1) as u32;
        self.metrics.per_token.record(dt / lanes);
        Ok(())
    }

    /// Remove a sequence from the running set, keeping the dense state
    /// consistent (hole without shrink; invalidate on shrink).
    fn retire(&mut self, seq: &mut Sequence, reason: FinishReason) -> Result<()> {
        let shrank = self.batcher.remove(seq.id)?;
        if shrank {
            self.invalidate_dense()?;
        } else if let Some(d) = self.dense.as_mut() {
            for slot in d.lanes.iter_mut() {
                if *slot == Some(seq.id) {
                    *slot = None;
                }
            }
        }
        self.finish_seq(seq, reason)
    }

    /// Persist the device cache into the paged store and drop it.
    fn invalidate_dense(&mut self) -> Result<()> {
        if let Some(prev) = self.dense.take() {
            // Only still-allocated lanes are written back.
            let lanes: Vec<Option<SeqId>> = prev
                .lanes
                .iter()
                .map(|slot| slot.filter(|id| self.kv.contains(*id)))
                .collect();
            if lanes.iter().any(Option::is_some) {
                let k_host = to_vec_f32(&prev.k)?;
                let v_host = to_vec_f32(&prev.v)?;
                self.kv.scatter_dense(&lanes, prev.bucket, &k_host, &v_host)?;
            }
        }
        Ok(())
    }

    /// Rebuild the dense device KV from the paged store for a new batch
    /// composition, first persisting the previous composition's state.
    fn rebuild_dense(&mut self, lanes: &[Option<SeqId>], bucket: usize) -> Result<()> {
        self.invalidate_dense()?;
        let geo = self.kv.geometry();
        let n = geo.dense_elems(bucket);
        let mut k_host = vec![0.0f32; n];
        let mut v_host = vec![0.0f32; n];
        self.kv.gather_dense(lanes, bucket, &mut k_host, &mut v_host)?;
        let shape = [geo.n_layers, bucket, geo.n_heads, geo.max_seq, geo.head_dim];
        self.dense = Some(DenseState {
            bucket,
            lanes: lanes.to_vec(),
            k: literal_f32(&k_host, &shape)?,
            v: literal_f32(&v_host, &shape)?,
        });
        Ok(())
    }

    /// Preempt one running sequence (KV pressure): the scheduler picks
    /// the victim *by id* — preferring sequences whose blocks stay
    /// reusable (shared with the prefix cache or other sequences), ties
    /// to the youngest — and the engine resolves id -> lane.
    fn preempt_one(&mut self) -> Result<()> {
        let candidates: Vec<PreemptCandidate> = self
            .batcher
            .running_ids()
            .into_iter()
            .map(|id| {
                let reusable = self
                    .kv
                    .seq_blocks(id)
                    .map(|bs| {
                        bs.iter()
                            .filter(|&&b| self.kv.block_refcount(b) > 1)
                            .count()
                    })
                    .unwrap_or(0);
                PreemptCandidate {
                    id,
                    reusable_blocks: reusable,
                }
            })
            .collect();
        let id = preemption_victim(&candidates)
            .ok_or_else(|| Error::Schedule("no preemption victim".into()))?;
        let mut seq = self.seqs.remove(&id).unwrap();
        self.metrics.preemptions += 1;
        self.retire(&mut seq, FinishReason::Preempted)
    }

    /// Register a finished/preempted sequence's *prompt* KV in the
    /// prefix cache. Only the prompt's full blocks are registered: they
    /// were written at prefill and are valid in the paged store, while
    /// generated-token KV may still be device-resident (scattered back
    /// only on a dense rebuild) and must not be published.
    fn register_prefix(&mut self, seq: &Sequence) {
        if !self.cfg.prefix_cache || !self.kv.contains(seq.id) {
            return;
        }
        let Some(blocks) = self.kv.seq_blocks(seq.id) else {
            return;
        };
        self.prefix.insert(&seq.prompt, &blocks, &mut self.kv);
    }

    fn finish_seq(&mut self, seq: &mut Sequence, reason: FinishReason) -> Result<()> {
        seq.state = SeqState::Finished(reason);
        seq.emit(TokenEvent::Finished {
            reason,
            n_generated: seq.generated.len(),
        });
        self.register_prefix(seq);
        if self.kv.contains(seq.id) {
            self.kv.free_seq(seq.id)?;
        }
        self.metrics.requests_finished += 1;
        Ok(())
    }

    /// Offline helper: generate `max_new_tokens` for one prompt, blocking.
    pub fn generate_text(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        params: SamplingParams,
    ) -> Result<String> {
        let (_, rx) = self.submit_text(prompt, max_new_tokens, params)?;
        self.run_to_completion()?;
        let mut out = Vec::new();
        while let Ok(ev) = rx.try_recv() {
            if let TokenEvent::Token(t) = ev {
                out.push(t);
            }
        }
        Ok(self.tokenizer.decode(&out))
    }
}
