//! The PJRT compute backend behind the production serving engine.
//!
//! [`Engine`] is [`crate::core::EngineCore`] over [`PjrtBackend`]: the
//! entire serving loop — scheduling, admission, flow control,
//! preemption, tracing, audit — lives in the shared core, and this
//! module supplies only what is PJRT-specific: executing the compiled
//! prefill/decode artifacts, and keeping the device-resident dense KV
//! tensors consistent with the batch composition.
//!
//! Because the orchestration is the shared core, the real engine now
//! exposes the same `enable_trace` / `take_trace` / `audit()` surface
//! as the deterministic sim twin — production debugging sees exactly
//! what the simulation-test oracles see.
//!
//! KV residency (perf pass, EXPERIMENTS.md §Perf): the dense KV tensors
//! persist on device across decode steps. Lanes are sticky, so a newly
//! prefilled sequence is spliced into the running batch *on device* via
//! the `insert_b{B}_s{S}` artifact — no host round trip. Only bucket
//! growth/shrink forces a host-side rebuild through the paged store.

use std::collections::HashMap;
use std::time::Duration;

use crate::batching::{pick_prefill_bucket, Admission, DecodeBatch};
use crate::config::EngineConfig;
use crate::core::{Backend, DecodeRun, EngineCore, LaneInput, PrefillRun};
use crate::error::{Error, Result};
use crate::kvcache::{KvCache, KvGeometry, SeqId};
use crate::metrics::EngineMetrics;
use crate::router::Sequence;
use crate::runtime::{literal_f32, literal_i32, to_vec_f32, Manifest, Runtime};
use crate::util::clock::Clock;

/// Device-resident dense KV state for the current batch composition.
struct DenseState {
    bucket: usize,
    /// Mirrors the batcher's sticky lanes at the time of the last sync.
    lanes: Vec<Option<SeqId>>,
    k: xla::Literal,
    v: xla::Literal,
}

/// The PJRT compute backend: compiled artifacts in, logits out, with a
/// device-resident dense KV cache synchronized against the paged store
/// through the core's batch-membership hooks.
pub struct PjrtBackend {
    pub rt: Runtime,
    dense: Option<DenseState>,
    vocab: usize,
}

impl PjrtBackend {
    pub fn new(rt: Runtime) -> Self {
        let vocab = rt.manifest.model.vocab_size;
        PjrtBackend {
            rt,
            dense: None,
            vocab,
        }
    }

    /// Persist the device cache into the paged store and drop it.
    fn invalidate_dense(&mut self, kv: &mut KvCache) -> Result<()> {
        if let Some(prev) = self.dense.take() {
            // Only still-allocated lanes are written back.
            let lanes: Vec<Option<SeqId>> = prev
                .lanes
                .iter()
                .map(|slot| slot.filter(|id| kv.contains(*id)))
                .collect();
            if lanes.iter().any(Option::is_some) {
                let k_host = to_vec_f32(&prev.k)?;
                let v_host = to_vec_f32(&prev.v)?;
                kv.scatter_dense(&lanes, prev.bucket, &k_host, &v_host)?;
            }
        }
        Ok(())
    }

    /// Rebuild the dense device KV from the paged store for a new batch
    /// composition, first persisting the previous composition's state.
    fn rebuild_dense(
        &mut self,
        kv: &mut KvCache,
        lanes: &[Option<SeqId>],
        bucket: usize,
    ) -> Result<()> {
        self.invalidate_dense(kv)?;
        let geo = kv.geometry();
        let n = geo.dense_elems(bucket);
        let mut k_host = vec![0.0f32; n];
        let mut v_host = vec![0.0f32; n];
        kv.gather_dense(lanes, bucket, &mut k_host, &mut v_host)?;
        let shape = [geo.n_layers, bucket, geo.n_heads, geo.max_seq, geo.head_dim];
        self.dense = Some(DenseState {
            bucket,
            lanes: lanes.to_vec(),
            k: literal_f32(&k_host, &shape)?,
            v: literal_f32(&v_host, &shape)?,
        });
        Ok(())
    }
}

impl Backend for PjrtBackend {
    /// Device K/V literals from prefill plus the prefill bucket, carried
    /// to the sticky-lane splice when the sequence joins the batch.
    type PrefillArtifact = (xla::Literal, xla::Literal, usize);

    fn geometry(&self, cfg: &EngineConfig) -> KvGeometry {
        let m = &self.rt.manifest.model;
        KvGeometry {
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.head_dim,
            block_tokens: cfg.kv_block_tokens,
            max_seq: m.max_seq,
        }
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    /// The prompt must fit the largest compiled prefill bucket.
    fn validate_prompt(&self, cfg: &EngineConfig, prompt_len: usize) -> Result<()> {
        let max_prefill = *cfg.prefill_buckets.last().unwrap();
        if prompt_len > max_prefill {
            return Err(Error::Request(format!(
                "prompt of {prompt_len} tokens exceeds the largest prefill bucket {max_prefill}"
            )));
        }
        Ok(())
    }

    /// Run the bucketed prefill executable and persist KV to the paged
    /// backing store (needed for rebuilds and preemption; off the
    /// per-decode-step path). Positions covered by the attached prefix
    /// are already resident and shared — only the uncached suffix is
    /// written. (The fixed-shape prefill artifact still runs over the
    /// whole padded prompt — compute skipping needs suffix-shaped
    /// artifacts — but the matched blocks are shared, not re-allocated.)
    fn prefill(
        &mut self,
        cfg: &EngineConfig,
        kv: &mut KvCache,
        seq: &Sequence,
        matched_tokens: usize,
        clock: &Clock,
    ) -> Result<PrefillRun<Self::PrefillArtifact>> {
        let len = seq.prompt.len();
        // Unreachable for requests that passed submit validation
        // (validate_prompt caps at the largest bucket); on a miss the
        // returned error makes the core fail the request through its
        // finish path — backends never emit stream events themselves.
        let bucket = pick_prefill_bucket(&cfg.prefill_buckets, len)
            .ok_or_else(|| Error::Request(format!("prompt {len} exceeds prefill buckets")))?;
        // Pad prompt to the bucket.
        let mut toks: Vec<i32> = seq.prompt.iter().map(|&t| t as i32).collect();
        toks.resize(bucket, 0);
        let tokens_lit = literal_i32(&toks, &[1, bucket])?;
        let entry = Manifest::prefill_entry_name(bucket);
        let exec_t0 = clock.now();
        let outs = self.rt.execute(&entry, &[&tokens_lit])?;
        let exec_time = clock.now().saturating_sub(exec_t0);
        let [logits, k, v]: [xla::Literal; 3] = outs
            .try_into()
            .map_err(|_| Error::Artifact("prefill must return 3 outputs".into()))?;

        let k_host = to_vec_f32(&k)?;
        let v_host = to_vec_f32(&v)?;
        kv.write_prefill_range(seq.id, &k_host, &v_host, bucket, matched_tokens, len)?;

        // The logits row of the last real position seeds the first
        // generated token.
        let logits_host = to_vec_f32(&logits)?;
        let last_logits = logits_host[(len - 1) * self.vocab..len * self.vocab].to_vec();
        Ok(PrefillRun {
            last_logits,
            exec_time,
            artifact: (k, v, bucket),
        })
    }

    /// Fast path: splice the new sequence's KV into the running dense
    /// cache on device (no host round trip). Bucket growth invalidates
    /// the dense state instead; the next decode step rebuilds it.
    fn on_batch_join(
        &mut self,
        kv: &mut KvCache,
        metrics: &mut EngineMetrics,
        id: SeqId,
        admission: Admission,
        artifact: Self::PrefillArtifact,
        clock: &Clock,
    ) -> Result<Duration> {
        let (k, v, bucket) = artifact;
        if admission.bucket_grew {
            // Bucket changed: the dense tensor shape no longer fits.
            // Persist and drop; the next decode step rebuilds.
            self.invalidate_dense(kv)?;
            return Ok(Duration::ZERO);
        }
        if let Some(mut dense) = self.dense.take() {
            let ins_entry = format!("insert_b{}_s{}", dense.bucket, bucket);
            let lane_lit = literal_i32(&[admission.lane as i32], &[1])?;
            let ins_t0 = clock.now();
            let mut outs = self
                .rt
                .execute(&ins_entry, &[&dense.k, &dense.v, &k, &v, &lane_lit])?;
            let ins_dt = clock.now().saturating_sub(ins_t0);
            if outs.len() != 2 {
                return Err(Error::Artifact(format!(
                    "{ins_entry}: expected 2 outputs, got {}",
                    outs.len()
                )));
            }
            dense.v = outs.pop().unwrap();
            dense.k = outs.pop().unwrap();
            dense.lanes[admission.lane] = Some(id);
            self.dense = Some(dense);
            metrics.kv_inserts += 1;
            return Ok(ins_dt);
        }
        Ok(Duration::ZERO)
    }

    /// One bucketed decode step: rebuild the dense cache if the batch
    /// composition changed, execute, adopt the updated device caches,
    /// and grow each occupied lane's paged bookkeeping by one token.
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        cfg: &EngineConfig,
        kv: &mut KvCache,
        _seqs: &HashMap<SeqId, Sequence>,
        batch: &DecodeBatch,
        inputs: &[LaneInput],
        metrics: &mut EngineMetrics,
        clock: &Clock,
    ) -> Result<DecodeRun> {
        let bucket = batch.bucket;
        let stale = match &self.dense {
            None => true,
            Some(d) => d.bucket != bucket || d.lanes != batch.lanes,
        };
        if stale {
            self.rebuild_dense(kv, &batch.lanes, bucket)?;
            metrics.kv_rebuilds += 1;
        }

        // Assemble token/pos lanes (holes: token 0, pos 0).
        let mut toks = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        for inp in inputs {
            toks[inp.lane] = inp.token as i32;
            pos[inp.lane] = inp.pos as i32;
        }
        let toks_lit = literal_i32(&toks, &[bucket])?;
        let pos_lit = literal_i32(&pos, &[bucket])?;

        let entry = Manifest::decode_entry_name(bucket, !cfg.async_softmax);
        let exec_t0 = clock.now();
        let outs = {
            let d = self.dense.take().expect("dense state after rebuild");
            let r = self.rt.execute(&entry, &[&toks_lit, &pos_lit, &d.k, &d.v]);
            self.dense = Some(d);
            r?
        };
        let exec_time = clock.now().saturating_sub(exec_t0);
        let mut outs = outs;
        if outs.len() != 4 {
            return Err(Error::Artifact(format!(
                "decode entry returned {} outputs, want 4",
                outs.len()
            )));
        }
        let flags = outs.pop().unwrap();
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();

        // The updated caches become the new device state.
        self.dense = Some(DenseState {
            bucket,
            lanes: batch.lanes.clone(),
            k: k_new,
            v: v_new,
        });

        let logits_host = to_vec_f32(&logits)?;
        let flags_host = to_vec_f32(&flags)?;
        let mut offsets = Vec::with_capacity(inputs.len());
        for inp in inputs {
            kv.grow_one(inp.id)?;
            offsets.push(inp.lane * self.vocab);
            if flags_host[inp.lane] > 0.5 {
                metrics.recompute_rows += 1;
            }
        }
        // The host logits tensor is handed over whole: each lane's row
        // is a view, no per-lane copy on the decode hot path.
        Ok(DecodeRun {
            logits: logits_host,
            offsets,
            row_len: self.vocab,
            exec_time,
        })
    }

    /// A retired lane becomes a hole (dense tensor stays valid — holes
    /// are masked by pos/kv_len); a bucket shrink invalidates.
    fn on_batch_leave(&mut self, kv: &mut KvCache, id: SeqId, shrank: bool) -> Result<()> {
        if shrank {
            return self.invalidate_dense(kv);
        }
        if let Some(d) = self.dense.as_mut() {
            for slot in d.lanes.iter_mut() {
                if *slot == Some(id) {
                    *slot = None;
                }
            }
        }
        Ok(())
    }

    /// A parked sequence will continue later (unlike a retirement), so
    /// its device-resident KV is persisted into the paged store before
    /// its lane is released; the next decode step rebuilds the dense
    /// cache for the smaller batch.
    fn on_pause(&mut self, kv: &mut KvCache) -> Result<()> {
        self.invalidate_dense(kv)
    }

    /// A resumed sequence's KV lives in the paged store (persisted at
    /// pause); bucket growth invalidates the dense state, and otherwise
    /// the lane mismatch makes the next decode step rebuild it.
    fn on_resume(&mut self, kv: &mut KvCache, admission: &Admission) -> Result<()> {
        if admission.bucket_grew {
            self.invalidate_dense(kv)?;
        }
        Ok(())
    }

    /// Only the prompt's blocks are publishable: they were written at
    /// prefill and are valid in the paged store, while generated-token
    /// KV may still be device-resident (scattered back only on a dense
    /// rebuild) and must not be published.
    fn publishable_tokens(&self, _kv: &KvCache, seq: &Sequence) -> Vec<u32> {
        seq.prompt.clone()
    }
}

/// The production engine: the shared serving core over the PJRT
/// backend. Owns all sequence state; not Send — run it on a dedicated
/// thread and talk to it via [`crate::server::EngineJob`] channels.
pub type Engine = EngineCore<PjrtBackend>;

impl EngineCore<PjrtBackend> {
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Result<Self> {
        EngineCore::with_backend(PjrtBackend::new(rt), cfg, Clock::system())
    }

    /// Pre-compile the executables the serving loop will need (moves the
    /// compile cost out of the first request's latency).
    pub fn warmup(&mut self) -> Result<()> {
        for &b in &self.cfg.decode_buckets.clone() {
            self.backend
                .rt
                .ensure_compiled(&Manifest::decode_entry_name(b, !self.cfg.async_softmax))?;
        }
        for &s in &self.cfg.prefill_buckets.clone() {
            self.backend
                .rt
                .ensure_compiled(&Manifest::prefill_entry_name(s))?;
        }
        Ok(())
    }
}
