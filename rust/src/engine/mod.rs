//! The serving engine: single-owner hot loop tying together the PJRT
//! runtime, paged KV cache, continuous batcher, scheduler and sampler.
//!
//! Per iteration: the scheduler picks prefill-vs-decode; prefill runs a
//! single sequence through a bucketed prefill executable and admits it
//! into the running set; decode assembles the bucketed batch, executes
//! one step for every running sequence, samples, streams tokens, and
//! retires finished sequences.
//!
//! The public surface is [`crate::api::InferenceEngine`] — typed
//! [`GenRequest`] in, [`GenEvent`] stream out — and the admission /
//! eviction / preemption logic is the shared [`crate::policy`] module,
//! both of which [`crate::simengine::SimEngine`] mirrors exactly.
//!
//! KV residency (perf pass, EXPERIMENTS.md §Perf): the dense KV tensors
//! persist on device across decode steps. Lanes are sticky, so a newly
//! prefilled sequence is spliced into the running batch *on device* via
//! the `insert_b{B}_s{S}` artifact — no host round trip. Only bucket
//! growth/shrink forces a host-side rebuild through the paged store.

use std::collections::HashMap;

use crate::api::{FinishReason, GenRequest, InferenceEngine, RequestId, SubmissionHandle, Wakeup};
use crate::batching::{pick_prefill_bucket, Batcher};
use crate::config::EngineConfig;
use crate::error::{Error, Result};
use crate::kvcache::{KvCache, KvGeometry, SeqId};
use crate::metrics::EngineMetrics;
use crate::policy::{self, StreamOp};
use crate::prefixcache::PrefixCache;
use crate::router::{self, Router, SeqState, Sequence, SubmitContext};
use crate::runtime::{literal_f32, literal_i32, to_vec_f32, Manifest, Runtime};
use crate::sampling::Sampler;
use crate::scheduler::{decide, preemption_victim, Action};
use crate::tokenizer::{ByteTokenizer, EOS};
use crate::util::clock::Clock;

/// Device-resident dense KV state for the current batch composition.
struct DenseState {
    bucket: usize,
    /// Mirrors the batcher's sticky lanes at the time of the last sync.
    lanes: Vec<Option<SeqId>>,
    k: xla::Literal,
    v: xla::Literal,
}

/// The engine. Owns all sequence state; not Send — run it on a
/// dedicated thread and talk to it via [`crate::server::EngineJob`]
/// channels.
pub struct Engine {
    pub rt: Runtime,
    pub cfg: EngineConfig,
    kv: KvCache,
    prefix: PrefixCache,
    batcher: Batcher,
    router: Router,
    sampler: Sampler,
    seqs: HashMap<SeqId, Sequence>,
    /// Sequences parked by stream backpressure: they stay in `seqs`
    /// (state `Paused`) and keep their KV in the paged store, but hold
    /// no decode lane (their device-resident KV is persisted on pause).
    paused: Vec<SeqId>,
    dense: Option<DenseState>,
    /// Engine time source (system clock in production; everything on
    /// the request path reads time through it, never `Instant::now()`).
    clock: Clock,
    /// Engine-loop wakeup each new stream notifies on client drains.
    wakeup: Option<Wakeup>,
    pub metrics: EngineMetrics,
    pub tokenizer: ByteTokenizer,
    vocab: usize,
}

impl Engine {
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let m = &rt.manifest.model;
        let geo = KvGeometry {
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.head_dim,
            block_tokens: cfg.kv_block_tokens,
            max_seq: m.max_seq,
        };
        let kv = KvCache::new(geo, cfg.kv_total_blocks);
        let tokenizer = ByteTokenizer::new(m.vocab_size);
        let vocab = m.vocab_size;
        Ok(Engine {
            prefix: PrefixCache::new(cfg.kv_block_tokens),
            batcher: Batcher::new(cfg.decode_buckets.clone()),
            sampler: Sampler::new(cfg.seed),
            router: Router::new(),
            seqs: HashMap::new(),
            paused: Vec::new(),
            dense: None,
            clock: Clock::system(),
            wakeup: None,
            metrics: EngineMetrics::default(),
            kv,
            rt,
            cfg,
            tokenizer,
            vocab,
        })
    }

    /// Pre-compile the executables the serving loop will need (moves the
    /// compile cost out of the first request's latency).
    pub fn warmup(&mut self) -> Result<()> {
        for &b in &self.cfg.decode_buckets.clone() {
            self.rt
                .ensure_compiled(&Manifest::decode_entry_name(b, !self.cfg.async_softmax))?;
        }
        for &s in &self.cfg.prefill_buckets.clone() {
            self.rt.ensure_compiled(&Manifest::prefill_entry_name(s))?;
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Prefill
    // -----------------------------------------------------------------

    fn step_prefill(&mut self) -> Result<()> {
        let t0 = self.clock.now();
        let mut seq = match self.router.pop_next() {
            Some(s) => s,
            None => return Ok(()),
        };
        let len = seq.prompt.len();
        let bucket = match pick_prefill_bucket(&self.cfg.prefill_buckets, len) {
            Some(b) => b,
            None => {
                seq.emit_finish(FinishReason::Error, seq.usage());
                return Err(Error::Request(format!("prompt {len} exceeds prefill buckets")));
            }
        };
        // Prefix-cache lookup + KV admission (+1 for the first generated
        // token). (The fixed-shape prefill artifact still runs over the
        // whole padded prompt — compute skipping needs suffix-shaped
        // artifacts — but the matched blocks are shared, not
        // re-allocated, and the accounting below drives the cache-aware
        // scheduler.)
        // Paused sequences count as pending work: their blocks return
        // when they resume or finish, so admission must wait for them
        // rather than fail the request.
        let matched = match policy::admit_kv(
            &self.cfg,
            &mut self.kv,
            &mut self.prefix,
            &mut self.metrics,
            self.batcher.is_empty() && self.paused.is_empty(),
            seq.id,
            &seq.prompt,
        ) {
            Ok(Some(m)) => m,
            Ok(None) => {
                // No room yet: requeue and let decode drain blocks. If
                // nothing is decoding, the holders are parked on
                // backpressure and decode will never free blocks —
                // preempt a strictly lower-priority parked victim so a
                // high-priority waiter is not starved by a stalled
                // client.
                if self.batcher.is_empty() {
                    if let Some(victim) = policy::admission_relief_victim(
                        &self.kv,
                        &self.seqs,
                        &self.paused,
                        seq.priority,
                    ) {
                        self.paused.retain(|&p| p != victim);
                        let mut vseq = self.seqs.remove(&victim).unwrap();
                        self.metrics.preemptions += 1;
                        self.finish_seq(&mut vseq, FinishReason::Preempted)?;
                    }
                }
                self.router.requeue_front(seq);
                return self.step_decode();
            }
            Err(_) => {
                // Truly stuck: nothing is running and eviction is
                // exhausted, so this request can never be admitted.
                // Fail it (surfaced on its stream) instead of wedging
                // the queue head forever.
                self.finish_seq(&mut seq, FinishReason::Error)?;
                return Ok(());
            }
        };
        policy::note_admission(&self.cfg, &mut self.metrics, &mut seq, matched.tokens);

        // Pad prompt to the bucket.
        let mut toks: Vec<i32> = seq.prompt.iter().map(|&t| t as i32).collect();
        toks.resize(bucket, 0);
        let tokens_lit = literal_i32(&toks, &[1, bucket])?;
        let entry = Manifest::prefill_entry_name(bucket);
        let exec_t0 = self.clock.now();
        let outs = self.rt.execute(&entry, &[&tokens_lit])?;
        let mut exec_dt = self.clock.now().saturating_sub(exec_t0);
        let [logits, k, v]: [xla::Literal; 3] = outs
            .try_into()
            .map_err(|_| Error::Artifact("prefill must return 3 outputs".into()))?;

        // Persist KV to the paged backing store (needed for rebuilds and
        // preemption; off the per-decode-step path). Positions covered
        // by the attached prefix are already resident and shared — only
        // the uncached suffix is written.
        let k_host = to_vec_f32(&k)?;
        let v_host = to_vec_f32(&v)?;
        self.kv
            .write_prefill_range(seq.id, &k_host, &v_host, bucket, matched.tokens, len)?;
        seq.kv_len = len;

        // First token from the logits row of the last real position.
        let logits_host = to_vec_f32(&logits)?;
        let row = &logits_host[(len - 1) * self.vocab..len * self.vocab];
        let tok = self.sampler.sample(row, seq.params);
        seq.generated.push(tok);
        let now = self.clock.now();
        seq.first_token_at = Some(now);
        self.metrics.first_token.record(now.saturating_sub(seq.arrived));
        // A fresh stream always has credit (capacity >= 1); a client
        // that already hung up is reaped by the next step's stream scan.
        let _ = seq.emit_token(tok);
        self.metrics.tokens_generated += 1;
        self.metrics.requests_admitted += 1;

        let done_eos = self.tokenizer.is_eos(tok);
        let done_stop = seq.hit_stop();
        if done_eos || done_stop || seq.max_new_tokens <= 1 {
            let reason = if done_eos {
                FinishReason::Eos
            } else if done_stop {
                FinishReason::Stop
            } else {
                FinishReason::MaxTokens
            };
            self.finish_seq(&mut seq, reason)?;
        } else {
            seq.state = SeqState::Decoding;
            let admission = self.batcher.admit(seq.id)?;
            if admission.bucket_grew {
                // Bucket changed: the dense tensor shape no longer fits.
                // Persist and drop; the next decode step rebuilds.
                self.invalidate_dense()?;
            } else if let Some(mut dense) = self.dense.take() {
                // Fast path: splice this sequence's KV into the running
                // dense cache on device (no host round trip).
                let ins_entry = format!("insert_b{}_s{}", dense.bucket, bucket);
                let lane_lit = literal_i32(&[admission.lane as i32], &[1])?;
                let ins_t0 = self.clock.now();
                let mut outs = self
                    .rt
                    .execute(&ins_entry, &[&dense.k, &dense.v, &k, &v, &lane_lit])?;
                exec_dt += self.clock.now().saturating_sub(ins_t0);
                if outs.len() != 2 {
                    return Err(Error::Artifact(format!(
                        "{ins_entry}: expected 2 outputs, got {}",
                        outs.len()
                    )));
                }
                dense.v = outs.pop().unwrap();
                dense.k = outs.pop().unwrap();
                dense.lanes[admission.lane] = Some(seq.id);
                self.dense = Some(dense);
                self.metrics.kv_inserts += 1;
            }
            self.seqs.insert(seq.id, seq);
        }
        self.metrics.prefill_steps += 1;
        let dt = self.clock.now().saturating_sub(t0);
        self.metrics.step.record(dt);
        self.metrics.step_overhead.record(dt.saturating_sub(exec_dt));
        Ok(())
    }

    // -----------------------------------------------------------------
    // Decode
    // -----------------------------------------------------------------

    fn step_decode(&mut self) -> Result<()> {
        let t0 = self.clock.now();
        // The stream scan may have paused or dropped every running
        // sequence; there is nothing to decode then.
        if self.batcher.is_empty() {
            return Ok(());
        }
        // KV headroom: each running sequence may need one fresh block.
        // The shared policy reclaims cached prefix blocks first;
        // preemption is the last resort, drawing victims from running
        // *and* backpressure-paused sequences (parked work holds KV
        // too).
        while policy::reclaim_decode_headroom(
            &mut self.kv,
            &mut self.prefix,
            &mut self.metrics,
            self.batcher.len(),
            self.batcher.len() + self.paused.len(),
        ) {
            self.preempt_one()?;
        }
        if self.batcher.is_empty() {
            return Ok(()); // preemption may have taken the last runner
        }
        let batch = self.batcher.assemble()?;
        let bucket = batch.bucket;
        let geo = self.kv.geometry();

        let stale = match &self.dense {
            None => true,
            Some(d) => d.bucket != bucket || d.lanes != batch.lanes,
        };
        if stale {
            self.rebuild_dense(&batch.lanes, bucket)?;
            self.metrics.kv_rebuilds += 1;
        }

        // Assemble token/pos lanes (holes: token 0, pos 0).
        let mut toks = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        for (i, slot) in batch.lanes.iter().enumerate() {
            if let Some(id) = slot {
                let s = &self.seqs[id];
                toks[i] = s.last_token() as i32;
                pos[i] = s.kv_len as i32;
            }
        }
        let toks_lit = literal_i32(&toks, &[bucket])?;
        let pos_lit = literal_i32(&pos, &[bucket])?;

        let entry = Manifest::decode_entry_name(bucket, !self.cfg.async_softmax);
        let exec_t0 = self.clock.now();
        let outs = {
            let d = self.dense.take().expect("dense state after rebuild");
            let r = self.rt.execute(&entry, &[&toks_lit, &pos_lit, &d.k, &d.v]);
            self.dense = Some(d);
            r?
        };
        let exec_dt = self.clock.now().saturating_sub(exec_t0);
        let mut outs = outs;
        if outs.len() != 4 {
            return Err(Error::Artifact(format!(
                "decode entry returned {} outputs, want 4",
                outs.len()
            )));
        }
        let flags = outs.pop().unwrap();
        let v_new = outs.pop().unwrap();
        let k_new = outs.pop().unwrap();
        let logits = outs.pop().unwrap();

        // The updated caches become the new device state.
        self.dense = Some(DenseState {
            bucket,
            lanes: batch.lanes.clone(),
            k: k_new,
            v: v_new,
        });

        let logits_host = to_vec_f32(&logits)?;
        let flags_host = to_vec_f32(&flags)?;
        let mut finished: Vec<(SeqId, FinishReason)> = Vec::new();
        for (i, slot) in batch.lanes.iter().enumerate() {
            let Some(id) = slot else { continue };
            let seq = self.seqs.get_mut(id).unwrap();
            let row = &logits_host[i * self.vocab..(i + 1) * self.vocab];
            let tok = self.sampler.sample(row, seq.params);
            self.kv.grow_one(*id)?;
            seq.kv_len += 1;
            seq.generated.push(tok);
            // Cannot be Full: the pre-decode stream scan guaranteed at
            // least one credit and this is the step's only token. A
            // mid-step disconnect is reaped by the next scan.
            let _ = seq.emit_token(tok);
            self.metrics.tokens_generated += 1;
            self.metrics.decode_rows += 1;
            if flags_host[i] > 0.5 {
                self.metrics.recompute_rows += 1;
            }
            let done_eos = tok == EOS;
            let done_stop = seq.hit_stop();
            let done_len =
                seq.generated.len() >= seq.max_new_tokens || seq.kv_len + 1 >= geo.max_seq;
            if done_eos || done_stop || done_len {
                let reason = if done_eos {
                    FinishReason::Eos
                } else if done_stop {
                    FinishReason::Stop
                } else {
                    FinishReason::MaxTokens
                };
                finished.push((*id, reason));
            }
        }
        // Retire finished sequences (their lanes become holes; the dense
        // tensor stays valid — holes are masked by pos/kv_len).
        for (id, reason) in finished {
            let mut seq = self.seqs.remove(&id).unwrap();
            self.retire(&mut seq, reason)?;
        }
        self.metrics.decode_steps += 1;
        let dt = self.clock.now().saturating_sub(t0);
        self.metrics.step.record(dt);
        self.metrics.step_overhead.record(dt.saturating_sub(exec_dt));
        let lanes = batch.occupancy().max(1) as u32;
        self.metrics.per_token.record(dt / lanes);
        Ok(())
    }

    /// Remove a sequence from the running set, keeping the dense state
    /// consistent (hole without shrink; invalidate on shrink).
    fn retire(&mut self, seq: &mut Sequence, reason: FinishReason) -> Result<()> {
        let shrank = self.batcher.remove(seq.id)?;
        if shrank {
            self.invalidate_dense()?;
        } else if let Some(d) = self.dense.as_mut() {
            for slot in d.lanes.iter_mut() {
                if *slot == Some(seq.id) {
                    *slot = None;
                }
            }
        }
        self.finish_seq(seq, reason)
    }

    /// Persist the device cache into the paged store and drop it.
    fn invalidate_dense(&mut self) -> Result<()> {
        if let Some(prev) = self.dense.take() {
            // Only still-allocated lanes are written back.
            let lanes: Vec<Option<SeqId>> = prev
                .lanes
                .iter()
                .map(|slot| slot.filter(|id| self.kv.contains(*id)))
                .collect();
            if lanes.iter().any(Option::is_some) {
                let k_host = to_vec_f32(&prev.k)?;
                let v_host = to_vec_f32(&prev.v)?;
                self.kv.scatter_dense(&lanes, prev.bucket, &k_host, &v_host)?;
            }
        }
        Ok(())
    }

    /// Rebuild the dense device KV from the paged store for a new batch
    /// composition, first persisting the previous composition's state.
    fn rebuild_dense(&mut self, lanes: &[Option<SeqId>], bucket: usize) -> Result<()> {
        self.invalidate_dense()?;
        let geo = self.kv.geometry();
        let n = geo.dense_elems(bucket);
        let mut k_host = vec![0.0f32; n];
        let mut v_host = vec![0.0f32; n];
        self.kv.gather_dense(lanes, bucket, &mut k_host, &mut v_host)?;
        let shape = [geo.n_layers, bucket, geo.n_heads, geo.max_seq, geo.head_dim];
        self.dense = Some(DenseState {
            bucket,
            lanes: lanes.to_vec(),
            k: literal_f32(&k_host, &shape)?,
            v: literal_f32(&v_host, &shape)?,
        });
        Ok(())
    }

    /// Preempt one victim under KV pressure: the scheduler picks it
    /// *by id* over the shared policy's priority-aware census, which
    /// spans running *and* backpressure-paused sequences (a parked slow
    /// client's KV is reclaimable like any other; within a priority
    /// level parked victims lose first). Running victims go through
    /// `retire` (lane + dense bookkeeping); paused victims hold no lane
    /// and finish directly.
    fn preempt_one(&mut self) -> Result<()> {
        let mut pool = self.batcher.running_ids();
        pool.extend(self.paused.iter().copied());
        let candidates = policy::preempt_candidates(&self.kv, &self.seqs, &pool);
        let id = preemption_victim(&candidates)
            .ok_or_else(|| Error::Schedule("no preemption victim".into()))?;
        let mut seq = self.seqs.remove(&id).unwrap();
        self.metrics.preemptions += 1;
        if self.paused.contains(&id) {
            self.paused.retain(|&p| p != id);
            self.finish_seq(&mut seq, FinishReason::Preempted)
        } else {
            self.retire(&mut seq, FinishReason::Preempted)
        }
    }

    // -----------------------------------------------------------------
    // Stream flow control
    // -----------------------------------------------------------------

    /// Park a running sequence whose client stream is out of credit.
    /// Its device-resident KV is persisted into the paged store first
    /// (the sequence will continue later, unlike a retirement), then
    /// its lane is released; the next decode step rebuilds the dense
    /// cache for the smaller batch.
    fn pause_seq(&mut self, id: SeqId) -> Result<()> {
        self.invalidate_dense()?;
        self.batcher.remove(id)?;
        let now = self.clock.now();
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.state = SeqState::Paused;
        seq.paused_at = Some(now);
        self.paused.push(id);
        self.metrics.backpressure_pauses += 1;
        Ok(())
    }

    /// Apply backpressure at the top of every step. The *decisions*
    /// (resume order, hysteresis, policy) are the shared
    /// [`policy::plan_stream_ops`]; this method supplies only the PJRT
    /// engine's mechanics: a resumed sequence's KV lives in the paged
    /// store (persisted at pause), so the lane mismatch makes the next
    /// decode step rebuild the dense cache. Checking credit *before*
    /// decode means a generated token always has a slot — backpressure
    /// halts generation, never loses data.
    fn service_streams(&mut self) -> Result<()> {
        let free_lanes = self.cfg.max_running.saturating_sub(self.batcher.len());
        let ops = policy::plan_stream_ops(
            &self.seqs,
            &self.paused,
            &self.batcher.running_ids(),
            self.cfg.backpressure,
            free_lanes,
            self.clock.now(),
            self.cfg.stream_idle_timeout(),
        );
        for op in ops {
            match op {
                StreamOp::Resume(id) => {
                    let admission = self.batcher.admit(id)?;
                    if admission.bucket_grew {
                        self.invalidate_dense()?;
                    }
                    self.paused.retain(|&p| p != id);
                    let seq = self.seqs.get_mut(&id).unwrap();
                    seq.state = SeqState::Decoding;
                    seq.paused_at = None;
                    self.metrics.backpressure_resumes += 1;
                }
                StreamOp::ReapPaused(id) => {
                    self.paused.retain(|&p| p != id);
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.metrics.client_disconnects += 1;
                    self.finish_seq(&mut seq, FinishReason::Cancelled)?;
                }
                StreamOp::ReapRunning(id) => {
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.metrics.client_disconnects += 1;
                    self.retire(&mut seq, FinishReason::Cancelled)?;
                }
                StreamOp::Pause(id) => self.pause_seq(id)?,
                StreamOp::DropOverrun(id) => {
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.metrics.backpressure_drops += 1;
                    self.retire(&mut seq, FinishReason::Overrun)?;
                }
                StreamOp::ExpireIdle(id) => {
                    // A long-parked client: demote to overrun so its KV
                    // is bounded even with no allocation pressure.
                    // Paused sequences hold no lane and no dense slot.
                    self.paused.retain(|&p| p != id);
                    let mut seq = self.seqs.remove(&id).unwrap();
                    self.metrics.stream_idle_drops += 1;
                    self.finish_seq(&mut seq, FinishReason::Overrun)?;
                }
            }
        }
        Ok(())
    }

    /// Register a finished/preempted sequence's *prompt* KV in the
    /// prefix cache. Only the prompt's full blocks are registered: they
    /// were written at prefill and are valid in the paged store, while
    /// generated-token KV may still be device-resident (scattered back
    /// only on a dense rebuild) and must not be published.
    fn register_prefix(&mut self, seq: &Sequence) {
        if !self.cfg.prefix_cache || !self.kv.contains(seq.id) {
            return;
        }
        let Some(blocks) = self.kv.seq_blocks(seq.id) else {
            return;
        };
        self.prefix.insert(&seq.prompt, &blocks, &mut self.kv);
    }

    fn finish_seq(&mut self, seq: &mut Sequence, reason: FinishReason) -> Result<()> {
        seq.state = SeqState::Finished(reason);
        let usage = seq.usage();
        seq.emit_finish(reason, usage);
        self.metrics.record_finish(&seq.tenant, usage);
        self.register_prefix(seq);
        if self.kv.contains(seq.id) {
            self.kv.free_seq(seq.id)?;
        }
        self.metrics.requests_finished += 1;
        Ok(())
    }
}

impl InferenceEngine for Engine {
    /// Queue a typed request; the prompt must fit the largest prefill
    /// bucket and the KV pool.
    fn submit(&mut self, req: GenRequest) -> Result<SubmissionHandle> {
        let prompt_tokens = router::encode_prompt(&self.tokenizer, &req.prompt)?;
        let max_prefill = *self.cfg.prefill_buckets.last().unwrap();
        if prompt_tokens.len() > max_prefill {
            return Err(Error::Request(format!(
                "prompt of {} tokens exceeds the largest prefill bucket {max_prefill}",
                prompt_tokens.len()
            )));
        }
        let need = (prompt_tokens.len() + 1).div_ceil(self.cfg.kv_block_tokens);
        if need > self.cfg.kv_total_blocks {
            return Err(Error::Request(format!(
                "prompt needs {need} KV blocks, pool has {}",
                self.cfg.kv_total_blocks
            )));
        }
        router::enqueue_request(
            &mut self.router,
            &self.tokenizer,
            &req,
            prompt_tokens,
            &SubmitContext {
                max_new_cap: self.cfg.max_new_tokens,
                stream_capacity: self.cfg.stream_capacity,
                now: self.clock.now(),
                wakeup: self.wakeup.as_ref(),
            },
        )
    }

    fn set_wakeup(&mut self, wakeup: Wakeup) {
        self.wakeup = Some(wakeup);
    }

    /// Run one scheduling iteration: service stream flow control, then
    /// prefill/decode/idle. Returns the action taken.
    fn step(&mut self) -> Result<Action> {
        self.service_streams()?;
        let state = policy::plan_admission(
            &self.cfg,
            &mut self.kv,
            &mut self.prefix,
            &mut self.metrics,
            self.router.peek_next(),
            self.router.queued(),
            self.batcher.len(),
        );
        let action = decide(state);
        match action {
            Action::Prefill => self.step_prefill()?,
            Action::Decode => self.step_decode()?,
            Action::Idle => {}
        }
        Ok(action)
    }

    /// Cancel a queued, running, or paused request; its KV blocks are
    /// released (prompt blocks may survive in the prefix cache,
    /// refcounted by the tree alone).
    fn cancel(&mut self, id: RequestId) -> Result<bool> {
        if let Some(mut seq) = self.router.take(id) {
            self.metrics.cancellations += 1;
            self.finish_seq(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        if self.paused.contains(&id) {
            self.paused.retain(|&p| p != id);
            let mut seq = self.seqs.remove(&id).unwrap();
            self.metrics.cancellations += 1;
            // Paused sequences hold no lane and no dense-cache slot:
            // finish directly, no retire bookkeeping.
            self.finish_seq(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        if let Some(mut seq) = self.seqs.remove(&id) {
            self.metrics.cancellations += 1;
            self.retire(&mut seq, FinishReason::Cancelled)?;
            return Ok(true);
        }
        Ok(false)
    }

    fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    /// True when no work remains.
    fn is_idle(&self) -> bool {
        self.router.queued() == 0 && self.batcher.is_empty() && self.paused.is_empty()
    }

    fn queued(&self) -> usize {
        self.router.queued()
    }

    fn running(&self) -> usize {
        self.batcher.len()
    }

    fn paused(&self) -> usize {
        self.paused.len()
    }

    fn queue_depths(&self) -> Vec<(i32, usize)> {
        self.router.depths_by_priority()
    }

    fn encode(&self, text: &str) -> Vec<u32> {
        self.tokenizer.encode(text)
    }

    fn decode(&self, tokens: &[u32]) -> String {
        self.tokenizer.decode(tokens)
    }
}
