//! Token sampling: greedy, temperature, top-k — deterministic under a
//! seeded RNG so end-to-end runs are reproducible.

use crate::util::rng::Rng;

/// Sampling parameters per request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// <= 0 means greedy argmax.
    pub temperature: f32,
    /// 0 means no top-k truncation.
    pub top_k: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
        }
    }
}

/// Deterministic sampler owned by the engine.
pub struct Sampler {
    rng: Rng,
}

impl Sampler {
    pub fn new(seed: u64) -> Self {
        Sampler {
            rng: Rng::seed_from_u64(seed),
        }
    }

    /// Sample a token id from a logits row.
    pub fn sample(&mut self, logits: &[f32], params: SamplingParams) -> u32 {
        if params.temperature <= 0.0 {
            return argmax(logits);
        }
        // softmax over (optionally top-k-truncated) logits / T
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if params.top_k > 0 && params.top_k < logits.len() {
            idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            idx.truncate(params.top_k);
        }
        let m = idx
            .iter()
            .map(|&i| logits[i])
            .fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f32> = idx
            .iter()
            .map(|&i| ((logits[i] - m) / params.temperature).exp())
            .collect();
        let total: f32 = weights.iter().sum();
        let mut u: f32 = self.rng.next_f32() * total;
        for (j, &w) in weights.iter().enumerate() {
            if u < w {
                return idx[j] as u32;
            }
            u -= w;
        }
        idx[idx.len() - 1] as u32
    }
}

/// Greedy argmax (ties -> lowest index, stable across runs).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(0);
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        assert_eq!(s.sample(&logits, SamplingParams::default()), 1);
    }

    #[test]
    fn argmax_tie_stable() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0]), 1);
    }

    #[test]
    fn temperature_sampling_deterministic_per_seed() {
        let logits: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37).sin()).collect();
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 10,
        };
        let a: Vec<u32> = {
            let mut s = Sampler::new(42);
            (0..20).map(|_| s.sample(&logits, p)).collect()
        };
        let b: Vec<u32> = {
            let mut s = Sampler::new(42);
            (0..20).map(|_| s.sample(&logits, p)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![10.0, 9.0, -50.0, -60.0];
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 2,
        };
        let mut s = Sampler::new(7);
        for _ in 0..50 {
            let t = s.sample(&logits, p);
            assert!(t == 0 || t == 1, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn high_temperature_still_valid_token() {
        let logits = vec![0.0; 16];
        let p = SamplingParams {
            temperature: 100.0,
            top_k: 0,
        };
        let mut s = Sampler::new(1);
        for _ in 0..32 {
            assert!((s.sample(&logits, p) as usize) < 16);
        }
    }
}
