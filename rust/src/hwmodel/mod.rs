//! Analytic GPU cost model — the testbed substitute (DESIGN.md §3).
//!
//! The paper's evaluation runs on four GPUs we don't have; every figure,
//! though, compares *kernel schedules* (padding waste, synchronization
//! overhead, parallelism-vs-reuse tradeoffs, resource choice), which are
//! functions of a resource model: SM count, HBM bandwidth, matrix-unit
//! and vector-unit throughput, launch overhead. This module implements
//! that model with published hardware specs and the schedule equations
//! from the paper (§4 Eq. 5, §5 insight about FastGEMV's M-fold weight
//! re-reads, §2.3/§3 partial-softmax synchronization).
//!
//! Absolute times are estimates; the reproduced quantities are the
//! *ratios and crossovers* of Figures 1, 7, 9, 10-13.

use crate::dataflow::ImplKind;
use crate::gemm;

/// Published hardware characteristics of one GPU.
#[derive(Debug, Clone)]
pub struct GpuProfile {
    pub name: String,
    pub vendor: Vendor,
    /// Streaming multiprocessors (NVIDIA) / compute units (AMD).
    pub sms: usize,
    /// HBM/GDDR bandwidth, bytes per second.
    pub hbm_bw: f64,
    /// Matrix-unit (Tensor Core / Matrix Core) f16 FLOP/s, dense.
    pub tc_flops: f64,
    /// Vector-unit (CUDA core / stream processor) f32 FLOP/s.
    pub cc_flops: f64,
    /// Kernel launch + driver overhead per kernel, seconds.
    pub launch_s: f64,
    /// VRAM capacity in bytes (Table 1).
    pub vram_bytes: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vendor {
    Nvidia,
    Amd,
}

/// Table 1 hardware platforms.
pub fn a100() -> GpuProfile {
    GpuProfile {
        name: "A100-80GB".into(),
        vendor: Vendor::Nvidia,
        sms: 108,
        hbm_bw: 2.039e12,
        tc_flops: 312e12,
        cc_flops: 19.5e12,
        launch_s: 4.0e-6,
        vram_bytes: 80 << 30,
    }
}

pub fn rtx3090() -> GpuProfile {
    GpuProfile {
        name: "RTX3090".into(),
        vendor: Vendor::Nvidia,
        sms: 82,
        hbm_bw: 0.936e12,
        tc_flops: 71e12,
        cc_flops: 35.6e12,
        launch_s: 4.0e-6,
        vram_bytes: 24 << 30,
    }
}

pub fn mi210() -> GpuProfile {
    GpuProfile {
        name: "MI210".into(),
        vendor: Vendor::Amd,
        sms: 104,
        hbm_bw: 1.638e12,
        tc_flops: 181e12,
        cc_flops: 22.6e12,
        launch_s: 6.0e-6,
        vram_bytes: 64 << 30,
    }
}

pub fn rx7900xtx() -> GpuProfile {
    GpuProfile {
        name: "RX7900XTX".into(),
        vendor: Vendor::Amd,
        sms: 96,
        hbm_bw: 0.960e12,
        tc_flops: 122.8e12,
        cc_flops: 61.4e12,
        launch_s: 6.0e-6,
        vram_bytes: 24 << 30,
    }
}

pub fn all_gpus() -> Vec<GpuProfile> {
    vec![a100(), rtx3090(), mi210(), rx7900xtx()]
}

// ---------------------------------------------------------------------------
// GEMM kernel models
// ---------------------------------------------------------------------------

/// Achievable-fraction constants (calibrated once against the paper's two
/// §5 measurements, then held fixed across all figures — see tests).
mod cal {
    /// FastGEMV reaches near-streaming bandwidth.
    pub const GEMV_BW_EFF: f64 = 0.88;
    /// cuBLAS-style TC GEMM on flat shapes: lower effective bandwidth
    /// (tile quantization + epilogue) — yields the 82.15% §5 ratio.
    pub const CONV_BW_EFF: f64 = 0.72;
    /// Flat GEMM with double buffering (large N).
    pub const FLAT_BW_EFF_DB: f64 = 0.85;
    /// Flat GEMM without double buffering (small N, parallelism-bound).
    pub const FLAT_BW_EFF: f64 = 0.66;
    /// MXU/TC sustained fraction for well-shaped GEMMs.
    pub const TC_EFF: f64 = 0.75;
    /// Vector-unit sustained fraction.
    pub const CC_EFF: f64 = 0.80;
}

/// Time (s) of one x[M,K] @ w[K,N] with implementation `impl_kind`.
/// `elt` is the element size in bytes (2 for fp16/bf16).
pub fn gemm_time(
    gpu: &GpuProfile,
    impl_kind: ImplKind,
    m: usize,
    n: usize,
    k: usize,
    elt: usize,
) -> f64 {
    let (mf, nf, kf) = (m as f64, n as f64, k as f64);
    match impl_kind {
        ImplKind::A => {
            // FastGEMV processes each output row as an independent GEMV:
            // the weight matrix is re-streamed per row (no MAC-array
            // reuse) — this is why ImplA loses past small M (§5). L2
            // catches part of the re-reads, so the effective traffic
            // grows sublinearly in M (calibrated to the §5 49.75% point).
            let passes = 1.0 + (mf - 1.0) * 0.55;
            let bytes = passes * kf * nf * elt as f64 + (mf * kf + mf * nf) * elt as f64;
            let t_mem = bytes / (gpu.hbm_bw * cal::GEMV_BW_EFF);
            let t_cmp = 2.0 * mf * nf * kf / (gpu.cc_flops * cal::CC_EFF);
            t_mem.max(t_cmp) + gpu.launch_s
        }
        ImplKind::B => {
            // Flat GEMM (§4): pad M to 8, tile N/K, weights read once.
            let mp = m.div_ceil(8) * 8;
            let tiling = gemm::choose_tiling(n, k, gpu.sms);
            let blocks = gemm::parallelism(n, tiling.b_n);
            // Memory-bound with double buffering overlapping the K loop.
            let bw_eff = if tiling.double_buffer {
                cal::FLAT_BW_EFF_DB
            } else {
                cal::FLAT_BW_EFF
            };
            // Bandwidth utilization needs enough blocks in flight.
            let bw_util = (blocks as f64 / (gpu.sms as f64 * 0.5)).min(1.0);
            let bytes = (kf * nf + mp as f64 * kf + mp as f64 * nf) * elt as f64;
            let t_mem = bytes / (gpu.hbm_bw * bw_eff * bw_util);
            let t_cmp = 2.0 * mp as f64 * nf * kf / (gpu.tc_flops * cal::TC_EFF);
            t_mem.max(t_cmp) + gpu.launch_s
        }
        ImplKind::C => {
            // Conventional tiled GEMM: pad M to 64 (the pre-§4 design).
            let mp = m.div_ceil(64) * 64;
            let bytes = (kf * nf + mp as f64 * kf + mp as f64 * nf) * elt as f64;
            let t_mem = bytes / (gpu.hbm_bw * cal::CONV_BW_EFF);
            // Padded rows burn real MACs.
            let t_cmp = 2.0 * mp as f64 * nf * kf / (gpu.tc_flops * cal::TC_EFF);
            t_mem.max(t_cmp) + gpu.launch_s
        }
    }
}

/// Figure 7 model: normalized flat-GEMM performance at a forced N-tile
/// size `b_n` (instead of the heuristic choice). M is padded to 8.
pub fn flat_gemm_time_forced_bn(
    gpu: &GpuProfile,
    m: usize,
    n: usize,
    k: usize,
    b_n: usize,
    elt: usize,
) -> f64 {
    let mp = m.div_ceil(8) * 8;
    let (mf, nf, kf) = (mp as f64, n as f64, k as f64);
    let blocks = gemm::parallelism(n, b_n);
    // Parallelism-bound regime: too few blocks idle SMs (both compute and
    // memory pipelines).
    let util = (blocks as f64 / (gpu.sms as f64 * 0.5)).min(1.0);
    // Reuse regime (Eq. 5): small B_N re-reads activations; express as
    // traffic inflation from the compute/memory-ratio formula.
    let ideal_ratio = gemm::compute_memory_ratio(mp, k, 4096.min(n));
    let ratio = gemm::compute_memory_ratio(mp, k, b_n);
    let traffic_inflation = ideal_ratio / ratio;
    let double_buffer = blocks >= gpu.sms;
    let bw_eff = if double_buffer {
        cal::FLAT_BW_EFF_DB
    } else {
        cal::FLAT_BW_EFF
    };
    let bytes = (kf * nf + mf * kf * 0.0 + mf * nf) * elt as f64 * traffic_inflation
        + mf * kf * elt as f64 * blocks as f64; // activations re-read per block
    let t_mem = bytes / (gpu.hbm_bw * bw_eff * util);
    let t_cmp = 2.0 * mf * nf * kf / (gpu.tc_flops * cal::TC_EFF * util);
    t_mem.max(t_cmp) + gpu.launch_s
}

// ---------------------------------------------------------------------------
// Attention kernel models
// ---------------------------------------------------------------------------

/// Softmax scheme of the decode-attention kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SoftmaxScheme {
    /// Whole-row softmax, scores materialized to HBM (HF eager).
    Naive,
    /// Partial softmax with synchronized max updates (FlashAttention /
    /// FlashDecoding, Figure 4(b)).
    SyncPartial,
    /// Unified-max asynchronized partials (FlashDecoding++, Figure 4(c)).
    AsyncUnified,
}

/// KV chunk length used by split-KV decode kernels.
pub const KV_CHUNK: usize = 256;

/// §2.3 calibration: the synchronized update costs 18.8% of attention
/// time for Llama2-7B @ 1K on A100. The rescale traffic+flops scale with
/// the same terms as the base kernel, so the fraction is scheme-constant.
const SYNC_UPDATE_FRAC: f64 = 0.188 / (1.0 - 0.188);

/// Expected recompute rate of the unified-max scheme (Figure 5: tails are
/// negligible for supported models).
const ASYNC_RECOMPUTE_RATE: f64 = 0.005;

/// Time (s) of decode attention for a whole model layer.
pub fn attention_decode_time(
    gpu: &GpuProfile,
    batch: usize,
    heads: usize,
    head_dim: usize,
    kv_len: usize,
    scheme: SoftmaxScheme,
    elt: usize,
) -> f64 {
    let rows = (batch * heads) as f64;
    let kv_bytes = 2.0 * rows * kv_len as f64 * head_dim as f64 * elt as f64;
    let flops = 4.0 * rows * kv_len as f64 * head_dim as f64;
    // Split-KV kernels expose rows*chunks blocks of parallelism; decode
    // attention is bandwidth-bound on every platform here.
    let chunks = kv_len.div_ceil(KV_CHUNK).max(1);
    let blocks = rows * chunks as f64;
    let util = (blocks / (gpu.sms as f64 * 0.5)).min(1.0);
    let t_mem = kv_bytes / (gpu.hbm_bw * 0.85 * util);
    let t_cmp = flops / (gpu.cc_flops * cal::CC_EFF);
    let base = t_mem.max(t_cmp);
    match scheme {
        SoftmaxScheme::Naive => {
            // Scores round-trip HBM (write P, read for softmax, write
            // softmax, read for PV) + separate kernel launches.
            let score_bytes = 4.0 * rows * kv_len as f64 * 4.0; // f32 scores
            base + score_bytes / (gpu.hbm_bw * 0.85 * util) + 3.0 * gpu.launch_s
        }
        SoftmaxScheme::SyncPartial => base * (1.0 + SYNC_UPDATE_FRAC) + gpu.launch_s,
        SoftmaxScheme::AsyncUnified => {
            // No synchronized updates; a final cross-chunk reduction and
            // the rare recompute remain.
            base * (1.0 + ASYNC_RECOMPUTE_RATE) + gpu.launch_s
        }
    }
}

/// Time (s) of causal prefill attention (FlashAttention-style fused
/// kernel unless `naive`).
pub fn attention_prefill_time(
    gpu: &GpuProfile,
    batch: usize,
    heads: usize,
    head_dim: usize,
    seq: usize,
    naive: bool,
    elt: usize,
) -> f64 {
    let rows = (batch * heads) as f64;
    // Causal: half the score matrix.
    let flops = 2.0 * rows * (seq as f64) * (seq as f64) * head_dim as f64;
    let io = 3.0 * rows * seq as f64 * head_dim as f64 * elt as f64;
    let t_cmp = flops / (gpu.tc_flops * cal::TC_EFF);
    let t_mem = io / (gpu.hbm_bw * 0.85);
    if naive {
        // Materialize S = QK^T ([seq, seq] f32) twice over.
        let score_bytes = 4.0 * rows * (seq as f64) * (seq as f64) * 4.0 / 2.0;
        t_cmp.max(t_mem) + score_bytes / (gpu.hbm_bw * 0.85) + 3.0 * gpu.launch_s
    } else {
        t_cmp.max(t_mem) + gpu.launch_s
    }
}

/// Roofline helper: attainable FLOP/s at arithmetic intensity `ai`.
pub fn roofline(gpu: &GpuProfile, ai: f64, matrix_unit: bool) -> f64 {
    let peak = if matrix_unit { gpu.tc_flops } else { gpu.cc_flops };
    peak.min(ai * gpu.hbm_bw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn section5_claim_gemv_vs_tc_at_m1() {
        // §5: "cuBLAS only achieves 82.15% of FastGEMV" for a Llama2-7B
        // linear at batch 1 on A100. Reproduce the ratio within ±8pts.
        let g = a100();
        let (n, k) = (4096, 4096); // O projection
        let t_a = gemm_time(&g, ImplKind::A, 1, n, k, 2);
        let t_c = gemm_time(&g, ImplKind::C, 1, n, k, 2);
        let perf_ratio = t_a / t_c; // cuBLAS perf / FastGEMV perf
        assert!(
            (0.74..=0.90).contains(&perf_ratio),
            "cuBLAS/FastGEMV perf ratio {perf_ratio:.4} (paper: 0.8215)"
        );
    }

    #[test]
    fn section5_claim_cc_vs_tc_at_m4() {
        // §5: CUDA core at batch 4 reaches only 49.75% of Tensor Core.
        let g = a100();
        let (n, k) = (4096, 4096);
        let t_a = gemm_time(&g, ImplKind::A, 4, n, k, 2);
        let t_b = gemm_time(&g, ImplKind::B, 4, n, k, 2);
        let perf_ratio = t_b / t_a; // CC perf / TC perf
        assert!(
            (0.30..=0.65).contains(&perf_ratio),
            "CC/TC perf ratio at M=4: {perf_ratio:.4} (paper: 0.4975)"
        );
    }

    #[test]
    fn impl_crossovers_exist_and_order() {
        // ImplA wins at M=1, ImplB in the middle, ImplC at large M.
        let g = a100();
        let (n, k) = (12288, 4096);
        let t = |ik, m| gemm_time(&g, ik, m, n, k, 2);
        assert!(t(ImplKind::A, 1) < t(ImplKind::B, 1));
        assert!(t(ImplKind::A, 1) < t(ImplKind::C, 1));
        assert!(t(ImplKind::B, 8) < t(ImplKind::A, 8));
        assert!(t(ImplKind::B, 8) < t(ImplKind::C, 8));
        assert!(t(ImplKind::C, 512) < t(ImplKind::A, 512));
        assert!(t(ImplKind::C, 512) <= t(ImplKind::B, 512) * 1.001);
    }

    #[test]
    fn pad8_beats_pad64_on_flat_shapes() {
        // The §4 headline: >50% loss from pad-to-64 on flat GEMMs.
        let g = a100();
        for m in [1usize, 2, 4, 8] {
            let t_b = gemm_time(&g, ImplKind::B, m, 11008, 4096, 2);
            let t_c = gemm_time(&g, ImplKind::C, m, 11008, 4096, 2);
            assert!(
                t_b < t_c,
                "flat GEMM must beat conventional at M={m}: {t_b} vs {t_c}"
            );
        }
    }

    #[test]
    fn sync_softmax_overhead_matches_profiling() {
        // §2.3: synchronized update = 18.8% of attention (Llama2-7B, 1K).
        let g = a100();
        let t_sync = attention_decode_time(&g, 1, 32, 128, 1024, SoftmaxScheme::SyncPartial, 2);
        let t_async = attention_decode_time(&g, 1, 32, 128, 1024, SoftmaxScheme::AsyncUnified, 2);
        let overhead = (t_sync - t_async) / t_sync;
        assert!(
            (0.12..=0.25).contains(&overhead),
            "sync overhead fraction {overhead:.3} (paper: 0.188)"
        );
    }

    #[test]
    fn naive_attention_slowest() {
        let g = a100();
        let t_n = attention_decode_time(&g, 1, 32, 128, 1024, SoftmaxScheme::Naive, 2);
        let t_s = attention_decode_time(&g, 1, 32, 128, 1024, SoftmaxScheme::SyncPartial, 2);
        assert!(t_n > t_s);
    }

    #[test]
    fn fig7_shape_small_n_parallelism_bound() {
        // Figure 7: at small N the best B_N is small; at large N bigger
        // B_N wins (memory-bound regime).
        let g = a100();
        let best_bn = |n: usize| {
            gemm::bn_candidates()
                .into_iter()
                .min_by(|&x, &y| {
                    flat_gemm_time_forced_bn(&g, 8, n, 4096, x, 2)
                        .partial_cmp(&flat_gemm_time_forced_bn(&g, 8, n, 4096, y, 2))
                        .unwrap()
                })
                .unwrap()
        };
        assert!(best_bn(1024) <= 64, "small N should prefer small B_N");
        assert!(best_bn(32768) >= 64, "large N should prefer larger B_N");
    }

    #[test]
    fn roofline_clamps() {
        let g = a100();
        assert_eq!(roofline(&g, 1e9, true), g.tc_flops);
        assert!(roofline(&g, 1.0, true) < g.tc_flops);
    }

    #[test]
    fn gpu_table1_specs() {
        assert_eq!(a100().vram_bytes, 80 << 30);
        assert_eq!(rtx3090().vram_bytes, 24 << 30);
        assert_eq!(mi210().vram_bytes, 64 << 30);
        assert_eq!(rx7900xtx().vram_bytes, 24 << 30);
        assert_eq!(all_gpus().len(), 4);
    }
}
