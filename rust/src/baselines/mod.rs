//! Baseline LLM-engine models (DESIGN.md §3 substitutions).
//!
//! Figures 1 and 10-13 compare FlashDecoding++ against seven engines.
//! Each baseline is modeled as a *composition* of the kernel schedules it
//! is documented to use (attention softmax scheme, GEMM padding policy,
//! dataflow staticness) plus its framework dispatch overhead. The paper's
//! three effects — C1 (softmax sync), C2 (pad-to-8 flat GEMM), C3
//! (heuristic dataflow) — are exactly the axes on which these engines
//! differ, so the bar *shapes* of the figures emerge from the composition.

pub mod sim;

use crate::config::ModelConfig;
use crate::dataflow::ImplKind;
use crate::hwmodel::{
    attention_decode_time, attention_prefill_time, gemm_time, GpuProfile, SoftmaxScheme, Vendor,
};
use crate::model::{decode_layer_ops, prefill_layer_ops};

/// The engines of Figure 10's legend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    HuggingFace,
    Vllm,
    DeepSpeed,
    OpenPpl,
    TensorRtLlm,
    FlashDecoding,
    FlashDecodingPP,
}

impl EngineKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EngineKind::HuggingFace => "HuggingFace",
            EngineKind::Vllm => "vLLM",
            EngineKind::DeepSpeed => "DeepSpeed",
            EngineKind::OpenPpl => "OpenPPL",
            EngineKind::TensorRtLlm => "TensorRT-LLM",
            EngineKind::FlashDecoding => "FlashDecoding",
            EngineKind::FlashDecodingPP => "FlashDecoding++",
        }
    }

    pub fn all() -> Vec<EngineKind> {
        vec![
            EngineKind::HuggingFace,
            EngineKind::Vllm,
            EngineKind::DeepSpeed,
            EngineKind::OpenPpl,
            EngineKind::TensorRtLlm,
            EngineKind::FlashDecoding,
            EngineKind::FlashDecodingPP,
        ]
    }

    /// Engines that support a given model (Figure 10's blank bars:
    /// OpenPPL does not run OPT-6.7B / ChatGLM2-6B).
    pub fn supports(&self, model: &ModelConfig) -> bool {
        match self {
            EngineKind::OpenPpl => model.name.starts_with("llama2"),
            _ => true,
        }
    }
}

/// Schedule composition of one engine.
#[derive(Debug, Clone)]
pub struct EngineModel {
    pub kind: EngineKind,
    /// Decode attention softmax scheme.
    pub decode_softmax: SoftmaxScheme,
    /// Naive (unfused) prefill attention?
    pub naive_prefill_attention: bool,
    /// GEMM policy for flat decode shapes.
    pub gemm_policy: GemmPolicy,
    /// Framework dispatch cost per kernel launch on the host path.
    pub per_op_overhead_s: f64,
    /// Dispatched host ops per transformer layer per step.
    pub ops_per_layer: f64,
    /// Weight/KV element size (HF eager defaults to fp32; optimized
    /// engines serve fp16/bf16).
    pub elt_bytes: usize,
}

/// How the engine picks its GEMM kernel for a flat [M,K]x[K,N].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmPolicy {
    /// cuBLAS-style: always the conventional pad-to-64 tiled kernel.
    StaticConventional,
    /// A statically tuned kernel choice per model (TensorRT-LLM builder):
    /// flat kernel for decode, conventional for prefill — but no per-M
    /// runtime adaptation and no GEMV escape hatch.
    StaticTuned,
    /// FlashDecoding++ §5: per-(op, M) lookup among ImplA/B/C.
    Heuristic,
}

impl EngineModel {
    pub fn new(kind: EngineKind) -> Self {
        use EngineKind::*;
        match kind {
            HuggingFace => EngineModel {
                kind,
                decode_softmax: SoftmaxScheme::Naive,
                naive_prefill_attention: true,
                gemm_policy: GemmPolicy::StaticConventional,
                per_op_overhead_s: 30e-6, // eager PyTorch dispatch
                ops_per_layer: 12.0,
                elt_bytes: 4,
            },
            Vllm => EngineModel {
                kind,
                decode_softmax: SoftmaxScheme::SyncPartial,
                naive_prefill_attention: false,
                gemm_policy: GemmPolicy::StaticConventional,
                per_op_overhead_s: 8e-6,
                ops_per_layer: 6.0,
                elt_bytes: 2,
            },
            DeepSpeed => EngineModel {
                kind,
                decode_softmax: SoftmaxScheme::SyncPartial,
                naive_prefill_attention: false,
                gemm_policy: GemmPolicy::StaticConventional,
                per_op_overhead_s: 4e-6,
                ops_per_layer: 5.0,
                elt_bytes: 2,
            },
            OpenPpl => EngineModel {
                kind,
                decode_softmax: SoftmaxScheme::SyncPartial,
                naive_prefill_attention: false,
                gemm_policy: GemmPolicy::StaticConventional,
                per_op_overhead_s: 2e-6, // C++ runtime
                ops_per_layer: 5.0,
                elt_bytes: 2,
            },
            TensorRtLlm => EngineModel {
                kind,
                decode_softmax: SoftmaxScheme::SyncPartial,
                naive_prefill_attention: false,
                gemm_policy: GemmPolicy::StaticTuned,
                per_op_overhead_s: 1.5e-6,
                ops_per_layer: 4.0,
                elt_bytes: 2,
            },
            FlashDecoding => EngineModel {
                kind,
                decode_softmax: SoftmaxScheme::SyncPartial,
                naive_prefill_attention: false,
                gemm_policy: GemmPolicy::StaticConventional,
                per_op_overhead_s: 3e-6,
                ops_per_layer: 5.0,
                elt_bytes: 2,
            },
            FlashDecodingPP => EngineModel {
                kind,
                decode_softmax: SoftmaxScheme::AsyncUnified,
                naive_prefill_attention: false,
                gemm_policy: GemmPolicy::Heuristic,
                per_op_overhead_s: 1.5e-6,
                ops_per_layer: 4.0,
                elt_bytes: 2,
            },
        }
    }

    fn decode_gemm(&self, gpu: &GpuProfile, m: usize, n: usize, k: usize, elt: usize) -> f64 {
        match self.gemm_policy {
            GemmPolicy::StaticConventional => gemm_time(gpu, ImplKind::C, m, n, k, elt),
            GemmPolicy::StaticTuned => gemm_time(gpu, ImplKind::B, m, n, k, elt),
            GemmPolicy::Heuristic => [ImplKind::A, ImplKind::B, ImplKind::C]
                .into_iter()
                .map(|ik| gemm_time(gpu, ik, m, n, k, elt))
                .fold(f64::INFINITY, f64::min),
        }
    }

    /// Element size on a given GPU. The paper's NVIDIA HF baseline runs
    /// eager fp32 (transformers default); its ROCm HF runs fp16 (the
    /// only supported path at the time) — which is why the AMD headline
    /// speedup (2.18x) is smaller than NVIDIA's (4.86x).
    fn effective_elt(&self, gpu: &GpuProfile) -> usize {
        if self.kind == EngineKind::HuggingFace && gpu.vendor == Vendor::Amd {
            2
        } else {
            self.elt_bytes
        }
    }

    fn decode_softmax_for(&self, model: &ModelConfig) -> SoftmaxScheme {
        // §3: the unified-max technique is disabled for OPT-6.7B (its
        // softmax-input range is too wide, Figure 5).
        if self.decode_softmax == SoftmaxScheme::AsyncUnified && model.name.starts_with("opt") {
            SoftmaxScheme::SyncPartial
        } else {
            self.decode_softmax
        }
    }

    /// Latency of generating ONE token at the given batch size with
    /// kv_len tokens of context (Figure 10-13's "each token latency").
    pub fn decode_token_time(
        &self,
        model: &ModelConfig,
        gpu: &GpuProfile,
        batch: usize,
        kv_len: usize,
    ) -> f64 {
        let elt = self.effective_elt(gpu);
        let ops = decode_layer_ops(model, batch, kv_len);
        let mut per_layer = 0.0;
        for l in &ops.linears {
            per_layer += self.decode_gemm(gpu, l.m, l.n, l.k, elt);
        }
        per_layer += attention_decode_time(
            gpu,
            batch,
            model.n_heads,
            model.head_dim(),
            kv_len,
            self.decode_softmax_for(model),
            elt,
        );
        // Norms/RoPE/residuals: activation-streaming traffic.
        let elementwise = 10.0 * (batch * model.dim) as f64 * 4.0 / gpu.hbm_bw;
        per_layer += elementwise;
        let lm_head = self.decode_gemm(gpu, batch, model.vocab_size, model.dim, elt);
        let overhead = self.per_op_overhead_s * self.ops_per_layer * model.n_layers as f64;
        model.n_layers as f64 * per_layer + lm_head + overhead
    }

    /// Latency of the prefill phase over `seq` prompt tokens (Figure 11's
    /// "first token latency").
    pub fn prefill_time(
        &self,
        model: &ModelConfig,
        gpu: &GpuProfile,
        batch: usize,
        seq: usize,
    ) -> f64 {
        let elt = self.effective_elt(gpu);
        let ops = prefill_layer_ops(model, batch, seq);
        let mut per_layer = 0.0;
        for l in &ops.linears {
            // Large-M shapes: every engine converges to the conventional
            // kernel; the heuristic dispatch picks it automatically.
            let ik = match self.gemm_policy {
                GemmPolicy::Heuristic | GemmPolicy::StaticTuned => {
                    if l.m < 64 {
                        ImplKind::B
                    } else {
                        ImplKind::C
                    }
                }
                GemmPolicy::StaticConventional => ImplKind::C,
            };
            per_layer += gemm_time(gpu, ik, l.m, l.n, l.k, elt);
        }
        per_layer += attention_prefill_time(
            gpu,
            batch,
            model.n_heads,
            model.head_dim(),
            seq,
            self.naive_prefill_attention,
            elt,
        );
        let elementwise = 10.0 * (batch * seq * model.dim) as f64 * 4.0 / gpu.hbm_bw;
        per_layer += elementwise;
        let lm_head = gemm_time(gpu, ImplKind::C, batch, model.vocab_size, model.dim, elt);
        let overhead = self.per_op_overhead_s * self.ops_per_layer * model.n_layers as f64;
        model.n_layers as f64 * per_layer + lm_head + overhead
    }
}

/// Convenience: decode speedup of `engine` over HuggingFace.
pub fn decode_speedup_vs_hf(
    engine: EngineKind,
    model: &ModelConfig,
    gpu: &GpuProfile,
    batch: usize,
    kv_len: usize,
) -> f64 {
    let hf = EngineModel::new(EngineKind::HuggingFace).decode_token_time(model, gpu, batch, kv_len);
    let e = EngineModel::new(engine).decode_token_time(model, gpu, batch, kv_len);
    hf / e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_model;
    use crate::hwmodel::{a100, rx7900xtx};

    #[test]
    fn fdpp_beats_every_baseline_on_decode() {
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let t_pp = EngineModel::new(EngineKind::FlashDecodingPP)
            .decode_token_time(&model, &gpu, 1, 1024);
        for kind in EngineKind::all() {
            if kind == EngineKind::FlashDecodingPP {
                continue;
            }
            let t = EngineModel::new(kind).decode_token_time(&model, &gpu, 1, 1024);
            assert!(t_pp < t, "FD++ must beat {} ({t_pp} vs {t})", kind.as_str());
        }
    }

    #[test]
    fn hf_speedup_in_paper_band_nvidia() {
        // Abstract: up to 4.86x vs HF on NVIDIA. At bs=1/1K on A100 the
        // overview figure shows ~3-5x; require the model lands in a sane
        // band and the *max over the sweep* reaches ~4x.
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let mut max_sp: f64 = 0.0;
        for (bs, kv) in [(1, 128), (1, 1024), (8, 1024), (32, 512), (64, 256)] {
            let sp = decode_speedup_vs_hf(EngineKind::FlashDecodingPP, &model, &gpu, bs, kv);
            assert!(sp > 1.5, "speedup vs HF at bs={bs} kv={kv}: {sp}");
            max_sp = max_sp.max(sp);
        }
        assert!(
            max_sp > 3.0 && max_sp < 8.0,
            "max decode speedup vs HF {max_sp} (paper: up to 4.86)"
        );
    }

    #[test]
    fn fd_speedup_average_near_paper() {
        // Abstract: avg 1.37x vs FlashDecoding (A100). Accept 1.1-1.7.
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let mut sps = vec![];
        for (bs, kv) in [(1, 128), (1, 1024), (8, 1024), (32, 512)] {
            let fd =
                EngineModel::new(EngineKind::FlashDecoding).decode_token_time(&model, &gpu, bs, kv);
            let pp = EngineModel::new(EngineKind::FlashDecodingPP)
                .decode_token_time(&model, &gpu, bs, kv);
            sps.push(fd / pp);
        }
        let avg = sps.iter().sum::<f64>() / sps.len() as f64;
        assert!(
            (1.1..=1.8).contains(&avg),
            "avg speedup vs FlashDecoding {avg} (paper: 1.37)"
        );
    }

    #[test]
    fn amd_speedup_band() {
        // Abstract: up to 2.18x vs HF on AMD.
        let model = paper_model("llama2-7b").unwrap();
        let gpu = rx7900xtx();
        let mut max_sp: f64 = 0.0;
        for (bs, kv) in [(1, 128), (1, 1024), (8, 1024)] {
            max_sp =
                max_sp.max(decode_speedup_vs_hf(EngineKind::FlashDecodingPP, &model, &gpu, bs, kv));
        }
        assert!(max_sp > 1.5, "AMD max speedup {max_sp} (paper: up to 2.18)");
    }

    #[test]
    fn opt_disables_async_softmax() {
        let opt = paper_model("opt-6.7b").unwrap();
        let e = EngineModel::new(EngineKind::FlashDecodingPP);
        assert_eq!(e.decode_softmax_for(&opt), SoftmaxScheme::SyncPartial);
        let llama = paper_model("llama2-7b").unwrap();
        assert_eq!(e.decode_softmax_for(&llama), SoftmaxScheme::AsyncUnified);
    }

    #[test]
    fn openppl_model_support_matrix() {
        let opt = paper_model("opt-6.7b").unwrap();
        let glm = paper_model("chatglm2-6b").unwrap();
        let llama = paper_model("llama2-7b").unwrap();
        assert!(!EngineKind::OpenPpl.supports(&opt));
        assert!(!EngineKind::OpenPpl.supports(&glm));
        assert!(EngineKind::OpenPpl.supports(&llama));
        assert!(EngineKind::Vllm.supports(&opt));
    }

    #[test]
    fn prefill_first_token_slower_than_decode_token() {
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let e = EngineModel::new(EngineKind::FlashDecodingPP);
        let prefill = e.prefill_time(&model, &gpu, 1, 1024);
        let decode = e.decode_token_time(&model, &gpu, 1, 1024);
        assert!(prefill > decode * 3.0);
    }

    #[test]
    fn decode_time_monotone_in_kv_len() {
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let e = EngineModel::new(EngineKind::FlashDecodingPP);
        let t1 = e.decode_token_time(&model, &gpu, 8, 256);
        let t2 = e.decode_token_time(&model, &gpu, 8, 2048);
        assert!(t2 > t1);
    }
}
