//! Serving-level queueing simulator: composes the per-kernel engine
//! models with each engine's *batching* behaviour to produce
//! latency/throughput under load — the serving-system view of
//! Figures 10-13 (the paper reports per-token kernel latency; deployed
//! engines additionally differ in continuous batching, which this
//! simulator captures).
//!
//! Event-driven over virtual time: Poisson arrivals, prefill admission,
//! batched decode steps whose duration comes from
//! `EngineModel::decode_token_time` at the current batch size and mean
//! context length.

use crate::baselines::{EngineKind, EngineModel};
use crate::config::ModelConfig;
use crate::hwmodel::GpuProfile;
use crate::util::rng::Rng;

/// Simulation workload + engine setup.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub engine: EngineKind,
    /// Max decode batch the engine can form (HF eager: 1 — no continuous
    /// batching; serving engines: their documented defaults).
    pub max_batch: usize,
    /// Request arrival rate (req/s).
    pub rate: f64,
    pub n_requests: usize,
    pub prompt_len: usize,
    pub output_len: usize,
    pub seed: u64,
}

impl SimConfig {
    /// Default max batch per engine (documented serving behaviour).
    pub fn default_max_batch(engine: EngineKind) -> usize {
        match engine {
            EngineKind::HuggingFace => 1, // eager loop, no batching server
            EngineKind::DeepSpeed => 16,
            _ => 32,
        }
    }
}

/// Aggregated simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub engine: EngineKind,
    pub throughput_tok_s: f64,
    pub mean_first_token_s: f64,
    pub p95_first_token_s: f64,
    pub mean_batch: f64,
    pub makespan_s: f64,
}

struct SimSeq {
    arrival: f64,
    first_token_at: Option<f64>,
    kv_len: usize,
    remaining: usize,
}

/// Run the simulation to completion.
pub fn simulate(
    cfg: &SimConfig,
    model: &ModelConfig,
    gpu: &GpuProfile,
) -> SimResult {
    let em = EngineModel::new(cfg.engine);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    // Arrival times.
    let mut arrivals: Vec<f64> = Vec::with_capacity(cfg.n_requests);
    let mut t = 0.0;
    for _ in 0..cfg.n_requests {
        t += rng.gen_exp(cfg.rate);
        arrivals.push(t);
    }

    let mut queue: Vec<SimSeq> = Vec::new();
    let mut running: Vec<SimSeq> = Vec::new();
    let mut done: Vec<SimSeq> = Vec::new();
    let mut next_arrival = 0usize;
    let mut now = 0.0f64;
    let mut tokens = 0u64;
    let mut batch_samples = 0.0f64;
    let mut batch_steps = 0u64;

    while done.len() < cfg.n_requests {
        // Admit arrivals up to `now`.
        while next_arrival < cfg.n_requests && arrivals[next_arrival] <= now {
            queue.push(SimSeq {
                arrival: arrivals[next_arrival],
                first_token_at: None,
                kv_len: cfg.prompt_len,
                remaining: cfg.output_len,
            });
            next_arrival += 1;
        }
        // Nothing active: jump to the next arrival.
        if queue.is_empty() && running.is_empty() {
            if next_arrival < cfg.n_requests {
                now = arrivals[next_arrival];
                continue;
            }
            break;
        }
        // Admission: prefill one queued request if a lane is free.
        if !queue.is_empty() && running.len() < cfg.max_batch {
            let mut seq = queue.remove(0);
            now += em.prefill_time(model, gpu, 1, cfg.prompt_len);
            seq.first_token_at = Some(now);
            seq.kv_len += 1;
            seq.remaining -= 1;
            tokens += 1;
            if seq.remaining == 0 {
                done.push(seq);
            } else {
                running.push(seq);
            }
            continue;
        }
        // Decode step over the running batch.
        let bs = running.len();
        let mean_kv =
            running.iter().map(|s| s.kv_len).sum::<usize>() as f64 / bs as f64;
        now += em.decode_token_time(model, gpu, bs, mean_kv as usize);
        batch_samples += bs as f64;
        batch_steps += 1;
        let mut still: Vec<SimSeq> = Vec::with_capacity(bs);
        for mut s in running.drain(..) {
            s.kv_len += 1;
            s.remaining -= 1;
            tokens += 1;
            if s.remaining == 0 {
                done.push(s);
            } else {
                still.push(s);
            }
        }
        running = still;
    }

    let mut first: Vec<f64> = done
        .iter()
        .map(|s| s.first_token_at.unwrap() - s.arrival)
        .collect();
    first.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_first = first.iter().sum::<f64>() / first.len() as f64;
    let p95 = first[((first.len() as f64 * 0.95) as usize).min(first.len() - 1)];
    SimResult {
        engine: cfg.engine,
        throughput_tok_s: tokens as f64 / now.max(1e-12),
        mean_first_token_s: mean_first,
        p95_first_token_s: p95,
        mean_batch: if batch_steps > 0 {
            batch_samples / batch_steps as f64
        } else {
            1.0
        },
        makespan_s: now,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_model;
    use crate::hwmodel::a100;

    fn cfg(engine: EngineKind, rate: f64) -> SimConfig {
        SimConfig {
            engine,
            max_batch: SimConfig::default_max_batch(engine),
            rate,
            n_requests: 64,
            prompt_len: 512,
            output_len: 64,
            seed: 1,
        }
    }

    #[test]
    fn all_requests_complete() {
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let r = simulate(&cfg(EngineKind::FlashDecodingPP, 5.0), &model, &gpu);
        assert!(r.throughput_tok_s > 0.0);
        assert!(r.makespan_s > 0.0);
        assert!(r.mean_batch >= 1.0);
    }

    #[test]
    fn batching_engine_beats_hf_under_load() {
        // Under concurrent load, continuous batching dominates: FD++
        // throughput must exceed HF's by far more than the kernel-level
        // speedup alone.
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let hf = simulate(&cfg(EngineKind::HuggingFace, 5.0), &model, &gpu);
        let pp = simulate(&cfg(EngineKind::FlashDecodingPP, 5.0), &model, &gpu);
        assert!(
            pp.throughput_tok_s > hf.throughput_tok_s * 2.0,
            "pp {} vs hf {}",
            pp.throughput_tok_s,
            hf.throughput_tok_s
        );
        assert!(pp.mean_batch > 2.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let a = simulate(&cfg(EngineKind::Vllm, 3.0), &model, &gpu);
        let b = simulate(&cfg(EngineKind::Vllm, 3.0), &model, &gpu);
        assert_eq!(a.throughput_tok_s, b.throughput_tok_s);
    }

    #[test]
    fn light_load_latency_dominated() {
        // At very low rate there is no queueing: first-token latency ~=
        // prefill time.
        let model = paper_model("llama2-7b").unwrap();
        let gpu = a100();
        let em = EngineModel::new(EngineKind::FlashDecodingPP);
        let prefill = em.prefill_time(&model, &gpu, 1, 512);
        let r = simulate(&cfg(EngineKind::FlashDecodingPP, 0.05), &model, &gpu);
        assert!(r.mean_first_token_s < prefill * 3.0);
    }
}
