//! Engine telemetry: latency histograms, counters, percentile summaries.
//!
//! Everything is plain data (no atomics on the hot path — the engine step
//! loop is single-owner and hands out snapshots).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::api::Usage;
use crate::util::json::Json;

/// Fixed-boundary log-scale latency histogram, microsecond resolution.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in micros, ascending; last is +inf.
    bounds: Vec<u64>,
    counts: Vec<u64>,
    sum_us: u128,
    count: u64,
    max_us: u64,
    min_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1us .. ~100s in 48 log-spaced buckets.
        let mut bounds = Vec::with_capacity(48);
        let mut b = 1.0f64;
        for _ in 0..48 {
            bounds.push(b as u64);
            b *= 1.47;
        }
        LatencyHistogram {
            counts: vec![0; bounds.len() + 1],
            bounds,
            sum_us: 0,
            count: 0,
            max_us: 0,
            min_us: u64::MAX,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        self.record_weighted(d, 1);
    }

    /// Record `d` as `weight` equal samples of `d / weight` each — the
    /// chunked-decode attribution shape: one measured chunk covering
    /// `weight` tokens lands as `weight` per-token observations whose
    /// micros sum to the chunk's total, so percentiles stay per-token
    /// and sums stay exact at any chunk size. `weight == 0` is treated
    /// as 1 (a chunk that measured time produced at least one sample).
    pub fn record_weighted(&mut self, d: Duration, weight: u64) {
        let weight = weight.max(1);
        let total_us = d.as_micros() as u64;
        let per_us = total_us / weight;
        let idx = self.bounds.partition_point(|&b| b < per_us);
        self.counts[idx] += weight;
        self.sum_us += total_us as u128;
        self.count += weight;
        self.max_us = self.max_us.max(per_us);
        self.min_us = self.min_us.min(per_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros((self.sum_us / self.count as u128) as u64)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(if self.count == 0 { 0 } else { self.max_us })
    }

    /// Smallest recorded sample ([`Duration::ZERO`] when empty).
    pub fn min(&self) -> Duration {
        Duration::from_micros(if self.count == 0 { 0 } else { self.min_us })
    }

    /// Total of all recorded samples, in microseconds.
    pub fn sum_us(&self) -> u128 {
        self.sum_us
    }

    /// Percentile estimate, interpolated within the winning bucket.
    ///
    /// The winning bucket spans `(lower_bound, upper_bound]`; the
    /// estimate walks linearly through it by in-bucket rank and is
    /// clamped to the observed `[min, max]`, so a single-valued
    /// histogram (or a sample landing exactly on a bucket edge) reports
    /// the recorded value itself rather than the bucket's upper bound.
    pub fn percentile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (((self.count as f64) * p.clamp(0.0, 1.0)).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 && seen + c >= target {
                let hi = if i < self.bounds.len() {
                    self.bounds[i].min(self.max_us)
                } else {
                    self.max_us
                };
                let lo = if i == 0 { 0 } else { self.bounds[i - 1] };
                let lo = lo.max(self.min_us).min(hi);
                let frac = (target - seen) as f64 / c as f64;
                let us = lo as f64 + frac * (hi - lo) as f64;
                return Duration::from_micros(us.round() as u64);
            }
            seen += c;
        }
        Duration::from_micros(self.max_us)
    }

    /// Fold another histogram into this one: bucket-wise count sums,
    /// summed totals, min/max folds. Both sides use the fixed
    /// [`Default`] bounds, so buckets line up index-for-index (the
    /// empty-histogram sentinel `min_us == u64::MAX` folds correctly
    /// through `min`).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        debug_assert_eq!(self.bounds, other.bounds, "histogram bounds must match");
        for (mine, theirs) in self.counts.iter_mut().zip(&other.counts) {
            *mine += *theirs;
        }
        self.sum_us += other.sum_us;
        self.count += other.count;
        self.max_us = self.max_us.max(other.max_us);
        self.min_us = self.min_us.min(other.min_us);
    }

    /// Full export: summary stats plus the raw `bounds`/`counts` arrays
    /// so external tooling can re-derive any percentile (`counts` has
    /// one trailing overflow bucket beyond the last bound).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum_us", Json::Num(self.sum_us as f64)),
            ("mean_us", Json::Num(self.mean().as_micros() as f64)),
            ("min_us", Json::Num(self.min().as_micros() as f64)),
            ("max_us", Json::Num(self.max().as_micros() as f64)),
            ("p50_us", Json::Num(self.percentile(0.5).as_micros() as f64)),
            ("p90_us", Json::Num(self.percentile(0.9).as_micros() as f64)),
            ("p99_us", Json::Num(self.percentile(0.99).as_micros() as f64)),
            (
                "bounds",
                Json::Arr(self.bounds.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            (
                "counts",
                Json::Arr(self.counts.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
        ])
    }
}

/// Distinct tenants tracked individually before new ones fold into the
/// [`OTHER_TENANTS`] bucket (tenant ids come off the wire, so the map
/// must stay bounded against adversarial cardinality).
pub const MAX_TRACKED_TENANTS: usize = 64;
/// Aggregate bucket for tenants beyond [`MAX_TRACKED_TENANTS`].
pub const OTHER_TENANTS: &str = "(other)";

/// Per-tenant usage counters, keyed by the request's tenant id.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantCounters {
    pub requests_finished: u64,
    /// Tokens generated for this tenant's requests.
    pub generated_tokens: u64,
    /// Prompt tokens this tenant served from the prefix cache.
    pub cached_prompt_tokens: u64,
}

impl TenantCounters {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests_finished", Json::Num(self.requests_finished as f64)),
            ("generated_tokens", Json::Num(self.generated_tokens as f64)),
            (
                "cached_prompt_tokens",
                Json::Num(self.cached_prompt_tokens as f64),
            ),
        ])
    }
}

/// Aggregated serving metrics, snapshotted by
/// [`crate::api::InferenceEngine::metrics`].
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    /// Time from request arrival to first generated token.
    pub first_token: LatencyHistogram,
    /// Per-token decode latency (one engine step amortized per sequence).
    pub per_token: LatencyHistogram,
    /// Whole-step wall time (prefill or decode).
    pub step: LatencyHistogram,
    /// Host-side overhead per step (everything except PJRT execute).
    pub step_overhead: LatencyHistogram,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub requests_finished: u64,
    pub requests_admitted: u64,
    /// C1 accounting: decode rows that took the recompute fallback.
    pub recompute_rows: u64,
    pub decode_rows: u64,
    /// KV composition rebuilds (full host round trip) — perf-pass counter.
    pub kv_rebuilds: u64,
    /// Device-side KV insertions (fast path; no host round trip).
    pub kv_inserts: u64,
    /// Prefix-cache telemetry: prompts looked up in the radix tree.
    pub prefix_lookups: u64,
    /// Lookups that matched at least one cached block.
    pub prefix_hits: u64,
    /// Prompt tokens served from cached KV instead of prefill compute.
    pub prefix_tokens_reused: u64,
    /// Prompt tokens that went through prefill (uncached).
    pub prefill_tokens_computed: u64,
    /// Cached blocks reclaimed to satisfy allocation pressure.
    pub prefix_blocks_evicted: u64,
    /// Preemptions triggered by KV exhaustion.
    pub preemptions: u64,
    /// Requests cancelled via `InferenceEngine::cancel`.
    pub cancellations: u64,
    /// Requests whose admission waited for an identical in-flight
    /// prompt to retire (cross-request dedup) instead of racing it with
    /// duplicate cold prefill compute.
    pub dedup_hits: u64,
    /// Submissions rejected by the per-tenant concurrency quota
    /// (`EngineConfig::tenant_max_inflight`).
    pub quota_rejections: u64,
    /// Flow control: sequences parked because their bounded client
    /// stream ran out of credit (`BackpressurePolicy::PauseDecode`).
    pub backpressure_pauses: u64,
    /// Paused sequences that rejoined the decode batch after their
    /// client drained.
    pub backpressure_resumes: u64,
    /// Requests finished early with `FinishReason::Overrun`
    /// (`BackpressurePolicy::DropSlow`).
    pub backpressure_drops: u64,
    /// Parked (`pause_decode`) requests demoted to `FinishReason::Overrun`
    /// because their client stayed idle past `stream_idle_timeout`.
    pub stream_idle_drops: u64,
    /// Requests reclaimed because the client dropped its event stream
    /// (hang-up detected mid-generation).
    pub client_disconnects: u64,
    /// Grouped decode (CoDec-style prefix compute reuse; see
    /// `core::DecodeGroup`): decode steps in which at least one
    /// prefix-sharing group was formed.
    pub grouped_decode_steps: u64,
    /// Prefix-sharing groups formed across all decode steps.
    pub grouped_groups_formed: u64,
    /// Decode rows (lane inputs) that were members of some group.
    pub grouped_rows: u64,
    /// Logical decode-attention span: for every decode row, the number
    /// of KV positions it attends over (stored prefix + the new token).
    /// Recorded by the core on every decode step, grouping or not, so
    /// grouped runs report savings against the same denominator an
    /// ungrouped run has.
    pub decode_attn_positions_total: u64,
    /// KV positions whose attention partial was reused from a group's
    /// shared-prefix computation instead of being re-scored per
    /// sequence. Recorded by backends that implement the grouped path.
    pub decode_attn_positions_saved: u64,
    /// Attention FLOPs avoided by grouped decode, using the fixed
    /// convention of 4 FLOPs per KV element per position (QK^T dot +
    /// AV accumulate, multiply and add each).
    pub decode_attn_flops_saved: u64,
    /// KV bytes not re-read thanks to grouped decode (K + V columns at
    /// 4 bytes per f32 element per saved position).
    pub decode_attn_bytes_saved: u64,
    /// Step-time attribution: where each `step()` call's wall time goes,
    /// recorded around the phases of the engine loop (stream-credit
    /// service, admission/scheduling policy, prefill, decode). Under the
    /// sim clock these are deterministically zero — the virtual clock
    /// only advances at step boundaries — but on the system clock they
    /// decompose real host overhead.
    pub attr_stream_service: LatencyHistogram,
    pub attr_policy: LatencyHistogram,
    pub attr_admission: LatencyHistogram,
    pub attr_prefill: LatencyHistogram,
    pub attr_decode: LatencyHistogram,
    /// Request-lifecycle span aggregates (see [`crate::obs`]), recorded
    /// when a request finishes: time spent queued before admission,
    /// admission→first-token, decoding, and parked on backpressure.
    pub span_queue_wait: LatencyHistogram,
    pub span_prefill: LatencyHistogram,
    pub span_decode: LatencyHistogram,
    pub span_paused: LatencyHistogram,
    /// Per-tenant generated/cached token counters (recorded at request
    /// finish, exposed in the `{"stats": true}` snapshot).
    pub tenants: BTreeMap<String, TenantCounters>,
}

impl EngineMetrics {
    /// Fraction of decode rows that fell back to synchronized softmax.
    pub fn recompute_rate(&self) -> f64 {
        if self.decode_rows == 0 {
            0.0
        } else {
            self.recompute_rows as f64 / self.decode_rows as f64
        }
    }

    pub fn throughput_tokens_per_sec(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            0.0
        } else {
            self.tokens_generated as f64 / wall.as_secs_f64()
        }
    }

    /// Fold one finished request's usage into the per-tenant counters.
    /// Tenant ids are client-supplied strings, so cardinality is capped:
    /// once [`MAX_TRACKED_TENANTS`] distinct tenants exist, further ones
    /// aggregate under `"(other)"` (bounded memory, bounded stats size).
    pub fn record_finish(&mut self, tenant: &str, usage: Usage) {
        let key = if self.tenants.contains_key(tenant)
            || self.tenants.len() < MAX_TRACKED_TENANTS
        {
            tenant
        } else {
            OTHER_TENANTS
        };
        let t = self.tenants.entry(key.to_string()).or_default();
        t.requests_finished += 1;
        t.generated_tokens += usage.generated_tokens as u64;
        t.cached_prompt_tokens += usage.cached_prompt_tokens as u64;
    }

    /// Fold another engine's metrics into this one: counters sum,
    /// histograms merge bucket-wise, and per-tenant counters accumulate
    /// under the same [`MAX_TRACKED_TENANTS`] cardinality cap as
    /// [`record_finish`](Self::record_finish). Used by the fleet layer
    /// to aggregate N replicas into one stats surface.
    pub fn merge(&mut self, other: &EngineMetrics) {
        self.first_token.merge(&other.first_token);
        self.per_token.merge(&other.per_token);
        self.step.merge(&other.step);
        self.step_overhead.merge(&other.step_overhead);
        self.attr_stream_service.merge(&other.attr_stream_service);
        self.attr_policy.merge(&other.attr_policy);
        self.attr_admission.merge(&other.attr_admission);
        self.attr_prefill.merge(&other.attr_prefill);
        self.attr_decode.merge(&other.attr_decode);
        self.span_queue_wait.merge(&other.span_queue_wait);
        self.span_prefill.merge(&other.span_prefill);
        self.span_decode.merge(&other.span_decode);
        self.span_paused.merge(&other.span_paused);
        self.prefill_steps += other.prefill_steps;
        self.decode_steps += other.decode_steps;
        self.tokens_generated += other.tokens_generated;
        self.requests_finished += other.requests_finished;
        self.requests_admitted += other.requests_admitted;
        self.recompute_rows += other.recompute_rows;
        self.decode_rows += other.decode_rows;
        self.kv_rebuilds += other.kv_rebuilds;
        self.kv_inserts += other.kv_inserts;
        self.prefix_lookups += other.prefix_lookups;
        self.prefix_hits += other.prefix_hits;
        self.prefix_tokens_reused += other.prefix_tokens_reused;
        self.prefill_tokens_computed += other.prefill_tokens_computed;
        self.prefix_blocks_evicted += other.prefix_blocks_evicted;
        self.preemptions += other.preemptions;
        self.cancellations += other.cancellations;
        self.dedup_hits += other.dedup_hits;
        self.quota_rejections += other.quota_rejections;
        self.backpressure_pauses += other.backpressure_pauses;
        self.backpressure_resumes += other.backpressure_resumes;
        self.backpressure_drops += other.backpressure_drops;
        self.stream_idle_drops += other.stream_idle_drops;
        self.client_disconnects += other.client_disconnects;
        self.grouped_decode_steps += other.grouped_decode_steps;
        self.grouped_groups_formed += other.grouped_groups_formed;
        self.grouped_rows += other.grouped_rows;
        self.decode_attn_positions_total += other.decode_attn_positions_total;
        self.decode_attn_positions_saved += other.decode_attn_positions_saved;
        self.decode_attn_flops_saved += other.decode_attn_flops_saved;
        self.decode_attn_bytes_saved += other.decode_attn_bytes_saved;
        for (tenant, c) in &other.tenants {
            let key = if self.tenants.contains_key(tenant)
                || self.tenants.len() < MAX_TRACKED_TENANTS
            {
                tenant.as_str()
            } else {
                OTHER_TENANTS
            };
            let t = self.tenants.entry(key.to_string()).or_default();
            t.requests_finished += c.requests_finished;
            t.generated_tokens += c.generated_tokens;
            t.cached_prompt_tokens += c.cached_prompt_tokens;
        }
    }

    /// Fraction of prefix-cache lookups that hit.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Fraction of prompt tokens served from the prefix cache.
    pub fn prefill_token_savings(&self) -> f64 {
        let total = self.prefix_tokens_reused + self.prefill_tokens_computed;
        if total == 0 {
            0.0
        } else {
            self.prefix_tokens_reused as f64 / total as f64
        }
    }

    /// Fraction of the logical decode-attention span whose compute was
    /// reused from a group's shared prefix (0.0 with grouping off).
    pub fn decode_attn_savings_rate(&self) -> f64 {
        if self.decode_attn_positions_total == 0 {
            0.0
        } else {
            self.decode_attn_positions_saved as f64 / self.decode_attn_positions_total as f64
        }
    }

    /// Snapshot as JSON for the server stats path.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prefill_steps", Json::Num(self.prefill_steps as f64)),
            ("decode_steps", Json::Num(self.decode_steps as f64)),
            ("tokens_generated", Json::Num(self.tokens_generated as f64)),
            ("requests_admitted", Json::Num(self.requests_admitted as f64)),
            ("requests_finished", Json::Num(self.requests_finished as f64)),
            ("recompute_rate", Json::Num(self.recompute_rate())),
            ("kv_rebuilds", Json::Num(self.kv_rebuilds as f64)),
            ("kv_inserts", Json::Num(self.kv_inserts as f64)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("cancellations", Json::Num(self.cancellations as f64)),
            ("dedup_hits", Json::Num(self.dedup_hits as f64)),
            (
                "quota_rejections",
                Json::Num(self.quota_rejections as f64),
            ),
            (
                "backpressure_pauses",
                Json::Num(self.backpressure_pauses as f64),
            ),
            (
                "backpressure_resumes",
                Json::Num(self.backpressure_resumes as f64),
            ),
            (
                "backpressure_drops",
                Json::Num(self.backpressure_drops as f64),
            ),
            (
                "stream_idle_drops",
                Json::Num(self.stream_idle_drops as f64),
            ),
            (
                "client_disconnects",
                Json::Num(self.client_disconnects as f64),
            ),
            (
                "grouped_decode_steps",
                Json::Num(self.grouped_decode_steps as f64),
            ),
            (
                "grouped_groups_formed",
                Json::Num(self.grouped_groups_formed as f64),
            ),
            ("grouped_rows", Json::Num(self.grouped_rows as f64)),
            (
                "decode_attn_positions_total",
                Json::Num(self.decode_attn_positions_total as f64),
            ),
            (
                "decode_attn_positions_saved",
                Json::Num(self.decode_attn_positions_saved as f64),
            ),
            (
                "decode_attn_flops_saved",
                Json::Num(self.decode_attn_flops_saved as f64),
            ),
            (
                "decode_attn_bytes_saved",
                Json::Num(self.decode_attn_bytes_saved as f64),
            ),
            (
                "decode_attn_savings_rate",
                Json::Num(self.decode_attn_savings_rate()),
            ),
            (
                "tenants",
                Json::Obj(
                    self.tenants
                        .iter()
                        .map(|(k, t)| (k.clone(), t.to_json()))
                        .collect(),
                ),
            ),
            ("prefix_lookups", Json::Num(self.prefix_lookups as f64)),
            ("prefix_hits", Json::Num(self.prefix_hits as f64)),
            ("prefix_hit_rate", Json::Num(self.prefix_hit_rate())),
            (
                "prefix_tokens_reused",
                Json::Num(self.prefix_tokens_reused as f64),
            ),
            (
                "prefill_tokens_computed",
                Json::Num(self.prefill_tokens_computed as f64),
            ),
            (
                "prefix_blocks_evicted",
                Json::Num(self.prefix_blocks_evicted as f64),
            ),
            (
                "step_mean_us",
                Json::Num(self.step.mean().as_micros() as f64),
            ),
            (
                "per_token_p50_us",
                Json::Num(self.per_token.percentile(0.5).as_micros() as f64),
            ),
            (
                "first_token_p50_us",
                Json::Num(self.first_token.percentile(0.5).as_micros() as f64),
            ),
            ("step_p50_us", pct_us(&self.step, 0.5)),
            ("step_p90_us", pct_us(&self.step, 0.9)),
            ("step_p99_us", pct_us(&self.step, 0.99)),
            ("step_min_us", Json::Num(self.step.min().as_micros() as f64)),
            ("per_token_p90_us", pct_us(&self.per_token, 0.9)),
            ("per_token_p99_us", pct_us(&self.per_token, 0.99)),
            (
                "per_token_min_us",
                Json::Num(self.per_token.min().as_micros() as f64),
            ),
            ("first_token_p90_us", pct_us(&self.first_token, 0.9)),
            ("first_token_p99_us", pct_us(&self.first_token, 0.99)),
            (
                "first_token_min_us",
                Json::Num(self.first_token.min().as_micros() as f64),
            ),
            (
                "step_overhead_mean_us",
                Json::Num(self.step_overhead.mean().as_micros() as f64),
            ),
            ("step_overhead_p99_us", pct_us(&self.step_overhead, 0.99)),
            (
                "histograms",
                Json::obj(vec![
                    ("first_token", self.first_token.to_json()),
                    ("per_token", self.per_token.to_json()),
                    ("step", self.step.to_json()),
                    ("step_overhead", self.step_overhead.to_json()),
                    ("attr_stream_service", self.attr_stream_service.to_json()),
                    ("attr_policy", self.attr_policy.to_json()),
                    ("attr_admission", self.attr_admission.to_json()),
                    ("attr_prefill", self.attr_prefill.to_json()),
                    ("attr_decode", self.attr_decode.to_json()),
                    ("span_queue_wait", self.span_queue_wait.to_json()),
                    ("span_prefill", self.span_prefill.to_json()),
                    ("span_decode", self.span_decode.to_json()),
                    ("span_paused", self.span_paused.to_json()),
                ]),
            ),
        ])
    }
}

/// Percentile of `h` at `p`, in microseconds, as a JSON number.
fn pct_us(h: &LatencyHistogram, p: f64) -> Json {
    Json::Num(h.percentile(p).as_micros() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic() {
        let mut h = LatencyHistogram::default();
        for ms in [1u64, 2, 3, 4, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(10));
        assert!(h.percentile(0.5) <= Duration::from_millis(5));
        assert_eq!(h.max(), Duration::from_millis(100));
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 37));
        }
        assert!(h.percentile(0.5) <= h.percentile(0.9));
        assert!(h.percentile(0.9) <= h.percentile(0.999));
    }

    #[test]
    fn percentile_exact_for_single_valued_histograms() {
        // Every sample is the same value: interpolation must clamp to
        // the observed min/max and report it exactly, not the bucket's
        // upper bound (37us sits strictly inside a log bucket).
        let mut h = LatencyHistogram::default();
        for _ in 0..500 {
            h.record(Duration::from_micros(37));
        }
        for p in [0.0, 0.01, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(h.percentile(p), Duration::from_micros(37), "p={p}");
        }
    }

    #[test]
    fn percentile_pinned_at_exact_bucket_edge() {
        // 1us is precisely the first bucket bound: the sample lands in
        // the first bucket and the estimate must be exactly 1us at
        // every percentile.
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(1));
        for p in [0.0, 0.5, 1.0] {
            assert_eq!(h.percentile(p), Duration::from_micros(1), "p={p}");
        }
    }

    #[test]
    fn percentile_interpolates_and_stays_within_observed_range() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_millis(1));
        h.record(Duration::from_millis(100));
        // p50 selects the 1ms sample's bucket; the estimate may not
        // regress below the sample or escape past the next log bound
        // (factor 1.47).
        let p50 = h.percentile(0.5);
        assert!(p50 >= Duration::from_millis(1), "p50={p50:?}");
        assert!(p50 <= Duration::from_micros(1500), "p50={p50:?}");
        // The top of the distribution is clamped to the observed max.
        assert_eq!(h.percentile(1.0), Duration::from_millis(100));
        for i in 0..=20 {
            let p = i as f64 / 20.0;
            let v = h.percentile(p);
            assert!(v >= h.min() && v <= h.max(), "p={p} v={v:?}");
        }
    }

    #[test]
    fn histogram_json_exports_raw_buckets() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(10));
        h.record(Duration::from_millis(5));
        let j = crate::util::json::parse(&h.to_json().to_string()).unwrap();
        let bounds = j.req_arr("bounds").unwrap();
        let counts = j.req_arr("counts").unwrap();
        assert_eq!(counts.len(), bounds.len() + 1, "one overflow bucket");
        let total: usize = counts.iter().filter_map(|c| c.as_usize()).sum();
        assert_eq!(total, 2);
        assert_eq!(j.get("count").and_then(|c| c.as_usize()), Some(2));
        assert_eq!(j.get("min_us").and_then(|c| c.as_usize()), Some(10));
        assert_eq!(j.get("max_us").and_then(|c| c.as_usize()), Some(5000));
    }

    #[test]
    fn metrics_json_exposes_tail_percentiles_and_histograms() {
        let mut m = EngineMetrics::default();
        for ms in [1u64, 2, 4, 8, 50] {
            m.first_token.record(Duration::from_millis(ms));
            m.per_token.record(Duration::from_millis(ms));
            m.step.record(Duration::from_millis(ms));
        }
        let back = crate::util::json::parse(&m.to_json().to_string()).unwrap();
        for key in [
            "first_token_p90_us",
            "first_token_p99_us",
            "first_token_min_us",
            "per_token_p90_us",
            "per_token_p99_us",
            "step_p50_us",
            "step_p90_us",
            "step_p99_us",
            "step_min_us",
            "step_overhead_mean_us",
        ] {
            assert!(back.get(key).is_some(), "missing {key}");
        }
        let hists = back.field("histograms").unwrap();
        for key in [
            "first_token",
            "per_token",
            "step",
            "step_overhead",
            "attr_stream_service",
            "attr_policy",
            "attr_admission",
            "attr_prefill",
            "attr_decode",
            "span_queue_wait",
            "span_prefill",
            "span_decode",
            "span_paused",
        ] {
            assert!(hists.get(key).is_some(), "missing histograms.{key}");
        }
        // p50 <= p90 <= p99 in the flat export too.
        let p50 = back.get("step_p50_us").and_then(|j| j.as_f64()).unwrap();
        let p90 = back.get("step_p90_us").and_then(|j| j.as_f64()).unwrap();
        let p99 = back.get("step_p99_us").and_then(|j| j.as_f64()).unwrap();
        assert!(p50 <= p90 && p90 <= p99);
    }

    #[test]
    fn histogram_merge_matches_recording_into_one() {
        // Merging b into a must be indistinguishable from recording all
        // samples into a single histogram.
        let samples_a = [3u64, 17, 240, 9_000];
        let samples_b = [1u64, 17, 55_000, 2, 2];
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        let mut both = LatencyHistogram::default();
        for &us in &samples_a {
            a.record(Duration::from_micros(us));
            both.record(Duration::from_micros(us));
        }
        for &us in &samples_b {
            b.record(Duration::from_micros(us));
            both.record(Duration::from_micros(us));
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.sum_us(), both.sum_us());
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
        assert_eq!(a.to_json().to_string(), both.to_json().to_string());
        for p in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.percentile(p), both.percentile(p), "p={p}");
        }
    }

    #[test]
    fn histogram_merge_with_empty_is_identity() {
        let mut a = LatencyHistogram::default();
        a.record(Duration::from_micros(42));
        let before = a.to_json().to_string();
        a.merge(&LatencyHistogram::default());
        assert_eq!(a.to_json().to_string(), before, "merging empty changes nothing");

        // Empty <- non-empty adopts the other side's min/max (the
        // u64::MAX sentinel must not leak through the fold).
        let mut e = LatencyHistogram::default();
        e.merge(&a);
        assert_eq!(e.min(), Duration::from_micros(42));
        assert_eq!(e.max(), Duration::from_micros(42));
        assert_eq!(e.count(), 1);
    }

    #[test]
    fn histogram_merge_disjoint_ranges_keeps_minmax_clamps_correct() {
        // One histogram holds only tiny samples, the other only huge
        // ones; after the merge the percentile clamps must track the
        // *global* observed range, not either side's.
        let mut tiny = LatencyHistogram::default();
        for _ in 0..10 {
            tiny.record(Duration::from_micros(3));
        }
        let mut huge = LatencyHistogram::default();
        for _ in 0..10 {
            huge.record(Duration::from_secs(2));
        }
        let mut merged = tiny.clone();
        merged.merge(&huge);
        assert_eq!(merged.count(), 20);
        assert_eq!(merged.min(), Duration::from_micros(3));
        assert_eq!(merged.max(), Duration::from_secs(2));
        // p0 / p100 pin to the observed extremes.
        assert_eq!(merged.percentile(0.0), Duration::from_micros(3));
        assert_eq!(merged.percentile(1.0), Duration::from_secs(2));
        // The lower half resolves to the tiny side exactly (single
        // value within its bucket, clamped by observed min); the upper
        // half interpolates inside the huge side's bucket, bounded by
        // the observed max.
        assert_eq!(merged.percentile(0.25), Duration::from_micros(3));
        let p90 = merged.percentile(0.9);
        assert!(
            p90 > Duration::from_secs(1) && p90 <= merged.max(),
            "p90={p90:?}"
        );
        // Merge order must not matter for any summary stat.
        let mut other_way = huge.clone();
        other_way.merge(&tiny);
        assert_eq!(
            merged.to_json().to_string(),
            other_way.to_json().to_string(),
            "merge must be commutative"
        );
        // And the merged result equals recording everything into one.
        let mut both = LatencyHistogram::default();
        for _ in 0..10 {
            both.record(Duration::from_micros(3));
        }
        for _ in 0..10 {
            both.record(Duration::from_secs(2));
        }
        for p in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0] {
            assert_eq!(merged.percentile(p), both.percentile(p), "p={p}");
        }
    }

    #[test]
    fn histogram_merge_overlapping_bucket_keeps_observed_bounds() {
        // Two samples land in the same log bucket but with different
        // exact values; the merged histogram's interpolation must stay
        // inside the union of observed values.
        let mut a = LatencyHistogram::default();
        a.record(Duration::from_micros(150));
        let mut b = LatencyHistogram::default();
        b.record(Duration::from_micros(170));
        a.merge(&b);
        assert_eq!(a.min(), Duration::from_micros(150));
        assert_eq!(a.max(), Duration::from_micros(170));
        for i in 0..=10 {
            let p = i as f64 / 10.0;
            let v = a.percentile(p);
            assert!(v >= a.min() && v <= a.max(), "p={p} v={v:?}");
        }
    }

    #[test]
    fn metrics_merge_sums_counters_histograms_and_tenants() {
        let usage = |cached: usize, generated: usize| Usage {
            prompt_tokens: cached + 2,
            cached_prompt_tokens: cached,
            prefill_tokens: 2,
            generated_tokens: generated,
        };
        let mut a = EngineMetrics::default();
        a.tokens_generated = 10;
        a.requests_finished = 2;
        a.prefix_lookups = 4;
        a.prefix_hits = 1;
        a.quota_rejections = 1;
        a.step.record(Duration::from_millis(2));
        a.span_decode.record(Duration::from_millis(8));
        a.record_finish("acme", usage(8, 6));

        let mut b = EngineMetrics::default();
        b.tokens_generated = 5;
        b.requests_finished = 1;
        b.prefix_lookups = 2;
        b.prefix_hits = 2;
        b.step.record(Duration::from_millis(4));
        b.record_finish("acme", usage(0, 3));
        b.record_finish("globex", usage(4, 2));

        a.merge(&b);
        assert_eq!(a.tokens_generated, 15);
        assert_eq!(a.requests_finished, 3);
        assert_eq!(a.prefix_lookups, 6);
        assert_eq!(a.prefix_hits, 3);
        assert_eq!(a.quota_rejections, 1);
        assert_eq!(a.step.count(), 2);
        assert_eq!(a.span_decode.count(), 1);
        assert!((a.prefix_hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(a.tenants["acme"].requests_finished, 2);
        assert_eq!(a.tenants["acme"].generated_tokens, 9);
        assert_eq!(a.tenants["globex"].cached_prompt_tokens, 4);
    }

    #[test]
    fn metrics_merge_respects_tenant_cardinality_cap() {
        let u = Usage {
            prompt_tokens: 2,
            cached_prompt_tokens: 0,
            prefill_tokens: 2,
            generated_tokens: 1,
        };
        let mut a = EngineMetrics::default();
        for i in 0..MAX_TRACKED_TENANTS {
            a.record_finish(&format!("a-{i}"), u);
        }
        let mut b = EngineMetrics::default();
        for i in 0..40 {
            b.record_finish(&format!("b-{i}"), u);
        }
        a.merge(&b);
        assert!(
            a.tenants.len() <= MAX_TRACKED_TENANTS + 1,
            "merge must stay bounded, got {}",
            a.tenants.len()
        );
        assert_eq!(a.tenants[OTHER_TENANTS].requests_finished, 40);
        // Total conservation across the fold.
        let total: u64 = a.tenants.values().map(|t| t.requests_finished).sum();
        assert_eq!(total, (MAX_TRACKED_TENANTS + 40) as u64);
    }

    #[test]
    fn recompute_rate() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.recompute_rate(), 0.0);
        m.decode_rows = 100;
        m.recompute_rows = 3;
        assert!((m.recompute_rate() - 0.03).abs() < 1e-12);
    }

    #[test]
    fn prefix_rates() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.prefix_hit_rate(), 0.0);
        assert_eq!(m.prefill_token_savings(), 0.0);
        m.prefix_lookups = 10;
        m.prefix_hits = 7;
        m.prefix_tokens_reused = 60;
        m.prefill_tokens_computed = 40;
        assert!((m.prefix_hit_rate() - 0.7).abs() < 1e-12);
        assert!((m.prefill_token_savings() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn metrics_json_snapshot_parses() {
        let m = EngineMetrics {
            prefix_lookups: 3,
            prefix_hits: 2,
            ..EngineMetrics::default()
        };
        let text = m.to_json().to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("prefix_hits").and_then(|j| j.as_usize()), Some(2));
    }

    #[test]
    fn per_tenant_counters_accumulate_and_serialize() {
        let mut m = EngineMetrics::default();
        let usage = |cached: usize, generated: usize| Usage {
            prompt_tokens: cached + 2,
            cached_prompt_tokens: cached,
            prefill_tokens: 2,
            generated_tokens: generated,
        };
        m.record_finish("acme", usage(8, 4));
        m.record_finish("acme", usage(0, 6));
        m.record_finish("globex", usage(16, 1));
        let acme = &m.tenants["acme"];
        assert_eq!(acme.requests_finished, 2);
        assert_eq!(acme.generated_tokens, 10);
        assert_eq!(acme.cached_prompt_tokens, 8);

        let back = crate::util::json::parse(&m.to_json().to_string()).unwrap();
        let tenants = back.field("tenants").unwrap();
        assert_eq!(
            tenants
                .field("acme")
                .unwrap()
                .get("generated_tokens")
                .and_then(|j| j.as_usize()),
            Some(10)
        );
        assert_eq!(
            tenants
                .field("globex")
                .unwrap()
                .get("cached_prompt_tokens")
                .and_then(|j| j.as_usize()),
            Some(16)
        );
    }

    #[test]
    fn tenant_cardinality_is_bounded() {
        let mut m = EngineMetrics::default();
        let u = Usage {
            prompt_tokens: 2,
            cached_prompt_tokens: 0,
            prefill_tokens: 2,
            generated_tokens: 1,
        };
        for i in 0..(MAX_TRACKED_TENANTS + 40) {
            m.record_finish(&format!("tenant-{i}"), u);
        }
        assert!(
            m.tenants.len() <= MAX_TRACKED_TENANTS + 1,
            "map must stay bounded, got {}",
            m.tenants.len()
        );
        let other = &m.tenants[OTHER_TENANTS];
        assert!(other.requests_finished >= 39, "overflow aggregates");
        // Known tenants keep accumulating individually.
        m.record_finish("tenant-0", u);
        assert_eq!(m.tenants["tenant-0"].requests_finished, 2);
    }
}
