//! Fleet serving: N engine replicas behind one cache-aware router.
//!
//! FlashDecoding++ makes one engine fast; serving at capacity runs
//! many. [`Fleet`] owns `n_replicas` [`EngineCore`]s and routes each
//! request with a per-replica [`RadixMirror`] — an approximate,
//! router-side copy of that replica's prefix cache, maintained from
//! placements and the replica's admission trace — so requests land
//! where their prompt prefix is already resident and prefill compute
//! is skipped (the same win the in-engine prefix cache gives, lifted
//! across the fleet). [`RoutePolicy::CacheAware`] trades the mirror
//! match against load imbalance under `cache_vs_balance`;
//! `benches/fleet_routing.rs` shows it beating round-robin and
//! least-loaded on the Zipf shared-prefix workload.
//!
//! Replicas have a health lifecycle (`Up` → `Draining` → `Dead`):
//! draining stops new placements and retires the replica once idle;
//! [`Fleet::kill`] retires it immediately and resubmits every
//! in-flight request to the survivors, so a replica death loses at
//! most the tokens already streamed — never a request. Cross-replica
//! tenant policy (fleet-wide max in-flight, token-rate refill buckets)
//! is enforced here, before placement, because no single replica can
//! see fleet-wide usage; rate rejections surface as
//! [`Error::RateLimit`] (`rate_limit_exceeded` on the wire).
//!
//! Everything is deterministic: the mirror is a `BTreeMap`, routing
//! ties break on the lowest replica index, kill resubmission walks
//! victims in id order, and a fleet of one is byte-identical — trace
//! fingerprints included — to a bare engine (`tests/fleet.rs` proves
//! both properties over the simtest seed matrix).

use std::collections::{BTreeMap, HashMap};
use std::mem;
use std::ops::Bound::{Excluded, Unbounded};
use std::time::Duration;

use crate::api::{
    GenRequest, InferenceEngine, RequestId, SubmissionHandle, TryRecvError, Wakeup,
};
use crate::config::{EngineConfig, FleetConfig, RoutePolicy};
use crate::core::{Backend, EngineCore, TraceEvent};
use crate::error::{Error, Result};
use crate::metrics::EngineMetrics;
use crate::router::encode_prompt;
use crate::scheduler::Action;
use crate::shard::ShardedBackend;
use crate::simengine::{SimBackend, SimSpec};
use crate::tokenizer::ByteTokenizer;
use crate::util::clock::Clock;
use crate::util::json::Json;

/// Replica `k` allocates request ids from base `k << REPLICA_ID_SHIFT`,
/// so ids are fleet-unique and name their replica. Replica 0 keeps
/// base 0: a fleet of one assigns exactly the ids a bare engine would,
/// which the N=1 transparency tests rely on.
pub const REPLICA_ID_SHIFT: u32 = 48;

// ---------------------------------------------------------------------
// Radix mirror
// ---------------------------------------------------------------------

/// Approximate router-side model of one replica's prefix cache.
///
/// Keys are block-aligned token prefixes (every `k * block_tokens`
/// prefix of an inserted prompt), values are last-touch ticks for LRU.
/// The mirror is fed from two places: optimistically at placement
/// (assume the prefill will populate the cache) and from the replica's
/// `Admitted` trace events (confirmation / LRU refresh). Eviction is
/// approximate — the engine does not trace its own evictions, so the
/// mirror runs the same capacity bound and LRU discipline on its side
/// and accepts occasional divergence; a stale entry only costs one
/// mis-routed request, never correctness.
///
/// A `BTreeMap` (not a hash map) keeps iteration — and therefore
/// eviction order and every routing decision downstream — fully
/// deterministic.
#[derive(Debug)]
pub struct RadixMirror {
    block_tokens: usize,
    cap: usize,
    entries: BTreeMap<Vec<u32>, u64>,
    tick: u64,
}

impl RadixMirror {
    pub fn new(block_tokens: usize, cap: usize) -> Self {
        RadixMirror {
            block_tokens: block_tokens.max(1),
            cap: cap.max(1),
            entries: BTreeMap::new(),
            tick: 0,
        }
    }

    /// Longest block-aligned prefix of `tokens` believed cached, in
    /// tokens. Non-mutating: probing every replica must not perturb
    /// LRU state, or routing would depend on probe order.
    pub fn probe(&self, tokens: &[u32]) -> usize {
        let blocks = tokens.len() / self.block_tokens;
        for k in (1..=blocks).rev() {
            let len = k * self.block_tokens;
            if self.entries.contains_key(&tokens[..len]) {
                return len;
            }
        }
        0
    }

    /// Record that `tokens` is (about to be) resident: upsert every
    /// block-aligned prefix at the current tick, then evict down to
    /// capacity.
    pub fn insert(&mut self, tokens: &[u32]) {
        self.tick += 1;
        let blocks = tokens.len() / self.block_tokens;
        for k in 1..=blocks {
            self.entries
                .insert(tokens[..k * self.block_tokens].to_vec(), self.tick);
        }
        self.evict_to_cap();
    }

    /// Tracked prefix entries (≈ cached blocks).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Evict least-recently-touched *leaves* (prefixes with no longer
    /// extension still tracked) until within capacity — mirroring the
    /// engine's own leaf-first block eviction. In a `BTreeMap`, every
    /// extension of key `K` sorts immediately after `K` and before any
    /// key that diverges from it, so `K` is a leaf iff its immediate
    /// successor does not start with `K`.
    fn evict_to_cap(&mut self) {
        while self.entries.len() > self.cap {
            let mut victim: Option<(u64, Vec<u32>)> = None;
            for (key, &tick) in &self.entries {
                let has_ext = self
                    .entries
                    .range::<[u32], _>((Excluded(&key[..]), Unbounded))
                    .next()
                    .map(|(succ, _)| succ.starts_with(key))
                    .unwrap_or(false);
                if !has_ext {
                    let better = match &victim {
                        None => true,
                        Some((vt, vk)) => tick < *vt || (tick == *vt && key < vk),
                    };
                    if better {
                        victim = Some((tick, key.clone()));
                    }
                }
            }
            match victim {
                Some((_, key)) => {
                    self.entries.remove(&key);
                }
                None => return, // unreachable: a finite map always has a leaf
            }
        }
    }
}

// ---------------------------------------------------------------------
// Tenant token-rate buckets
// ---------------------------------------------------------------------

/// Classic refill bucket on the fleet clock. A fresh tenant starts
/// with a full burst allowance.
#[derive(Debug, Clone, Copy)]
struct TokenBucket {
    level: f64,
    last: Duration,
}

impl TokenBucket {
    fn full(burst: f64, now: Duration) -> Self {
        TokenBucket { level: burst, last: now }
    }

    /// Refill for elapsed time and report whether `cost` is covered —
    /// without deducting it. Admission control checks affordability up
    /// front but only commits the charge once the request is actually
    /// accepted downstream; a routing failure or per-replica rejection
    /// must not consume tenant budget.
    fn refill_and_check(&mut self, cost: f64, now: Duration, rate: f64, burst: f64) -> bool {
        let dt = now.saturating_sub(self.last).as_secs_f64();
        self.level = (self.level + dt * rate).min(burst);
        self.last = now;
        self.level >= cost
    }

    /// Deduct a cost previously approved by [`Self::refill_and_check`].
    fn commit(&mut self, cost: f64) {
        self.level = (self.level - cost).max(0.0);
    }
}

// ---------------------------------------------------------------------
// Replicas
// ---------------------------------------------------------------------

/// Replica lifecycle: `Up` accepts placements; `Draining` finishes
/// in-flight work but takes nothing new, then retires; `Dead` is
/// retired (metrics snapshotted, core dropped).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    Up,
    Draining,
    Dead,
}

impl ReplicaHealth {
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaHealth::Up => "up",
            ReplicaHealth::Draining => "draining",
            ReplicaHealth::Dead => "dead",
        }
    }
}

/// Terminal counters captured when a replica retires, so fleet stats
/// keep naming the dead replica instead of silently shrinking.
#[derive(Debug, Clone, Copy, Default)]
struct ReplicaSnapshot {
    prefix_hits: u64,
    prefix_lookups: u64,
    tokens_generated: u64,
    requests_finished: u64,
}

struct Replica<B: Backend> {
    core: Option<EngineCore<B>>,
    health: ReplicaHealth,
    mirror: RadixMirror,
    /// Trace events drained from the core and not yet handed to
    /// [`Fleet::take_trace_of`]. Only populated when fleet tracing is
    /// armed; the observe pass itself always runs (the mirror and the
    /// in-flight registry are fed from it).
    pending_trace: Vec<TraceEvent>,
    /// Requests this replica was chosen for (routing decisions).
    routed: u64,
    snapshot: Option<ReplicaSnapshot>,
}

impl<B: Backend> Replica<B> {
    fn live(&self) -> Option<&EngineCore<B>> {
        self.core.as_ref()
    }
}

/// Point-in-time view of one replica for operators and the example
/// drivers (health, load gauges, cache effectiveness, mirror size).
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub health: ReplicaHealth,
    pub routed: u64,
    pub queued: usize,
    pub running: usize,
    pub paused: usize,
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    pub tokens_generated: u64,
    pub requests_finished: u64,
    pub mirror_blocks: usize,
}

/// Fleet-side record of one in-flight request: enough to re-route it
/// if its replica dies mid-stream.
#[derive(Debug)]
struct InflightRec {
    replica: usize,
    tenant: String,
    req: GenRequest,
    prompt_tokens: Vec<u32>,
}

// ---------------------------------------------------------------------
// Fleet
// ---------------------------------------------------------------------

/// N engine replicas behind one cache-aware router; implements
/// [`InferenceEngine`] so the server, simtest harness, and examples
/// drive it exactly like a single engine. See the module docs for the
/// design.
pub struct Fleet<B: Backend> {
    fcfg: FleetConfig,
    replicas: Vec<Replica<B>>,
    /// Every request admitted to the fleet and not yet finished,
    /// keyed by the engine-assigned id.
    inflight: HashMap<RequestId, InflightRec>,
    tenant_inflight: HashMap<String, usize>,
    buckets: HashMap<String, TokenBucket>,
    clock: Clock,
    tokenizer: ByteTokenizer,
    /// Tightest per-replica `max_new_tokens` cap, used to bound the
    /// rate-bucket charge for requests that never set their own cap.
    max_new_cap: usize,
    rr_next: usize,
    trace_armed: bool,
    /// Cumulative metrics: retired replicas' totals plus every live
    /// core, re-merged after each mutating call (`metrics()` must
    /// return a reference, so the merge is kept materialized).
    merged: EngineMetrics,
    /// Totals of retired (dead) replicas — counters must survive the
    /// core being dropped.
    retired: EngineMetrics,
    quota_rejections: u64,
    rate_limited: u64,
    resubmitted: u64,
    routing_decisions: u64,
    routing_cache_hits: u64,
    /// Handles of kill-resubmitted requests the server-side owner
    /// never sees; serviced each step so PauseDecode streams drain.
    orphans: Vec<SubmissionHandle>,
}

impl<B: Backend> Fleet<B> {
    /// Assemble a fleet from pre-built replicas. Replica `k` gets the
    /// id base `k << REPLICA_ID_SHIFT` and always-on core tracing (the
    /// admission feed for its mirror); all replicas must share a clock
    /// (replica 0's is adopted as the fleet clock).
    pub fn from_replicas(cores: Vec<EngineCore<B>>, fcfg: FleetConfig) -> Result<Self> {
        fcfg.validate()?;
        if cores.len() != fcfg.n_replicas {
            return Err(Error::Config(format!(
                "fleet built with {} replicas but n_replicas={}",
                cores.len(),
                fcfg.n_replicas
            )));
        }
        let clock = cores[0].clock();
        let tokenizer = cores[0].tokenizer.clone();
        let max_new_cap = cores
            .iter()
            .map(|c| c.cfg.max_new_tokens)
            .min()
            .unwrap_or(usize::MAX);
        let mut replicas = Vec::with_capacity(cores.len());
        for (k, mut core) in cores.into_iter().enumerate() {
            core.set_seq_id_base((k as RequestId) << REPLICA_ID_SHIFT);
            core.enable_trace();
            let mirror = RadixMirror::new(core.cfg.kv_block_tokens, core.cfg.kv_total_blocks);
            replicas.push(Replica {
                core: Some(core),
                health: ReplicaHealth::Up,
                mirror,
                pending_trace: Vec::new(),
                routed: 0,
                snapshot: None,
            });
        }
        let mut fleet = Fleet {
            fcfg,
            replicas,
            inflight: HashMap::new(),
            tenant_inflight: HashMap::new(),
            buckets: HashMap::new(),
            clock,
            tokenizer,
            max_new_cap,
            rr_next: 0,
            trace_armed: false,
            merged: EngineMetrics::default(),
            retired: EngineMetrics::default(),
            quota_rejections: 0,
            rate_limited: 0,
            resubmitted: 0,
            routing_decisions: 0,
            routing_cache_hits: 0,
            orphans: Vec::new(),
        };
        fleet.refresh_merged();
        Ok(fleet)
    }

    pub fn config(&self) -> &FleetConfig {
        &self.fcfg
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn clock(&self) -> Clock {
        self.clock.clone()
    }

    pub fn health(&self, replica: usize) -> Option<ReplicaHealth> {
        self.replicas.get(replica).map(|r| r.health)
    }

    /// Arm fleet-level trace buffering: every replica's events are
    /// retained for [`Fleet::take_trace_of`]. Without this the observe
    /// pass still runs but events are dropped after bookkeeping.
    pub fn enable_trace(&mut self) {
        self.trace_armed = true;
    }

    /// Drain buffered trace events of one replica, observing its core
    /// first — so events emitted outside `step` (a cancel on an idle
    /// engine) are visible immediately, matching the bare engine's
    /// `take_trace` semantics.
    pub fn take_trace_of(&mut self, replica: usize) -> Vec<TraceEvent> {
        self.observe_replica(replica);
        self.replicas
            .get_mut(replica)
            .map(|r| mem::take(&mut r.pending_trace))
            .unwrap_or_default()
    }

    /// Operator view of one replica (live gauges or the terminal
    /// snapshot for dead replicas).
    pub fn replica_stats(&self, replica: usize) -> Option<ReplicaStats> {
        let r = self.replicas.get(replica)?;
        Some(match r.live() {
            Some(core) => ReplicaStats {
                health: r.health,
                routed: r.routed,
                queued: core.queued(),
                running: core.running(),
                paused: core.paused(),
                prefix_hits: core.metrics.prefix_hits,
                prefix_lookups: core.metrics.prefix_lookups,
                tokens_generated: core.metrics.tokens_generated,
                requests_finished: core.metrics.requests_finished,
                mirror_blocks: r.mirror.len(),
            },
            None => {
                let s = r.snapshot.unwrap_or_default();
                ReplicaStats {
                    health: r.health,
                    routed: r.routed,
                    queued: 0,
                    running: 0,
                    paused: 0,
                    prefix_hits: s.prefix_hits,
                    prefix_lookups: s.prefix_lookups,
                    tokens_generated: s.tokens_generated,
                    requests_finished: s.requests_finished,
                    mirror_blocks: 0,
                }
            }
        })
    }

    /// Direct access to a live replica's core (tests, audits).
    pub fn core(&self, replica: usize) -> Option<&EngineCore<B>> {
        self.replicas.get(replica).and_then(|r| r.live())
    }

    /// Requests resubmitted after replica deaths.
    pub fn resubmitted(&self) -> u64 {
        self.resubmitted
    }

    /// Placements made / placements that matched a cached prefix.
    pub fn routing_counts(&self) -> (u64, u64) {
        (self.routing_decisions, self.routing_cache_hits)
    }

    /// Requests rejected by the fleet tenant token-rate limiter.
    pub fn rate_limited(&self) -> u64 {
        self.rate_limited
    }

    // -- routing ------------------------------------------------------

    /// Pick a replica for a prompt: `(index, matched_prefix_tokens)`.
    /// `None` when no replica is `Up` with a live core.
    fn route(&mut self, prompt: &[u32]) -> Option<(usize, usize)> {
        let up: Vec<usize> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.health == ReplicaHealth::Up && r.core.is_some())
            .map(|(i, _)| i)
            .collect();
        if up.is_empty() {
            return None;
        }
        let load = |fleet: &Self, i: usize| -> usize {
            let core = fleet.replicas[i].live().expect("candidate is live");
            core.queued() + core.running() + core.paused()
        };
        match self.fcfg.policy {
            RoutePolicy::RoundRobin => {
                let i = up[self.rr_next % up.len()];
                self.rr_next = self.rr_next.wrapping_add(1);
                Some((i, self.replicas[i].mirror.probe(prompt)))
            }
            RoutePolicy::LeastLoaded => {
                let i = up
                    .iter()
                    .copied()
                    .min_by_key(|&i| (load(self, i), i))
                    .expect("non-empty candidate set");
                Some((i, self.replicas[i].mirror.probe(prompt)))
            }
            RoutePolicy::CacheAware => {
                let w = self.fcfg.cache_vs_balance;
                let max_load = up.iter().map(|&i| load(self, i)).max().unwrap_or(0);
                let mut best: Option<(f64, usize, usize)> = None;
                for &i in &up {
                    let matched = self.replicas[i].mirror.probe(prompt);
                    let hit = if prompt.is_empty() {
                        0.0
                    } else {
                        matched as f64 / prompt.len() as f64
                    };
                    let balance = load(self, i) as f64 / (max_load as f64 + 1.0);
                    let score = w * hit - (1.0 - w) * balance;
                    // Strict `>` keeps the lowest index on ties: the
                    // decision must be reproducible across runs.
                    if best.map(|(s, _, _)| score > s).unwrap_or(true) {
                        best = Some((score, i, matched));
                    }
                }
                best.map(|(_, i, matched)| (i, matched))
            }
        }
    }

    /// Admission + placement. `charge` is false for kill resubmission:
    /// the request already paid quota and rate on first entry.
    fn submit_routed(&mut self, req: GenRequest, charge: bool) -> Result<SubmissionHandle> {
        let tenant = if req.tenant.is_empty() {
            "default".to_string()
        } else {
            req.tenant.clone()
        };
        if charge {
            let cap = self.fcfg.tenant_max_inflight;
            if cap > 0 && self.tenant_inflight.get(&tenant).copied().unwrap_or(0) >= cap {
                self.quota_rejections += 1;
                return Err(Error::Quota(format!(
                    "tenant '{tenant}' at fleet max_inflight {cap}"
                )));
            }
        }
        let prompt_tokens = encode_prompt(&self.tokenizer, &req.prompt)?;
        // Rate limiting is check-then-commit: affordability is decided
        // here (so an over-budget tenant is rejected before routing),
        // but the budget is only consumed after the replica accepts the
        // request. Work rejected downstream — no healthy replica, or a
        // per-replica quota/validation failure — must not charge the
        // tenant for tokens that were never admitted.
        let mut pending_charge = None;
        if charge && self.fcfg.tenant_token_rate > 0.0 {
            let now = self.clock.now();
            let (rate, burst) = (self.fcfg.tenant_token_rate, self.fcfg.tenant_token_burst);
            let cost = (prompt_tokens.len() + req.max_new_tokens.min(self.max_new_cap)) as f64;
            let bucket = self
                .buckets
                .entry(tenant.clone())
                .or_insert_with(|| TokenBucket::full(burst, now));
            if !bucket.refill_and_check(cost, now, rate, burst) {
                self.rate_limited += 1;
                return Err(Error::RateLimit(format!(
                    "tenant '{tenant}' exceeds {rate} tokens/s (burst {burst})"
                )));
            }
            pending_charge = Some(cost);
        }
        let (replica, matched) = self
            .route(&prompt_tokens)
            .ok_or_else(|| Error::Request("no healthy replica available".into()))?;
        let handle = self.replicas[replica]
            .core
            .as_mut()
            .expect("routed replica is live")
            .submit(req.clone())?;
        if let Some(cost) = pending_charge {
            self.buckets
                .get_mut(&tenant)
                .expect("bucket created during the affordability check")
                .commit(cost);
        }
        self.routing_decisions += 1;
        if matched > 0 {
            self.routing_cache_hits += 1;
        }
        self.replicas[replica].routed += 1;
        self.replicas[replica].mirror.insert(&prompt_tokens);
        self.inflight.insert(
            handle.id,
            InflightRec {
                replica,
                tenant: tenant.clone(),
                req,
                prompt_tokens,
            },
        );
        *self.tenant_inflight.entry(tenant).or_insert(0) += 1;
        Ok(handle)
    }

    // -- lifecycle ----------------------------------------------------

    /// Stop placing new work on a replica; it retires (metrics
    /// snapshotted, core dropped) as soon as it goes idle.
    pub fn drain(&mut self, replica: usize) -> Result<()> {
        let r = self
            .replicas
            .get(replica)
            .ok_or_else(|| Error::Request(format!("no replica {replica}")))?;
        match r.health {
            ReplicaHealth::Dead => Err(Error::Request(format!("replica {replica} is dead"))),
            ReplicaHealth::Draining => Ok(()),
            ReplicaHealth::Up => {
                self.replicas[replica].health = ReplicaHealth::Draining;
                // A draining replica takes no placements, so its
                // routing hints are dead weight at best — and a stale
                // mirror would bias scoring if the replica were ever
                // considered again. Clear now, not at retirement.
                self.replicas[replica].mirror.clear();
                let idle = self.replicas[replica]
                    .live()
                    .map(|c| c.is_idle())
                    .unwrap_or(true);
                if idle {
                    self.retire_replica(replica);
                }
                self.refresh_merged();
                Ok(())
            }
        }
    }

    /// Kill a replica now: retire it and resubmit every in-flight
    /// request it held to the survivors. Returns `(old_id, handle)`
    /// per victim so the owner can rebind streams; tokens already
    /// streamed from the dead replica are lost (the request restarts),
    /// but no request is dropped and none runs twice.
    pub fn kill(&mut self, replica: usize) -> Result<Vec<(RequestId, SubmissionHandle)>> {
        let r = self
            .replicas
            .get(replica)
            .ok_or_else(|| Error::Request(format!("no replica {replica}")))?;
        if r.health == ReplicaHealth::Dead {
            return Err(Error::Request(format!("replica {replica} is dead")));
        }
        self.retire_replica(replica);
        // HashMap iteration order is arbitrary; sort victims so
        // resubmission order (and thus routing) is deterministic.
        let mut victims: Vec<RequestId> = self
            .inflight
            .iter()
            .filter(|(_, rec)| rec.replica == replica)
            .map(|(&id, _)| id)
            .collect();
        victims.sort_unstable();
        let mut moved = Vec::with_capacity(victims.len());
        for id in victims {
            let rec = self.inflight.remove(&id).expect("victim is inflight");
            if let Some(n) = self.tenant_inflight.get_mut(&rec.tenant) {
                *n = n.saturating_sub(1);
                if *n == 0 {
                    self.tenant_inflight.remove(&rec.tenant);
                }
            }
            let handle = self.submit_routed(rec.req, false)?;
            self.resubmitted += 1;
            moved.push((id, handle));
        }
        self.refresh_merged();
        Ok(moved)
    }

    /// Final observe + metrics snapshot, then drop the core.
    fn retire_replica(&mut self, replica: usize) {
        self.observe_replica(replica);
        if let Some(core) = self.replicas[replica].core.take() {
            self.replicas[replica].snapshot = Some(ReplicaSnapshot {
                prefix_hits: core.metrics.prefix_hits,
                prefix_lookups: core.metrics.prefix_lookups,
                tokens_generated: core.metrics.tokens_generated,
                requests_finished: core.metrics.requests_finished,
            });
            self.retired.merge(&core.metrics);
        }
        self.replicas[replica].health = ReplicaHealth::Dead;
        self.replicas[replica].mirror.clear();
    }

    /// Retire any draining replica that has gone idle.
    fn reap_drained(&mut self) {
        for k in 0..self.replicas.len() {
            if self.replicas[k].health == ReplicaHealth::Draining
                && self.replicas[k].live().map(|c| c.is_idle()).unwrap_or(true)
            {
                self.retire_replica(k);
            }
        }
    }

    /// Drain one replica's core trace and fold it into fleet state:
    /// `Finished` retires the in-flight record (and its tenant slot),
    /// `Admitted` confirms/refreshes the prompt in the mirror. Events
    /// are buffered for [`Fleet::take_trace_of`] only when armed.
    fn observe_replica(&mut self, replica: usize) {
        let Some(r) = self.replicas.get_mut(replica) else {
            return;
        };
        let Some(core) = r.core.as_mut() else {
            return;
        };
        let events = core.take_trace();
        for ev in &events {
            match *ev {
                TraceEvent::Finished { id, .. } => {
                    if let Some(rec) = self.inflight.remove(&id) {
                        if let Some(n) = self.tenant_inflight.get_mut(&rec.tenant) {
                            *n = n.saturating_sub(1);
                            if *n == 0 {
                                self.tenant_inflight.remove(&rec.tenant);
                            }
                        }
                    }
                }
                TraceEvent::Admitted { id, .. } => {
                    // Routing hints are only kept for replicas that can
                    // still receive placements; admissions trickling in
                    // on a draining replica must not repopulate the
                    // mirror cleared at drain time.
                    if self.replicas[replica].health == ReplicaHealth::Up {
                        if let Some(rec) = self.inflight.get(&id) {
                            self.replicas[replica].mirror.insert(&rec.prompt_tokens);
                        }
                    }
                }
                _ => {}
            }
        }
        if self.trace_armed {
            self.replicas[replica].pending_trace.extend(events);
        }
    }

    /// Drain kill-orphaned streams so PauseDecode replicas never park
    /// forever on a reader that does not exist.
    fn service_orphans(&mut self) {
        self.orphans.retain(|h| loop {
            match h.events.try_recv() {
                Ok(crate::api::GenEvent::Token(_)) => {}
                Ok(crate::api::GenEvent::Finished { .. }) => return false,
                Err(TryRecvError::Empty) => return true,
                Err(TryRecvError::Closed) => return false,
            }
        });
    }

    /// Rebuild the materialized fleet metrics: retired totals plus
    /// every live core, plus fleet-level rejections.
    fn refresh_merged(&mut self) {
        let mut merged = self.retired.clone();
        for r in &self.replicas {
            if let Some(core) = r.live() {
                merged.merge(&core.metrics);
            }
        }
        merged.quota_rejections += self.quota_rejections;
        self.merged = merged;
    }

    fn sum_live<F: Fn(&EngineCore<B>) -> usize>(&self, f: F) -> usize {
        self.replicas.iter().filter_map(|r| r.live()).map(f).sum()
    }

    fn fleet_json(&self) -> Json {
        let count = |h: ReplicaHealth| {
            self.replicas.iter().filter(|r| r.health == h).count() as f64
        };
        Json::obj(vec![
            ("replicas", Json::Num(self.replicas.len() as f64)),
            ("replicas_up", Json::Num(count(ReplicaHealth::Up))),
            ("replicas_draining", Json::Num(count(ReplicaHealth::Draining))),
            ("replicas_dead", Json::Num(count(ReplicaHealth::Dead))),
            ("policy", Json::Str(self.fcfg.policy.as_str().to_string())),
            ("rate_limited", Json::Num(self.rate_limited as f64)),
            ("resubmitted", Json::Num(self.resubmitted as f64)),
            (
                "routing_decisions",
                Json::Num(self.routing_decisions as f64),
            ),
            (
                "routing_cache_hits",
                Json::Num(self.routing_cache_hits as f64),
            ),
        ])
    }

    fn replicas_json(&self) -> Json {
        let mut map = BTreeMap::new();
        for k in 0..self.replicas.len() {
            let s = self.replica_stats(k).expect("index in range");
            map.insert(
                k.to_string(),
                Json::obj(vec![
                    (
                        "up",
                        Json::Num(if s.health == ReplicaHealth::Up { 1.0 } else { 0.0 }),
                    ),
                    ("health", Json::Str(s.health.as_str().to_string())),
                    ("routed", Json::Num(s.routed as f64)),
                    ("queued", Json::Num(s.queued as f64)),
                    ("running", Json::Num(s.running as f64)),
                    ("paused", Json::Num(s.paused as f64)),
                    ("prefix_hits", Json::Num(s.prefix_hits as f64)),
                    ("prefix_lookups", Json::Num(s.prefix_lookups as f64)),
                    ("tokens_generated", Json::Num(s.tokens_generated as f64)),
                    (
                        "requests_finished",
                        Json::Num(s.requests_finished as f64),
                    ),
                    ("mirror_blocks", Json::Num(s.mirror_blocks as f64)),
                ]),
            );
        }
        Json::Obj(map)
    }
}

impl Fleet<SimBackend> {
    /// Build a sim fleet: `n_replicas` [`crate::simengine::SimEngine`]s
    /// sharing one manual clock, each from a clone of `cfg`.
    pub fn sim(cfg: EngineConfig, fcfg: FleetConfig, spec: SimSpec) -> Result<Self> {
        let clock = Clock::manual();
        let mut cores = Vec::with_capacity(fcfg.n_replicas);
        for _ in 0..fcfg.n_replicas {
            cores.push(EngineCore::with_clock(cfg.clone(), spec, clock.clone())?);
        }
        Fleet::from_replicas(cores, fcfg)
    }
}

impl Fleet<ShardedBackend<SimBackend>> {
    /// Build a sim fleet whose replicas each run a
    /// [`ShardedBackend<SimBackend>`] with `shards` simulated
    /// tensor-parallel lanes, sharing one manual clock. Sharding is
    /// invisible to scheduling, so this fleet must behave byte-for-byte
    /// like [`Fleet::sim`] under any scenario — `tests/fleet.rs`
    /// asserts it across the replica-kill matrix.
    pub fn sharded_sim(
        cfg: EngineConfig,
        fcfg: FleetConfig,
        spec: SimSpec,
        shards: usize,
    ) -> Result<Self> {
        let clock = Clock::manual();
        let mut cores = Vec::with_capacity(fcfg.n_replicas);
        for _ in 0..fcfg.n_replicas {
            cores.push(EngineCore::with_backend(
                ShardedBackend::new(SimBackend::new(spec), shards),
                cfg.clone(),
                clock.clone(),
            )?);
        }
        Fleet::from_replicas(cores, fcfg)
    }
}

impl<B: Backend> InferenceEngine for Fleet<B> {
    fn submit(&mut self, req: GenRequest) -> Result<SubmissionHandle> {
        let out = self.submit_routed(req, true);
        self.refresh_merged();
        out
    }

    fn set_wakeup(&mut self, wakeup: Wakeup) {
        for r in &mut self.replicas {
            if let Some(core) = r.core.as_mut() {
                core.set_wakeup(wakeup.clone());
            }
        }
    }

    /// One fleet step: step every non-idle live replica once, observe
    /// all traces, retire drained replicas. Returns the first
    /// non-`Idle` action so callers can tell whether work happened —
    /// with one replica this is exactly the bare engine's step.
    fn step(&mut self) -> Result<Action> {
        let mut action = Action::Idle;
        for k in 0..self.replicas.len() {
            let stepped = match self.replicas[k].core.as_mut() {
                Some(core) if !core.is_idle() => Some(core.step()?),
                _ => None,
            };
            if let Some(a) = stepped {
                if action == Action::Idle {
                    action = a;
                }
            }
            self.observe_replica(k);
        }
        self.service_orphans();
        self.reap_drained();
        self.refresh_merged();
        Ok(action)
    }

    fn cancel(&mut self, id: RequestId) -> Result<bool> {
        let Some(rec) = self.inflight.get(&id) else {
            return Ok(false); // unknown or already finished — engine parity
        };
        let replica = rec.replica;
        let out = match self.replicas[replica].core.as_mut() {
            Some(core) => core.cancel(id),
            None => Ok(false),
        };
        self.observe_replica(replica);
        self.refresh_merged();
        out
    }

    fn metrics(&self) -> &EngineMetrics {
        &self.merged
    }

    fn is_idle(&self) -> bool {
        self.replicas
            .iter()
            .filter_map(|r| r.live())
            .all(|c| c.is_idle())
    }

    fn queued(&self) -> usize {
        self.sum_live(|c| c.queued())
    }

    fn running(&self) -> usize {
        self.sum_live(|c| c.running())
    }

    fn paused(&self) -> usize {
        self.sum_live(|c| c.paused())
    }

    fn queue_depths(&self) -> Vec<(i32, usize)> {
        let mut by_priority: BTreeMap<i32, usize> = BTreeMap::new();
        for r in self.replicas.iter().filter_map(|r| r.live()) {
            for (p, n) in r.queue_depths() {
                *by_priority.entry(p).or_insert(0) += n;
            }
        }
        by_priority.into_iter().collect()
    }

    fn stats_json(&self) -> Json {
        let mut j = self.merged.to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("queued".to_string(), Json::Num(self.queued() as f64));
            map.insert("running".to_string(), Json::Num(self.running() as f64));
            map.insert("paused".to_string(), Json::Num(self.paused() as f64));
            let depths = self
                .queue_depths()
                .into_iter()
                .map(|(p, n)| (p.to_string(), Json::Num(n as f64)))
                .collect();
            map.insert("queue_depths".to_string(), Json::Obj(depths));
            map.insert("fleet".to_string(), self.fleet_json());
            map.insert("replicas".to_string(), self.replicas_json());
        }
        j
    }

    fn dump_flight(&self, n: usize) -> Json {
        let mut map = BTreeMap::new();
        for (k, r) in self.replicas.iter().enumerate() {
            let dump = match r.live() {
                Some(core) => core.dump_flight(n),
                None => Json::obj(vec![
                    ("capacity", Json::Num(0.0)),
                    ("recorded", Json::Num(0.0)),
                    ("dropped", Json::Num(0.0)),
                    ("entries", Json::Arr(Vec::new())),
                ]),
            };
            map.insert(k.to_string(), dump);
        }
        Json::obj(vec![("replicas", Json::Obj(map))])
    }

    fn admin(&mut self, verb: &str, arg: &Json) -> Option<Json> {
        match verb {
            "drain_replica" => {
                let Some(k) = arg.as_usize() else {
                    return Some(Json::obj(vec![(
                        "error",
                        Json::Str("drain_replica wants a replica index".into()),
                    )]));
                };
                Some(match self.drain(k) {
                    Ok(()) => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("replica", Json::Num(k as f64)),
                        (
                            "health",
                            Json::Str(
                                self.health(k)
                                    .map(|h| h.as_str())
                                    .unwrap_or("unknown")
                                    .to_string(),
                            ),
                        ),
                    ]),
                    Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
                })
            }
            "kill_replica" => {
                let Some(k) = arg.as_usize() else {
                    return Some(Json::obj(vec![(
                        "error",
                        Json::Str("kill_replica wants a replica index".into()),
                    )]));
                };
                Some(match self.kill(k) {
                    Ok(moved) => {
                        let n = moved.len();
                        // The original submitters' streams died with
                        // the replica; the fleet babysits the re-run
                        // streams so they cannot park a survivor.
                        self.orphans.extend(moved.into_iter().map(|(_, h)| h));
                        Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("replica", Json::Num(k as f64)),
                            ("resubmitted", Json::Num(n as f64)),
                        ])
                    }
                    Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
                })
            }
            "fleet_stats" => Some(self.stats_json()),
            _ => None,
        }
    }

    fn encode(&self, text: &str) -> Vec<u32> {
        self.tokenizer.encode(text)
    }

    fn decode(&self, tokens: &[u32]) -> String {
        self.tokenizer.decode(tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simengine::SimEngine;

    fn cfg() -> EngineConfig {
        EngineConfig {
            kv_block_tokens: 8,
            kv_total_blocks: 128,
            max_new_tokens: 16,
            prefix_cache: true,
            ..EngineConfig::default()
        }
    }

    fn fcfg(n: usize, policy: RoutePolicy) -> FleetConfig {
        FleetConfig {
            n_replicas: n,
            policy,
            ..FleetConfig::default()
        }
    }

    #[test]
    fn mirror_probes_block_aligned_prefixes() {
        let mut m = RadixMirror::new(4, 16);
        let p: Vec<u32> = (1..=12).collect();
        m.insert(&p);
        assert_eq!(m.len(), 3); // prefixes of 4, 8, 12 tokens
        assert_eq!(m.probe(&p), 12);
        assert_eq!(m.probe(&p[..6]), 4);
        assert_eq!(m.probe(&p[..3]), 0); // under one block
        assert_eq!(m.probe(&[7, 7, 7, 7]), 0);
    }

    #[test]
    fn mirror_evicts_lru_leaves_first() {
        let mut m = RadixMirror::new(4, 3);
        let p1: Vec<u32> = (1..=12).collect();
        m.insert(&p1); // three entries, at capacity
        m.insert(&[9, 9, 9, 9]); // over cap: the p1 12-token leaf is LRU
        assert_eq!(m.len(), 3);
        assert_eq!(m.probe(&p1), 8); // trunk survived, leaf gone
        assert_eq!(m.probe(&[9, 9, 9, 9]), 4);
        m.insert(&p1); // refresh p1 fully: now [9,9,9,9] is LRU
        assert_eq!(m.probe(&[9, 9, 9, 9]), 0);
        assert_eq!(m.probe(&p1), 12);
    }

    #[test]
    fn round_robin_cycles_up_replicas() {
        let mut f =
            Fleet::sim(cfg(), fcfg(3, RoutePolicy::RoundRobin), SimSpec::default()).unwrap();
        for p in ["alpha", "beta", "gamma"] {
            f.submit(GenRequest::text(p).max_new_tokens(4)).unwrap();
        }
        for k in 0..3 {
            assert_eq!(f.replica_stats(k).unwrap().routed, 1, "replica {k}");
        }
        f.run_to_completion().unwrap();
        assert_eq!(f.metrics().requests_finished, 3);
    }

    #[test]
    fn least_loaded_prefers_idle_replica() {
        let mut f =
            Fleet::sim(cfg(), fcfg(2, RoutePolicy::LeastLoaded), SimSpec::default()).unwrap();
        f.submit(GenRequest::text("first").max_new_tokens(4)).unwrap();
        f.submit(GenRequest::text("second").max_new_tokens(4)).unwrap();
        assert_eq!(f.replica_stats(0).unwrap().routed, 1);
        assert_eq!(f.replica_stats(1).unwrap().routed, 1);
        f.run_to_completion().unwrap();
    }

    #[test]
    fn cache_aware_routes_repeat_prompt_to_its_replica() {
        let mut f =
            Fleet::sim(cfg(), fcfg(2, RoutePolicy::CacheAware), SimSpec::default()).unwrap();
        // 31 chars + BOS = 32 tokens = 4 full blocks.
        let prompt = "system: shared preamble (0123)!";
        let h = f.submit(GenRequest::text(prompt).max_new_tokens(4)).unwrap();
        f.run_to_completion().unwrap();
        h.drain();
        // Same prompt again: the mirror match must beat load balance.
        f.submit(GenRequest::text(prompt).max_new_tokens(4)).unwrap();
        assert_eq!(f.replica_stats(0).unwrap().routed, 2);
        assert_eq!(f.replica_stats(1).unwrap().routed, 0);
        let (decisions, cache_hits) = f.routing_counts();
        assert_eq!(decisions, 2);
        assert_eq!(cache_hits, 1);
        f.run_to_completion().unwrap();
        // The replica-side prefix cache confirms the routing paid off.
        assert!(f.replica_stats(0).unwrap().prefix_hits >= 1);
    }

    #[test]
    fn drain_stops_placement_then_retires_when_idle() {
        let mut f =
            Fleet::sim(cfg(), fcfg(2, RoutePolicy::RoundRobin), SimSpec::default()).unwrap();
        let h0 = f.submit(GenRequest::text("long one").max_new_tokens(8)).unwrap();
        f.drain(0).unwrap();
        assert_eq!(f.health(0), Some(ReplicaHealth::Draining));
        // New work must land on the survivor while 0 drains.
        let h1 = f.submit(GenRequest::text("other").max_new_tokens(4)).unwrap();
        assert_eq!(f.replica_stats(1).unwrap().routed, 1);
        f.run_to_completion().unwrap();
        assert_eq!(f.health(0), Some(ReplicaHealth::Dead));
        assert!(f.core(0).is_none());
        // Retired counters survive the core being dropped.
        assert_eq!(f.metrics().requests_finished, 2);
        assert!(h0.drain().1.is_some());
        assert!(h1.drain().1.is_some());
        // Draining an idle replica retires it immediately; a dead fleet
        // refuses new work.
        f.drain(1).unwrap();
        assert_eq!(f.health(1), Some(ReplicaHealth::Dead));
        let err = f.submit(GenRequest::text("nope")).unwrap_err();
        assert!(matches!(err, Error::Request(_)));
        assert!(f.drain(0).is_err()); // already dead
    }

    #[test]
    fn kill_resubmits_inflight_to_survivors() {
        let mut f =
            Fleet::sim(cfg(), fcfg(2, RoutePolicy::RoundRobin), SimSpec::default()).unwrap();
        let mut handles = Vec::new();
        for p in ["a request", "b request", "c request", "d request"] {
            handles.push(f.submit(GenRequest::text(p).max_new_tokens(8)).unwrap());
        }
        f.step().unwrap();
        f.step().unwrap();
        let moved = f.kill(0).unwrap();
        assert_eq!(moved.len(), 2, "both of replica 0's requests move");
        assert_eq!(f.health(0), Some(ReplicaHealth::Dead));
        assert_eq!(f.resubmitted(), 2);
        for (old_id, handle) in &moved {
            assert_eq!(old_id >> REPLICA_ID_SHIFT, 0, "victims came from replica 0");
            assert_eq!(handle.id >> REPLICA_ID_SHIFT, 1, "rerouted to replica 1");
        }
        f.run_to_completion().unwrap();
        for (_, handle) in &moved {
            let (_, fin) = handle.drain();
            assert!(fin.is_some(), "resubmitted request must finish");
        }
        // Survivor finished its own two plus the two refugees.
        assert_eq!(f.replica_stats(1).unwrap().requests_finished, 4);
        assert!(f.kill(0).is_err(), "killing a dead replica is an error");
    }

    #[test]
    fn fleet_tenant_quota_is_cross_replica() {
        let mut fc = fcfg(2, RoutePolicy::RoundRobin);
        fc.tenant_max_inflight = 1;
        let mut f = Fleet::sim(cfg(), fc, SimSpec::default()).unwrap();
        let h = f
            .submit(GenRequest::text("one").tenant("acme").max_new_tokens(4))
            .unwrap();
        // Same tenant, would land on the *other* replica — still over
        // the fleet-wide cap.
        let err = f
            .submit(GenRequest::text("two").tenant("acme").max_new_tokens(4))
            .unwrap_err();
        assert!(matches!(err, Error::Quota(_)));
        assert_eq!(err.wire_code(), "quota_exceeded");
        assert_eq!(f.metrics().quota_rejections, 1);
        // Other tenants are unaffected.
        f.submit(GenRequest::text("two").tenant("globex").max_new_tokens(4))
            .unwrap();
        f.run_to_completion().unwrap();
        h.drain();
        // Slot freed: the tenant can submit again.
        f.submit(GenRequest::text("three").tenant("acme").max_new_tokens(4))
            .unwrap();
        f.run_to_completion().unwrap();
    }

    #[test]
    fn tenant_token_rate_bucket_refills_on_the_clock() {
        let mut fc = fcfg(2, RoutePolicy::RoundRobin);
        fc.tenant_token_rate = 10.0;
        fc.tenant_token_burst = 20.0;
        let mut f = Fleet::sim(cfg(), fc, SimSpec::default()).unwrap();
        // "abcd" = BOS + 4 bytes = 5 prompt tokens; cost 5 + 4 = 9.
        let req = || GenRequest::text("abcd").tenant("acme").max_new_tokens(4);
        f.submit(req()).unwrap(); // level 20 -> 11
        f.submit(req()).unwrap(); // level 11 -> 2
        let err = f.submit(req()).unwrap_err();
        assert!(matches!(err, Error::RateLimit(_)));
        assert_eq!(err.wire_code(), "rate_limit_exceeded");
        assert_eq!(f.rate_limited(), 1);
        // A different tenant has its own bucket.
        f.submit(GenRequest::text("abcd").tenant("globex").max_new_tokens(4))
            .unwrap();
        // Refill: 1 virtual second at 10 tok/s covers the next charge.
        f.clock().advance(Duration::from_secs(1));
        f.submit(req()).unwrap();
        f.run_to_completion().unwrap();
    }

    #[test]
    fn downstream_rejection_does_not_consume_rate_budget() {
        // One replica with a per-replica tenant quota of 1, plus a
        // fleet token-rate bucket. The second submit passes the
        // affordability check, routes, and is then rejected by the
        // replica's own quota — that rejection must not charge the
        // tenant's bucket, or admitted+rejected work double-bills and
        // a later legitimate request starves.
        let mut c = cfg();
        c.tenant_max_inflight = 1;
        let mut fc = fcfg(1, RoutePolicy::RoundRobin);
        fc.tenant_token_rate = 10.0;
        fc.tenant_token_burst = 20.0;
        let mut f = Fleet::sim(c, fc, SimSpec::default()).unwrap();
        // "abcd" = BOS + 4 bytes = 5 prompt tokens; cost 5 + 4 = 9.
        let req = || GenRequest::text("abcd").tenant("acme").max_new_tokens(4);
        f.submit(req()).unwrap(); // level 20 -> 11
        let err = f.submit(req()).unwrap_err();
        assert!(matches!(err, Error::Quota(_)), "replica quota, not rate: {err}");
        assert_eq!(f.rate_limited(), 0);
        // Finish the in-flight request to free the replica quota slot.
        f.run_to_completion().unwrap();
        // Level is still ~11 (virtual time barely advanced); cost 9
        // fits. Before the check/commit split the rejected submit had
        // already drained the bucket to 2 and this would rate-limit.
        f.submit(req()).unwrap();
        assert_eq!(f.rate_limited(), 0);
        f.run_to_completion().unwrap();
    }

    #[test]
    fn drain_and_kill_clear_the_replica_mirror() {
        let mut f =
            Fleet::sim(cfg(), fcfg(3, RoutePolicy::CacheAware), SimSpec::default()).unwrap();
        // 31 chars + BOS = 32 tokens = 4 full blocks of 8.
        let prompt = "system: shared preamble (0123)!";
        f.submit(GenRequest::text(prompt).max_new_tokens(4)).unwrap();
        assert!(
            f.replica_stats(0).unwrap().mirror_blocks > 0,
            "placement seeds the routing mirror"
        );
        // Drain while the request is still queued: the mirror must be
        // cleared immediately, not at retirement.
        f.drain(0).unwrap();
        assert_eq!(f.replica_stats(0).unwrap().health, ReplicaHealth::Draining);
        assert_eq!(f.replica_stats(0).unwrap().mirror_blocks, 0);
        // The admission trace observed on the next step must not
        // repopulate a draining replica's mirror.
        f.step().unwrap();
        assert!(f.replicas[0].mirror.is_empty(), "admission repopulated a draining mirror");
        f.run_to_completion().unwrap();
        assert_eq!(f.replica_stats(0).unwrap().health, ReplicaHealth::Dead);
        assert!(f.replicas[0].mirror.is_empty());

        // Kill: replica 1 takes the next placement (replica 0 is
        // dead); its mirror must be empty after the kill so a scoring
        // pass can never match hints on a dead replica.
        f.submit(GenRequest::text(prompt).max_new_tokens(4)).unwrap();
        f.step().unwrap();
        assert!(!f.replicas[1].mirror.is_empty());
        let moved = f.kill(1).unwrap();
        assert_eq!(moved.len(), 1, "in-flight victim resubmitted");
        assert_eq!(f.replica_stats(1).unwrap().health, ReplicaHealth::Dead);
        assert!(f.replicas[1].mirror.is_empty());
        f.run_to_completion().unwrap();
    }

    #[test]
    fn stats_and_admin_surface_fleet_state() {
        let mut f =
            Fleet::sim(cfg(), fcfg(2, RoutePolicy::CacheAware), SimSpec::default()).unwrap();
        f.submit(GenRequest::text("hello").max_new_tokens(4)).unwrap();
        f.run_to_completion().unwrap();
        let stats = f.stats_json();
        let fleet = stats.get("fleet").expect("fleet section");
        assert_eq!(fleet.get("replicas").unwrap().as_usize(), Some(2));
        assert_eq!(fleet.get("replicas_up").unwrap().as_usize(), Some(2));
        assert_eq!(
            fleet.get("policy").unwrap().as_str(),
            Some("cache_aware")
        );
        let replicas = stats.get("replicas").expect("replicas section");
        assert_eq!(replicas.get("0").unwrap().get("health").unwrap().as_str(), Some("up"));
        assert_eq!(
            replicas.get("0").unwrap().get("routed").unwrap().as_usize(),
            Some(1)
        );

        // Admin verbs: drain, then kill the survivor, then stats again.
        let out = f.admin("drain_replica", &Json::Num(0.0)).expect("handled");
        assert_eq!(out.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(f.health(0), Some(ReplicaHealth::Dead)); // idle -> retired now
        let out = f.admin("kill_replica", &Json::Num(1.0)).expect("handled");
        assert_eq!(out.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(out.get("resubmitted").unwrap().as_usize(), Some(0));
        let out = f.admin("fleet_stats", &Json::Null).expect("handled");
        assert_eq!(
            out.get("fleet").unwrap().get("replicas_dead").unwrap().as_usize(),
            Some(2)
        );
        assert!(f.admin("warp_core", &Json::Null).is_none());
        let out = f.admin("drain_replica", &Json::Str("x".into())).expect("handled");
        assert!(out.get("error").is_some(), "bad arg reports an error");
    }

    #[test]
    fn single_replica_fleet_matches_bare_engine() {
        let mut bare = SimEngine::new(cfg(), SimSpec::default()).unwrap();
        bare.set_seq_id_base(0); // no-op, mirrors fleet construction order
        let mut f =
            Fleet::sim(cfg(), fcfg(1, RoutePolicy::CacheAware), SimSpec::default()).unwrap();
        let mut bare_handles = Vec::new();
        let mut fleet_handles = Vec::new();
        for p in ["parity one", "parity two", "parity one"] {
            let req = GenRequest::text(p).max_new_tokens(6);
            bare_handles.push(bare.submit(req.clone()).unwrap());
            fleet_handles.push(f.submit(req).unwrap());
        }
        bare.run_to_completion().unwrap();
        f.run_to_completion().unwrap();
        for (b, fl) in bare_handles.iter().zip(&fleet_handles) {
            assert_eq!(b.id, fl.id, "replica 0 allocates bare-engine ids");
            let (bt, bf) = b.drain();
            let (ft, ff) = fl.drain();
            assert_eq!(bt, ft, "identical token streams");
            assert_eq!(
                bf.expect("bare finished").1,
                ff.expect("fleet finished").1,
                "identical usage"
            );
        }
        assert_eq!(
            bare.metrics.tokens_generated,
            f.metrics().tokens_generated
        );
        assert_eq!(bare.metrics.prefix_hits, f.metrics().prefix_hits);
    }
}
