//! JSON-lines TCP serving front-end (std::net + threads; offline build).
//!
//! Engines are single-owner (the PJRT one is not even Send), so the
//! [`crate::api::InferenceEngine`] runs on a dedicated OS thread;
//! connection handlers forward [`EngineJob`]s over an mpsc channel and
//! stream id-tagged [`GenEvent`]s back per request. [`spawn_engine`]
//! backs the loop with the real [`crate::engine::Engine`];
//! [`spawn_sim_engine`] backs it with the deterministic
//! [`crate::simengine::SimEngine`] twin (loopback tests, artifact-free
//! serving demos) — the loop itself is generic and identical for both.
//!
//! The full wire protocol (v2.4) — request/response/stats/cancel/admin
//! schemas, defaults, and error shapes — is documented in
//! `docs/PROTOCOL.md`. In short (one JSON object per line):
//!
//!   -> {"id": "a", "prompt": "...", "max_new_tokens": 32,
//!       "tenant": "acme", "stop": ["\n"], "temperature": 0.0}
//!   <- {"id": "a", "accepted": true, "global": "g7"}   (submission ack)
//!   <- {"id": "a", "token": 104, "text": "h"}     (per generated token)
//!   <- {"id": "a", "done": true, "reason": "eos", "n": 12,
//!       "usage": {"prompt_tokens": 5, "cached_tokens": 0,
//!                 "prefill_tokens": 5, "generated_tokens": 12}}
//!
//!   -> {"cancel": "a"}      (wire id on this connection, or a global
//!                            "g7" id from *any* connection)
//!   <- {"ok": true, "id": "a"}         (ack; the stream ends with a
//!                                       done line, reason "cancelled")
//!
//!   -> {"admin": {"cancel_tenant": "acme"}}
//!   <- {"ok": true, "cancelled": 3}    (bulk cancel across connections)
//!
//!   -> {"admin": {"dump_flight": 50}}
//!   <- {"ok": true, "flight": {"capacity": 512, "dropped": 0,
//!       "entries": [{"seq": 0, "at_us": 1000, "what": "..."}, ...]}}
//!
//!   -> {"admin": {"drain_replica": 1}}     (fleet-backed engines only)
//!   <- {"ok": true, "replica": 1, "health": "draining"}
//!
//!   -> {"admin": {"kill_replica": 1}}
//!   <- {"ok": true, "replica": 1, "resubmitted": 2}
//!
//!   -> {"admin": {"fleet_stats": true}}
//!   <- {"tokens_generated": 512, "fleet": {"replicas": 3, ...},
//!       "replicas": {"0": {"health": "up", ...}, ...}}
//!
//!   -> {"stats": true}
//!   <- {"tokens_generated": 512, "prefix_hit_rate": 0.7,
//!       "registry_depth": 2, "queue_depths": {"0": 1},
//!       "backpressure_pauses": 4, "tenants": {"acme": {...}}, ...}
//!
//!   -> {"stats": "prometheus"}
//!   <- {"prometheus": true, "text": "# TYPE fdpp_... \n..."}
//!      (the same snapshot as Prometheus text exposition, JSON-framed)
//!
//! Cross-connection cancellation works through the shared
//! [`RequestRegistry`]: every accepted submission is registered under a
//! server-global id (echoed in the `accepted` line) and pruned when its
//! done line goes out.
//!
//! Per-request streams are *bounded* ([`crate::api::event_channel`]):
//! a client that stops reading causes the engine to pause or drop that
//! request (its configured [`crate::config::BackpressurePolicy`]), never
//! to buffer unboundedly; other connections' streams are unaffected.
//!
//! Malformed input never kills a connection: the server answers
//! `{"error": "...", "code": "..."}` and keeps reading.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::api::{
    EventReceiver, FinishReason, GenEvent, GenRequest, InferenceEngine, RequestId,
    SubmissionHandle, Usage, Wakeup,
};
use crate::config::{EngineConfig, FleetConfig};
use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::fleet::Fleet;
use crate::obs::{prometheus_text, SpanBreakdown};
use crate::router::RequestRegistry;
use crate::runtime::Runtime;
use crate::sampling::SamplingParams;
use crate::scheduler::Action;
use crate::simengine::{SimEngine, SimSpec};
use crate::tokenizer::ByteTokenizer;
use crate::util::json::{parse, Json};
use crate::{log_info, log_warn};

/// A parsed and validated wire request (docs/PROTOCOL.md).
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    /// Client correlation id; echoed on every response line for this
    /// request and usable with `{"cancel": id}`.
    pub id: Option<String>,
    pub prompt: String,
    pub tenant: String,
    pub priority: i32,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub stop: Vec<String>,
}

/// Render a JSON number as a wire id string (integers lose the ".0").
fn num_id(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

fn bad(field: &str, want: &str) -> Error {
    Error::Request(format!("field '{field}' must be {want}"))
}

impl WireRequest {
    /// Strict parse: absent fields take documented defaults, but a
    /// present field with the wrong type or an invalid value (non-finite
    /// temperature, fractional counts, empty stop entries) is an error —
    /// never a silent default.
    pub fn from_json(j: &Json) -> Result<Self> {
        let prompt = j.req_str("prompt")?;
        let id = match j.get("id") {
            None => None,
            Some(Json::Str(s)) => Some(s.clone()),
            Some(Json::Num(n)) => Some(num_id(*n)),
            Some(_) => return Err(bad("id", "a string or number")),
        };
        let tenant = match j.get("tenant") {
            None => String::new(),
            Some(v) => v
                .as_str()
                .ok_or_else(|| bad("tenant", "a string"))?
                .to_string(),
        };
        let priority = match j.get("priority") {
            None => 0,
            Some(v) => {
                let p = v.as_f64().ok_or_else(|| bad("priority", "an integer"))?;
                if !p.is_finite() || p.fract() != 0.0 {
                    return Err(bad("priority", "an integer"));
                }
                p as i32
            }
        };
        let max_new_tokens = match j.get("max_new_tokens") {
            None => 32,
            Some(v) => non_negative_int(v)
                .filter(|&n| n >= 1)
                .ok_or_else(|| bad("max_new_tokens", "a positive integer"))?,
        };
        let temperature = match j.get("temperature") {
            None => 0.0,
            Some(v) => {
                let t = v.as_f64().ok_or_else(|| bad("temperature", "a finite number"))?;
                if !t.is_finite() {
                    return Err(bad("temperature", "a finite number"));
                }
                t as f32
            }
        };
        let top_k = match j.get("top_k") {
            None => 0,
            Some(v) => non_negative_int(v).ok_or_else(|| bad("top_k", "a non-negative integer"))?,
        };
        let stop = match j.get("stop") {
            None => Vec::new(),
            Some(v) => {
                let arr = v.as_arr().ok_or_else(|| bad("stop", "an array of strings"))?;
                let mut out = Vec::with_capacity(arr.len());
                for s in arr {
                    let s = s.as_str().ok_or_else(|| bad("stop", "an array of strings"))?;
                    if s.is_empty() {
                        return Err(bad("stop", "an array of non-empty strings"));
                    }
                    out.push(s.to_string());
                }
                out
            }
        };
        Ok(WireRequest {
            id,
            prompt,
            tenant,
            priority,
            max_new_tokens,
            temperature,
            top_k,
            stop,
        })
    }

    /// Convenience for tests and single-line parsing.
    pub fn from_json_line(line: &str) -> Result<Self> {
        Self::from_json(&parse(line)?)
    }

    /// Lower to the typed engine request, clamping the token budget to
    /// the engine's configured cap.
    pub fn into_gen_request(self, max_new_cap: usize) -> GenRequest {
        let mut req = GenRequest::text(self.prompt)
            .tenant(self.tenant)
            .priority(self.priority)
            .stop(self.stop)
            .params(SamplingParams {
                temperature: self.temperature,
                top_k: self.top_k,
            })
            .max_new_tokens(self.max_new_tokens.min(max_new_cap));
        if let Some(id) = self.id {
            req = req.client_id(id);
        }
        req
    }
}

fn non_negative_int(v: &Json) -> Option<usize> {
    let n = v.as_f64()?;
    if n.is_finite() && n >= 0.0 && n.fract() == 0.0 {
        Some(n as usize)
    } else {
        None
    }
}

/// Wire responses (docs/PROTOCOL.md).
pub fn token_response(id: &str, token: u32, text: &str) -> String {
    Json::obj(vec![
        ("id", Json::Str(id.to_string())),
        ("token", Json::Num(token as f64)),
        ("text", Json::Str(text.to_string())),
    ])
    .to_string()
}

pub fn done_response(id: &str, reason: FinishReason, usage: &Usage) -> String {
    done_response_with_span(id, reason, usage, None)
}

/// [`done_response`] carrying the request's lifecycle phase breakdown
/// when the engine recorded one (every `EngineCore` backend does; the
/// `"spans"` object is simply absent otherwise). See `docs/PROTOCOL.md`
/// v2.3 and `docs/OBSERVABILITY.md`.
pub fn done_response_with_span(
    id: &str,
    reason: FinishReason,
    usage: &Usage,
    span: Option<&SpanBreakdown>,
) -> String {
    let mut fields = vec![
        ("id", Json::Str(id.to_string())),
        ("done", Json::Bool(true)),
        ("reason", Json::Str(reason.as_str().to_string())),
        ("n", Json::Num(usage.generated_tokens as f64)),
        (
            "usage",
            Json::obj(vec![
                ("prompt_tokens", Json::Num(usage.prompt_tokens as f64)),
                (
                    "cached_tokens",
                    Json::Num(usage.cached_prompt_tokens as f64),
                ),
                ("prefill_tokens", Json::Num(usage.prefill_tokens as f64)),
                (
                    "generated_tokens",
                    Json::Num(usage.generated_tokens as f64),
                ),
            ]),
        ),
    ];
    if let Some(b) = span {
        fields.push(("spans", b.to_json()));
    }
    Json::obj(fields).to_string()
}

pub fn error_response(code: &str, msg: &str) -> String {
    Json::obj(vec![
        ("error", Json::Str(msg.to_string())),
        ("code", Json::Str(code.to_string())),
    ])
    .to_string()
}

pub fn cancel_ack(id: &str) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("id", Json::Str(id.to_string())),
    ])
    .to_string()
}

/// Submission ack: echoes the wire id and carries the server-global id
/// usable with `{"cancel": ...}` from any connection.
pub fn accepted_response(id: &str, global: &str) -> String {
    Json::obj(vec![
        ("id", Json::Str(id.to_string())),
        ("accepted", Json::Bool(true)),
        ("global", Json::Str(global.to_string())),
    ])
    .to_string()
}

/// Admin bulk-cancel ack.
pub fn admin_ack(cancelled: usize) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("cancelled", Json::Num(cancelled as f64)),
    ])
    .to_string()
}

/// Admin flight-recorder dump reply.
pub fn flight_ack(flight: Json) -> String {
    Json::obj(vec![("ok", Json::Bool(true)), ("flight", flight)]).to_string()
}

/// Prometheus exposition reply: the rendered text is JSON-framed so the
/// one-object-per-line protocol invariant holds (clients unwrap the
/// `"text"` field to feed a scraper).
pub fn prometheus_response(stats: &Json) -> String {
    Json::obj(vec![
        ("prometheus", Json::Bool(true)),
        ("text", Json::Str(prometheus_text(stats))),
    ])
    .to_string()
}

/// A request as it travels to the engine thread.
pub enum EngineJob {
    Submit {
        req: GenRequest,
        /// Submission outcome: the engine's handle (id + event stream,
        /// consumed directly by the connection's pump thread — no
        /// per-token re-send), or the rejection as a `(code, message)`
        /// pair (`"rejected"`, or `"quota_exceeded"` for per-tenant
        /// quota rejections — docs/PROTOCOL.md § Errors).
        submitted: mpsc::Sender<std::result::Result<SubmissionHandle, (String, String)>>,
    },
    Cancel {
        id: RequestId,
        /// When present, receives whether the engine actually cancelled
        /// a live request (`false` for unknown/finished ids) — used by
        /// the admin bulk-cancel path to report a truthful count.
        reply: Option<mpsc::Sender<bool>>,
    },
    /// Metrics snapshot — the server stats path. The engine replies
    /// with the structured [`Json`] value so the connection thread can
    /// merge server-side fields (registry depth) without re-parsing.
    Stats {
        reply: mpsc::Sender<Json>,
    },
    /// Flight-recorder dump — the `{"admin": {"dump_flight": n}}` path.
    /// The engine replies with [`InferenceEngine::dump_flight`]'s JSON.
    DumpFlight {
        n: usize,
        reply: mpsc::Sender<Json>,
    },
    /// Engine-specific admin verb ([`InferenceEngine::admin`]): the
    /// fleet's `drain_replica` / `kill_replica` / `fleet_stats` travel
    /// here. `None` back means the engine does not know the verb (a
    /// bare engine behind the same loop answers `bad_admin`).
    Admin {
        verb: String,
        arg: Json,
        reply: mpsc::Sender<Option<Json>>,
    },
}

/// The connection side's channel to the engine thread: an
/// [`EngineJob`] sender that also rings the engine loop's [`Wakeup`],
/// so a loop blocked on parked work processes a new job immediately
/// instead of waiting out its fallback timeout.
#[derive(Clone)]
pub struct JobSender {
    tx: mpsc::Sender<EngineJob>,
    wakeup: Wakeup,
}

impl JobSender {
    pub fn send(&self, job: EngineJob) -> std::result::Result<(), mpsc::SendError<EngineJob>> {
        let r = self.tx.send(job);
        self.wakeup.notify();
        r
    }
}

/// Handle to the engine thread.
pub struct EngineHandle {
    pub tx: JobSender,
    pub join: thread::JoinHandle<()>,
}

/// Spawn any engine behind the serving loop on a dedicated thread. The
/// engine is constructed *inside* the thread (PJRT handles are not
/// Send); startup errors are reported back synchronously before this
/// function returns. The thread owns a [`Wakeup`] notified by job
/// submission and by every client-side stream drain (the engine
/// attaches it to new streams via
/// [`InferenceEngine::set_wakeup`]), replacing the old polling nap.
fn spawn_engine_thread<E, F>(build: F) -> Result<EngineHandle>
where
    E: InferenceEngine,
    F: FnOnce() -> Result<E> + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<EngineJob>();
    let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
    let wakeup = Wakeup::new();
    let loop_wakeup = wakeup.clone();
    let join = thread::spawn(move || {
        let mut engine = match build() {
            Ok(e) => {
                let _ = ready_tx.send(Ok(()));
                e
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e.to_string()));
                return;
            }
        };
        engine.set_wakeup(loop_wakeup.clone());
        engine_loop(&mut engine, rx, loop_wakeup);
    });
    match ready_rx.recv() {
        Ok(Ok(())) => Ok(EngineHandle {
            tx: JobSender { tx, wakeup },
            join,
        }),
        Ok(Err(msg)) => Err(Error::Request(format!("engine startup failed: {msg}"))),
        Err(_) => Err(Error::Request("engine thread died during startup".into())),
    }
}

/// Spawn the real PJRT engine loop (loads artifacts, warms up buckets).
pub fn spawn_engine(artifacts_dir: &str, cfg: EngineConfig) -> Result<EngineHandle> {
    let dir = artifacts_dir.to_string();
    spawn_engine_thread(move || {
        Runtime::load(&dir)
            .and_then(|rt| Engine::new(rt, cfg))
            .and_then(|mut e| e.warmup().map(|_| e))
    })
}

/// Spawn the deterministic sim engine behind the same serving loop —
/// the loopback-test and artifact-free demo path.
pub fn spawn_sim_engine(cfg: EngineConfig, spec: SimSpec) -> Result<EngineHandle> {
    spawn_engine_thread(move || SimEngine::new(cfg, spec))
}

/// Spawn a sim-backed [`Fleet`] behind the same serving loop: N
/// replicas, cache-aware routing, and the `drain_replica` /
/// `kill_replica` / `fleet_stats` admin verbs live — the loopback way
/// to exercise fleet serving end to end without artifacts.
pub fn spawn_sim_fleet(
    cfg: EngineConfig,
    fcfg: FleetConfig,
    spec: SimSpec,
) -> Result<EngineHandle> {
    spawn_engine_thread(move || Fleet::sim(cfg, fcfg, spec))
}

/// The engine thread: drain incoming jobs, then step until idle. Works
/// for any [`InferenceEngine`] — this is the piece the sim twin shares
/// with production serving. Event streams flow straight from the
/// engine's [`SubmissionHandle`] to the connection's pump thread; the
/// loop itself only schedules.
///
/// When work is pending but nothing is runnable (every live request is
/// parked on backpressure), the loop blocks on `wakeup` instead of
/// polling: client drains, disconnects, and new jobs all notify it, so
/// resume latency is event-driven. The epoch is captured at the top of
/// each iteration — before the job drain and the step — closing the
/// race where a job arrives or a client drains while either runs. The
/// timeout is only a safety net against a lost notification.
fn engine_loop<E: InferenceEngine>(engine: &mut E, rx: mpsc::Receiver<EngineJob>, wakeup: Wakeup) {
    /// Fallback wait when parked; the expected wake path is a notify.
    const PARKED_WAIT: Duration = Duration::from_millis(2);
    loop {
        // Capture the epoch *before* draining jobs: a job or client
        // drain landing anywhere after this point bumps it, so a
        // subsequent wait_from returns immediately instead of sleeping
        // the fallback with work pending.
        let epoch = wakeup.epoch();
        // Accept new jobs (block only when idle).
        loop {
            let job = if engine.is_idle() {
                match rx.recv() {
                    Ok(j) => j,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        if engine.is_idle() {
                            return;
                        }
                        break;
                    }
                }
            };
            match job {
                EngineJob::Stats { reply } => {
                    let _ = reply.send(engine.stats_json());
                }
                EngineJob::DumpFlight { n, reply } => {
                    let _ = reply.send(engine.dump_flight(n));
                }
                EngineJob::Admin { verb, arg, reply } => {
                    let _ = reply.send(engine.admin(&verb, &arg));
                }
                EngineJob::Cancel { id, reply } => {
                    let r = engine.cancel(id);
                    if let Err(e) = &r {
                        log_warn!("cancel {id}: {e}");
                    }
                    if let Some(tx) = reply {
                        let _ = tx.send(matches!(r, Ok(true)));
                    }
                }
                EngineJob::Submit { req, submitted } => {
                    let _ = submitted.send(
                        engine
                            .submit(req)
                            .map_err(|e| (e.wire_code().to_string(), e.to_string())),
                    );
                }
            }
        }
        if !engine.is_idle() {
            match engine.step() {
                Ok(Action::Idle) => {
                    wakeup.wait_from(epoch, PARKED_WAIT);
                }
                Ok(_) => {
                    // Everything live is parked on backpressure (an
                    // admission may be waiting on parked KV): block on
                    // the wakeup until a client drains, disconnects, or
                    // a job arrives — no spinning, no polling quantum.
                    if engine.running() == 0 && engine.paused() > 0 {
                        wakeup.wait_from(epoch, PARKED_WAIT);
                    }
                }
                Err(e) => log_warn!("engine step failed: {e}"),
            }
        }
    }
}

/// Run the TCP server on the real engine (blocks forever).
pub fn serve(addr: &str, artifacts_dir: &str, cfg: EngineConfig) -> Result<()> {
    let vocab = {
        let manifest = crate::runtime::Manifest::load(std::path::Path::new(artifacts_dir))?;
        manifest.model.vocab_size
    };
    let max_new_cap = cfg.max_new_tokens;
    let handle = spawn_engine(artifacts_dir, cfg)?;
    let listener =
        TcpListener::bind(addr).map_err(|e| Error::Request(format!("bind {addr}: {e}")))?;
    serve_on(listener, handle, vocab, max_new_cap)
}

/// Accept loop over an already-bound listener and a running engine
/// thread (any backend). Tests bind port 0 and drive a sim-backed
/// engine through the exact production plumbing. All connections share
/// one [`RequestRegistry`], so cancellation works across connections.
pub fn serve_on(
    listener: TcpListener,
    handle: EngineHandle,
    vocab: usize,
    max_new_cap: usize,
) -> Result<()> {
    if let Ok(addr) = listener.local_addr() {
        log_info!("serving on {addr}");
    }
    let registry = Arc::new(RequestRegistry::new());
    for sock in listener.incoming() {
        let sock = match sock {
            Ok(s) => s,
            Err(e) => {
                log_warn!("accept: {e}");
                continue;
            }
        };
        let tx = handle.tx.clone();
        let registry = Arc::clone(&registry);
        thread::spawn(move || {
            if let Err(e) = handle_conn(sock, tx, registry, vocab, max_new_cap) {
                log_warn!("conn: {e}");
            }
        });
    }
    Ok(())
}

/// `{"stats": true}` exactly, with no prompt — a generate request that
/// happens to carry a stats field must not be hijacked.
pub fn is_stats_request(j: &Json) -> bool {
    j.get("stats").and_then(Json::as_bool) == Some(true) && j.get("prompt").is_none()
}

/// `{"stats": "prometheus"}` exactly, with no prompt (same hijack rule
/// as stats): the same snapshot, rendered as Prometheus text.
pub fn is_prometheus_request(j: &Json) -> bool {
    j.get("stats").and_then(Json::as_str) == Some("prometheus") && j.get("prompt").is_none()
}

/// `{"cancel": id}` with no prompt (same hijack rule as stats).
pub fn cancel_request_id(j: &Json) -> Option<String> {
    if j.get("prompt").is_some() {
        return None;
    }
    match j.get("cancel") {
        Some(Json::Str(s)) => Some(s.clone()),
        Some(Json::Num(n)) => Some(num_id(*n)),
        _ => None,
    }
}

/// `{"admin": {...}}` with no prompt (same hijack rule as stats).
pub fn admin_request(j: &Json) -> Option<&Json> {
    if j.get("prompt").is_some() {
        return None;
    }
    j.get("admin")
}

/// The admin verbs forwarded to [`InferenceEngine::admin`] (fleet
/// verbs today): the first known verb key present in the admin object,
/// with its argument. `cancel_tenant` and `dump_flight` are handled
/// server-side and never reach here.
pub fn engine_admin_verb(admin: &Json) -> Option<(&'static str, &Json)> {
    ["drain_replica", "kill_replica", "fleet_stats"]
        .into_iter()
        .find_map(|verb| admin.get(verb).map(|arg| (verb, arg)))
}

type SharedWriter = Arc<Mutex<TcpStream>>;
/// Wire id -> engine id for one connection's in-flight requests; shared
/// with the per-request pump threads, which prune their entry when the
/// done line goes out (so a finished id cancels as `unknown_id`, and
/// the map cannot grow without bound on long-lived connections).
type InflightIds = Arc<Mutex<HashMap<String, RequestId>>>;

fn write_line(w: &SharedWriter, line: &str) -> Result<()> {
    let mut g = w.lock().unwrap();
    writeln!(g, "{line}").map_err(Error::Io)
}

/// Forward one request's events to the socket, tagged with its wire id.
/// This thread is the consumer of the request's *bounded* event stream:
/// when the socket write stalls (client stopped reading), the stream
/// fills and the engine applies backpressure to just this request. On
/// every exit path the request's registry entry is pruned, so the
/// registry depth tracks requests actually in flight.
fn pump_events(
    wire_id: String,
    global_id: String,
    events: EventReceiver,
    w: SharedWriter,
    ids: InflightIds,
    registry: Arc<RequestRegistry>,
    tokenizer: ByteTokenizer,
) {
    while let Ok(ev) = events.recv() {
        let line = match ev {
            GenEvent::Token(t) => token_response(&wire_id, t, &tokenizer.decode(&[t])),
            GenEvent::Finished { reason, usage } => {
                // Prune the registry entry *before* the done line goes
                // out, so a client that reads `done` and immediately
                // queries stats (or cancels the global id) sees the
                // request fully retired. Then write the done line and
                // prune the wire id while holding the map lock, so a
                // client reusing the id is either rejected as duplicate
                // (strictly before this) or its stream starts strictly
                // after our done line — never interleaved under one id.
                // (Lock order everywhere is ids, then writer.)
                registry.remove(&global_id);
                // The engine closes the span before emitting the
                // terminal event, so the breakdown is readable here.
                let span = events.span_breakdown();
                let line = done_response_with_span(&wire_id, reason, &usage, span.as_ref());
                let mut in_flight = ids.lock().unwrap();
                let _ = write_line(&w, &line);
                in_flight.remove(&wire_id);
                return;
            }
        };
        if write_line(&w, &line).is_err() {
            // Client hung up: dropping `events` closes the stream and
            // the engine reclaims the request on its next scan.
            break;
        }
    }
    ids.lock().unwrap().remove(&wire_id);
    registry.remove(&global_id);
}

fn handle_conn(
    sock: TcpStream,
    engine_tx: JobSender,
    registry: Arc<RequestRegistry>,
    vocab: usize,
    max_new_cap: usize,
) -> Result<()> {
    let w: SharedWriter = Arc::new(Mutex::new(sock.try_clone().map_err(Error::Io)?));
    let r = BufReader::new(sock);
    let ids: InflightIds = Arc::new(Mutex::new(HashMap::new()));
    let mut next_local = 0u64;
    for line in r.lines() {
        let line = line.map_err(Error::Io)?;
        if line.trim().is_empty() {
            continue;
        }
        let j = match parse(&line) {
            Ok(j) => j,
            Err(e) => {
                write_line(&w, &error_response("bad_json", &e.to_string()))?;
                continue;
            }
        };
        // Stats request: one JSON object back, no generation. The
        // engine snapshot is augmented with the server-side registry
        // depth (requests in flight across all connections).
        if is_stats_request(&j) || is_prometheus_request(&j) {
            let (reply_tx, reply_rx) = mpsc::channel::<Json>();
            if engine_tx.send(EngineJob::Stats { reply: reply_tx }).is_err() {
                return engine_gone(&w);
            }
            match reply_rx.recv() {
                Ok(mut stats) => {
                    if let Json::Obj(m) = &mut stats {
                        m.insert(
                            "registry_depth".to_string(),
                            Json::Num(registry.depth() as f64),
                        );
                    }
                    // Same snapshot, two renderings: raw JSON, or
                    // Prometheus text (JSON-framed to keep the
                    // one-object-per-line protocol).
                    if is_prometheus_request(&j) {
                        write_line(&w, &prometheus_response(&stats))?;
                    } else {
                        write_line(&w, &stats.to_string())?;
                    }
                }
                Err(_) => return engine_gone(&w),
            }
            continue;
        }
        // Admin request. `cancel_tenant` bulk-cancels that tenant's
        // in-flight requests on *every* connection; each affected
        // stream ends with its own done line, reason "cancelled", and
        // the ack reports how many live requests were actually
        // cancelled (a request racing to completion is not counted).
        // `dump_flight` returns the newest n entries of the engine's
        // always-on flight recorder. The fleet verbs (`drain_replica`,
        // `kill_replica`, `fleet_stats`) forward to
        // [`InferenceEngine::admin`]; an engine that does not know the
        // verb answers `bad_admin`, so a fleet deployment and a bare
        // engine share one dispatch path.
        if let Some(admin) = admin_request(&j) {
            if let Some(tenant) = admin.get("cancel_tenant").and_then(Json::as_str) {
                let rids = registry.tenant_ids(tenant);
                let (ack_tx, ack_rx) = mpsc::channel::<bool>();
                for rid in rids {
                    let job = EngineJob::Cancel {
                        id: rid,
                        reply: Some(ack_tx.clone()),
                    };
                    if engine_tx.send(job).is_err() {
                        return engine_gone(&w);
                    }
                }
                drop(ack_tx);
                let n = ack_rx.iter().filter(|&cancelled| cancelled).count();
                write_line(&w, &admin_ack(n))?;
            } else if let Some(dump) = admin.get("dump_flight") {
                let Some(n) = non_negative_int(dump) else {
                    let msg = "dump_flight takes a non-negative entry count";
                    write_line(&w, &error_response("bad_admin", msg))?;
                    continue;
                };
                let (reply_tx, reply_rx) = mpsc::channel::<Json>();
                let job = EngineJob::DumpFlight { n, reply: reply_tx };
                if engine_tx.send(job).is_err() {
                    return engine_gone(&w);
                }
                match reply_rx.recv() {
                    Ok(flight) => write_line(&w, &flight_ack(flight))?,
                    Err(_) => return engine_gone(&w),
                }
            } else if let Some((verb, arg)) = engine_admin_verb(admin) {
                let (reply_tx, reply_rx) = mpsc::channel::<Option<Json>>();
                let job = EngineJob::Admin {
                    verb: verb.to_string(),
                    arg: arg.clone(),
                    reply: reply_tx,
                };
                if engine_tx.send(job).is_err() {
                    return engine_gone(&w);
                }
                match reply_rx.recv() {
                    // The engine's reply is already a complete wire
                    // object (ok ack, stats snapshot, or error shape).
                    Ok(Some(reply)) => write_line(&w, &reply.to_string())?,
                    Ok(None) => {
                        let msg = format!("this engine does not support {verb:?}");
                        write_line(&w, &error_response("bad_admin", &msg))?;
                    }
                    Err(_) => return engine_gone(&w),
                }
            } else {
                let msg = "admin supports {\"cancel_tenant\": \"<tenant>\"}, \
                           {\"dump_flight\": <n>}, {\"drain_replica\": <k>}, \
                           {\"kill_replica\": <k>}, and {\"fleet_stats\": true}";
                write_line(&w, &error_response("bad_admin", msg))?;
            }
            continue;
        }
        // Cancel request: resolve the wire id submitted on this
        // connection, falling back to the cross-connection registry's
        // global ids; the generation stream itself ends with a done
        // line, reason "cancelled".
        if let Some(wire_id) = cancel_request_id(&j) {
            let rid = ids
                .lock()
                .unwrap()
                .get(&wire_id)
                .copied()
                .or_else(|| registry.resolve(&wire_id));
            match rid {
                Some(rid) => {
                    let job = EngineJob::Cancel {
                        id: rid,
                        reply: None,
                    };
                    if engine_tx.send(job).is_err() {
                        return engine_gone(&w);
                    }
                    write_line(&w, &cancel_ack(&wire_id))?;
                }
                None => {
                    let msg = format!("no in-flight request with id {wire_id:?}");
                    write_line(&w, &error_response("unknown_id", &msg))?;
                }
            }
            continue;
        }
        let req = match WireRequest::from_json(&j) {
            Ok(r) => r,
            Err(e) => {
                write_line(&w, &error_response("bad_request", &e.to_string()))?;
                continue;
            }
        };
        let gen = req.into_gen_request(max_new_cap);
        let wire_id = match gen.client_id.clone() {
            Some(id) => {
                if ids.lock().unwrap().contains_key(&id) {
                    let msg = format!("id {id:?} is already in flight on this connection");
                    write_line(&w, &error_response("duplicate_id", &msg))?;
                    continue;
                }
                id
            }
            None => loop {
                next_local += 1;
                let candidate = format!("r{next_local}");
                if !ids.lock().unwrap().contains_key(&candidate) {
                    break candidate;
                }
            },
        };
        let tenant = gen.tenant.clone();
        let priority = gen.priority;
        let (sub_tx, sub_rx) = mpsc::channel();
        let job = EngineJob::Submit {
            req: gen,
            submitted: sub_tx,
        };
        if engine_tx.send(job).is_err() {
            return engine_gone(&w);
        }
        match sub_rx.recv() {
            Ok(Ok(handle)) => {
                // Ack before any token can flow (the pump thread is not
                // spawned yet): the accepted line is always the first
                // line of the stream. On a dead socket, bail before
                // registering — dropping `handle` closes the stream and
                // the engine reclaims the request.
                let gid = registry.register(handle.id, &tenant, priority);
                if let Err(e) = write_line(&w, &accepted_response(&wire_id, &gid)) {
                    registry.remove(&gid);
                    return Err(e);
                }
                ids.lock().unwrap().insert(wire_id.clone(), handle.id);
                let w2 = Arc::clone(&w);
                let ids2 = Arc::clone(&ids);
                let reg2 = Arc::clone(&registry);
                let tokenizer = ByteTokenizer::new(vocab);
                thread::spawn(move || {
                    pump_events(wire_id, gid, handle.events, w2, ids2, reg2, tokenizer)
                });
            }
            Ok(Err((code, msg))) => {
                write_line(&w, &error_response(&code, &msg))?;
            }
            Err(_) => return engine_gone(&w),
        }
    }
    Ok(())
}

/// Tell the client the engine thread is gone, then end the connection
/// (there is nothing left to serve).
fn engine_gone(w: &SharedWriter) -> Result<()> {
    write_line(w, &error_response("engine_gone", "engine thread exited"))
}

/// Minimal blocking client for tests/examples. One reader is held for
/// the whole connection, so buffered lines are never lost between
/// calls.
pub struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let sock = TcpStream::connect(addr).map_err(Error::Io)?;
        let r = BufReader::new(sock.try_clone().map_err(Error::Io)?);
        Ok(Client { w: sock, r })
    }

    /// Send one raw JSON line.
    pub fn send(&mut self, j: &Json) -> Result<()> {
        writeln!(self.w, "{}", j.to_string()).map_err(Error::Io)
    }

    /// Send one raw line verbatim (exercises the error path).
    pub fn send_raw(&mut self, line: &str) -> Result<()> {
        writeln!(self.w, "{line}").map_err(Error::Io)
    }

    /// Bound every subsequent `recv` (tests use this to fail loudly
    /// instead of hanging when an expected line never arrives).
    pub fn set_read_timeout(&mut self, d: Option<std::time::Duration>) -> Result<()> {
        self.w.set_read_timeout(d).map_err(Error::Io)
    }

    /// Read the next non-empty response line as JSON.
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.r.read_line(&mut line).map_err(Error::Io)?;
            if n == 0 {
                return Err(Error::Request("connection closed".into()));
            }
            if !line.trim().is_empty() {
                return parse(line.trim());
            }
        }
    }

    /// Send one request and collect the full generation (skipping the
    /// `accepted` ack line).
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<String> {
        self.send(&Json::obj(vec![
            ("prompt", Json::Str(prompt.to_string())),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
        ]))?;
        let mut out = String::new();
        loop {
            let j = self.recv()?;
            if j.get("error").is_some() {
                return Err(Error::Request(j.req_str("error")?));
            }
            if j.get("accepted").is_some() {
                continue;
            }
            if j.get("done").is_some() {
                return Ok(out);
            }
            if let Ok(text) = j.req_str("text") {
                out.push_str(&text);
            }
        }
    }

    /// Request cancellation of an in-flight id: a wire id submitted on
    /// this connection, or a global `"g<N>"` id from any connection.
    pub fn cancel(&mut self, id: &str) -> Result<()> {
        self.send(&Json::obj(vec![("cancel", Json::Str(id.to_string()))]))
    }

    /// Bulk-cancel every in-flight request of a tenant, server-wide.
    pub fn admin_cancel_tenant(&mut self, tenant: &str) -> Result<()> {
        self.send(&Json::obj(vec![(
            "admin",
            Json::obj(vec![("cancel_tenant", Json::Str(tenant.to_string()))]),
        )]))
    }

    /// Fetch the engine's metrics snapshot (raw JSON line).
    pub fn stats(&mut self) -> Result<String> {
        self.send(&Json::obj(vec![("stats", Json::Bool(true))]))?;
        Ok(self.recv()?.to_string())
    }

    /// Fetch the stats snapshot as Prometheus text exposition
    /// (unwrapped from its JSON framing).
    pub fn stats_prometheus(&mut self) -> Result<String> {
        self.send(&Json::obj(vec![(
            "stats",
            Json::Str("prometheus".to_string()),
        )]))?;
        self.recv()?.req_str("text")
    }

    /// Send one engine-forwarded admin verb and return the reply
    /// object (an `{"error": ...}` reply becomes an `Err`).
    pub fn admin_verb(&mut self, verb: &str, arg: Json) -> Result<Json> {
        self.send(&Json::obj(vec![("admin", Json::obj(vec![(verb, arg)]))]))?;
        let reply = self.recv()?;
        if let Some(err) = reply.get("error").and_then(Json::as_str) {
            return Err(Error::Request(err.to_string()));
        }
        Ok(reply)
    }

    /// Stop placing new work on a fleet replica (it retires once idle).
    pub fn drain_replica(&mut self, k: usize) -> Result<Json> {
        self.admin_verb("drain_replica", Json::Num(k as f64))
    }

    /// Kill a fleet replica; its in-flight work restarts on survivors.
    pub fn kill_replica(&mut self, k: usize) -> Result<Json> {
        self.admin_verb("kill_replica", Json::Num(k as f64))
    }

    /// Fetch the fleet-wide stats snapshot (per-replica breakdown).
    pub fn fleet_stats(&mut self) -> Result<Json> {
        self.admin_verb("fleet_stats", Json::Bool(true))
    }

    /// Fetch the newest `n` flight-recorder entries from the engine.
    pub fn dump_flight(&mut self, n: usize) -> Result<Json> {
        self.send(&Json::obj(vec![(
            "admin",
            Json::obj(vec![("dump_flight", Json::Num(n as f64))]),
        )]))?;
        let reply = self.recv()?;
        if let Some(err) = reply.get("error").and_then(Json::as_str) {
            return Err(Error::Request(err.to_string()));
        }
        Ok(reply.field("flight")?.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_request_defaults() {
        let r = WireRequest::from_json_line(r#"{"prompt":"hi"}"#).unwrap();
        assert_eq!(r.id, None);
        assert_eq!(r.tenant, "");
        assert_eq!(r.priority, 0);
        assert_eq!(r.max_new_tokens, 32);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.top_k, 0);
        assert!(r.stop.is_empty());
    }

    #[test]
    fn wire_request_full() {
        let r = WireRequest::from_json_line(
            r#"{"id":7,"prompt":"p","tenant":"acme","priority":2,"max_new_tokens":8,
               "temperature":0.7,"top_k":40,"stop":["\n\n","END"]}"#,
        )
        .unwrap();
        assert_eq!(r.id.as_deref(), Some("7"));
        assert_eq!(r.tenant, "acme");
        assert_eq!(r.priority, 2);
        assert_eq!(r.max_new_tokens, 8);
        assert!((r.temperature - 0.7).abs() < 1e-6);
        assert_eq!(r.top_k, 40);
        assert_eq!(r.stop, vec!["\n\n".to_string(), "END".to_string()]);
    }

    #[test]
    fn wire_request_rejects_invalid_fields() {
        // Present-but-wrong fields must error, not silently default.
        for line in [
            r#"{"max_new_tokens":4}"#,                   // missing prompt
            r#"{"prompt":"p","temperature":1e999}"#,     // non-finite
            r#"{"prompt":"p","temperature":"hot"}"#,     // wrong type
            r#"{"prompt":"p","max_new_tokens":-3}"#,     // negative
            r#"{"prompt":"p","max_new_tokens":0}"#,      // zero budget
            r#"{"prompt":"p","max_new_tokens":1.5}"#,    // fractional
            r#"{"prompt":"p","max_new_tokens":"many"}"#, // wrong type
            r#"{"prompt":"p","top_k":-1}"#,              // negative
            r#"{"prompt":"p","priority":0.5}"#,          // fractional
            r#"{"prompt":"p","tenant":3}"#,              // wrong type
            r#"{"prompt":"p","stop":"x"}"#,              // not an array
            r#"{"prompt":"p","stop":[1]}"#,              // not strings
            r#"{"prompt":"p","stop":[""]}"#,             // empty entry
            r#"{"prompt":"p","id":true}"#,               // bad id type
        ] {
            assert!(
                WireRequest::from_json_line(line).is_err(),
                "must reject: {line}"
            );
        }
    }

    #[test]
    fn into_gen_request_clamps_budget() {
        let r = WireRequest::from_json_line(r#"{"prompt":"p","max_new_tokens":10000}"#).unwrap();
        let g = r.into_gen_request(64);
        assert_eq!(g.max_new_tokens, 64);
        assert_eq!(g.tenant, "");
        let r = WireRequest::from_json_line(r#"{"prompt":"p","max_new_tokens":3}"#).unwrap();
        assert_eq!(r.into_gen_request(64).max_new_tokens, 3);
    }

    #[test]
    fn stats_detection_is_exact() {
        assert!(is_stats_request(&parse(r#"{"stats":true}"#).unwrap()));
        // Wrong value, wrong type, or a generate request carrying the
        // field must all fall through to the generate path.
        assert!(!is_stats_request(&parse(r#"{"stats":false}"#).unwrap()));
        assert!(!is_stats_request(&parse(r#"{"stats":1}"#).unwrap()));
        assert!(!is_stats_request(
            &parse(r#"{"prompt":"hi","stats":true}"#).unwrap()
        ));
        assert!(!is_stats_request(&parse(r#"{"prompt":"hi"}"#).unwrap()));
    }

    #[test]
    fn prometheus_detection_is_exact() {
        assert!(is_prometheus_request(
            &parse(r#"{"stats":"prometheus"}"#).unwrap()
        ));
        // Wrong value/type, or a generate request carrying the field,
        // must all fall through — and plain `{"stats":true}` stays on
        // the JSON stats path.
        assert!(!is_prometheus_request(&parse(r#"{"stats":true}"#).unwrap()));
        assert!(!is_prometheus_request(
            &parse(r#"{"stats":"json"}"#).unwrap()
        ));
        assert!(!is_prometheus_request(
            &parse(r#"{"prompt":"hi","stats":"prometheus"}"#).unwrap()
        ));
        assert!(!is_stats_request(&parse(r#"{"stats":"prometheus"}"#).unwrap()));
    }

    #[test]
    fn done_response_carries_span_breakdown() {
        let usage = Usage {
            prompt_tokens: 5,
            cached_prompt_tokens: 2,
            prefill_tokens: 3,
            generated_tokens: 4,
        };
        let b = SpanBreakdown {
            queue_wait_us: 100,
            prefill_us: 200,
            decode_us: 300,
            paused_us: 0,
            ttft_us: Some(300),
            total_us: 600,
        };
        let line = done_response_with_span("a", FinishReason::Eos, &usage, Some(&b));
        let j = parse(&line).unwrap();
        let spans = j.field("spans").unwrap();
        assert_eq!(spans.get("queue_wait_us").and_then(Json::as_usize), Some(100));
        assert_eq!(spans.get("ttft_us").and_then(Json::as_usize), Some(300));
        assert_eq!(spans.get("total_us").and_then(Json::as_usize), Some(600));
        // Without a span the field is absent and the legacy shape is
        // byte-for-byte what done_response always produced.
        let bare = done_response("a", FinishReason::Eos, &usage);
        assert!(parse(&bare).unwrap().get("spans").is_none());
        assert_eq!(
            bare,
            done_response_with_span("a", FinishReason::Eos, &usage, None)
        );
    }

    #[test]
    fn flight_ack_and_prometheus_response_are_valid_json() {
        let flight = Json::obj(vec![
            ("capacity", Json::Num(8.0)),
            ("entries", Json::Arr(vec![])),
        ]);
        let line = flight_ack(flight);
        let j = parse(&line).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            j.field("flight").unwrap().get("capacity").and_then(Json::as_usize),
            Some(8)
        );
        let stats = Json::obj(vec![("tokens_generated", Json::Num(3.0))]);
        let line = prometheus_response(&stats);
        assert!(!line.contains('\n'), "must stay one JSON line: {line}");
        let j = parse(&line).unwrap();
        assert!(j.req_str("text").unwrap().contains("fdpp_tokens_generated 3\n"));
    }

    #[test]
    fn cancel_detection_is_exact() {
        assert_eq!(
            cancel_request_id(&parse(r#"{"cancel":"abc"}"#).unwrap()),
            Some("abc".to_string())
        );
        assert_eq!(
            cancel_request_id(&parse(r#"{"cancel":12}"#).unwrap()),
            Some("12".to_string())
        );
        assert_eq!(cancel_request_id(&parse(r#"{"cancel":true}"#).unwrap()), None);
        assert_eq!(
            cancel_request_id(&parse(r#"{"prompt":"p","cancel":"abc"}"#).unwrap()),
            None,
            "generate requests are never hijacked"
        );
    }

    #[test]
    fn admin_detection_is_exact() {
        let j = parse(r#"{"admin":{"cancel_tenant":"acme"}}"#).unwrap();
        let a = admin_request(&j).expect("admin object detected");
        assert_eq!(a.get("cancel_tenant").and_then(Json::as_str), Some("acme"));
        assert!(
            admin_request(&parse(r#"{"prompt":"p","admin":{}}"#).unwrap()).is_none(),
            "generate requests are never hijacked"
        );
        assert!(admin_request(&parse(r#"{"stats":true}"#).unwrap()).is_none());
    }

    #[test]
    fn engine_admin_verb_detection() {
        let j = parse(r#"{"admin":{"drain_replica":1}}"#).unwrap();
        let admin = admin_request(&j).unwrap();
        let (verb, arg) = engine_admin_verb(admin).unwrap();
        assert_eq!(verb, "drain_replica");
        assert_eq!(arg.as_usize(), Some(1));
        let j = parse(r#"{"admin":{"fleet_stats":true}}"#).unwrap();
        let (verb, _) = engine_admin_verb(admin_request(&j).unwrap()).unwrap();
        assert_eq!(verb, "fleet_stats");
        // Server-side verbs and unknown verbs never forward.
        for line in [
            r#"{"admin":{"cancel_tenant":"acme"}}"#,
            r#"{"admin":{"dump_flight":5}}"#,
            r#"{"admin":{"explode":true}}"#,
        ] {
            let j = parse(line).unwrap();
            assert!(engine_admin_verb(admin_request(&j).unwrap()).is_none());
        }
    }

    #[test]
    fn responses_are_valid_json() {
        let usage = Usage {
            prompt_tokens: 5,
            cached_prompt_tokens: 2,
            prefill_tokens: 3,
            generated_tokens: 4,
        };
        for s in [
            token_response("a", 104, "h"),
            done_response("a", FinishReason::Eos, &usage),
            error_response("bad_request", "nope"),
            cancel_ack("a"),
            accepted_response("a", "g1"),
            admin_ack(3),
        ] {
            parse(&s).unwrap();
        }
        assert!(token_response("a", 104, "h").contains("\"token\":104"));
        let done = done_response("a", FinishReason::MaxTokens, &usage);
        assert!(done.contains("max_tokens"));
        assert!(done.contains("\"cached_tokens\":2"));
        assert!(done.contains("\"n\":4"));
        let cancelled = done_response("a", FinishReason::Cancelled, &usage);
        assert!(cancelled.contains("cancelled"));
        let overrun = done_response("a", FinishReason::Overrun, &usage);
        assert!(overrun.contains("overrun"));
        let accepted = accepted_response("a", "g7");
        assert!(accepted.contains("\"accepted\":true"));
        assert!(accepted.contains("\"global\":\"g7\""));
        assert!(admin_ack(3).contains("\"cancelled\":3"));
    }
}
