//! JSON-lines TCP serving front-end (std::net + threads; offline build).
//!
//! The engine is single-owner and not Send, so it runs on a dedicated
//! OS thread; connection handlers forward requests over an mpsc channel
//! and stream `TokenEvent`s back per request.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "...", "max_new_tokens": 32, "temperature": 0.0}
//!   <- {"token": 104, "text": "h"}            (per generated token)
//!   <- {"done": true, "reason": "eos", "n": 12}
//!
//! Stats (engine + prefix-cache counters, one JSON object back):
//!   -> {"stats": true}
//!   <- {"tokens_generated": 512, "prefix_hit_rate": 0.7, ...}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::thread;

use crate::config::EngineConfig;
use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::router::{FinishReason, TokenEvent};
use crate::runtime::Runtime;
use crate::sampling::SamplingParams;
use crate::tokenizer::ByteTokenizer;
use crate::util::json::{parse, Json};
use crate::{log_info, log_warn};

/// A parsed wire request.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
}

impl WireRequest {
    pub fn from_json_line(line: &str) -> Result<Self> {
        let j = parse(line)?;
        Ok(WireRequest {
            prompt: j.req_str("prompt")?,
            max_new_tokens: j
                .get("max_new_tokens")
                .and_then(Json::as_usize)
                .unwrap_or(32),
            temperature: j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            top_k: j.get("top_k").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

/// Wire responses.
pub fn token_response(token: u32, text: &str) -> String {
    Json::obj(vec![
        ("token", Json::Num(token as f64)),
        ("text", Json::Str(text.to_string())),
    ])
    .to_string()
}

pub fn done_response(reason: FinishReason, n: usize) -> String {
    let reason = match reason {
        FinishReason::Eos => "eos",
        FinishReason::MaxTokens => "max_tokens",
        FinishReason::Preempted => "preempted",
        FinishReason::Error => "error",
    };
    Json::obj(vec![
        ("done", Json::Bool(true)),
        ("reason", Json::Str(reason.to_string())),
        ("n", Json::Num(n as f64)),
    ])
    .to_string()
}

pub fn error_response(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

/// A request as it travels to the engine thread.
pub enum EngineJob {
    Generate {
        prompt: String,
        max_new_tokens: usize,
        params: SamplingParams,
        reply: mpsc::Sender<TokenEvent>,
    },
    /// Metrics snapshot (serialized JSON) — the server stats path.
    Stats { reply: mpsc::Sender<String> },
}

/// Handle to the engine thread.
pub struct EngineHandle {
    pub tx: mpsc::Sender<EngineJob>,
    pub join: thread::JoinHandle<()>,
}

/// Spawn the engine loop on its own thread. The engine (PJRT handles are
/// not Send) is constructed *inside* the thread; startup errors are
/// reported back synchronously before this function returns.
pub fn spawn_engine(artifacts_dir: &str, cfg: EngineConfig) -> Result<EngineHandle> {
    let (tx, rx) = mpsc::channel::<EngineJob>();
    let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
    let dir = artifacts_dir.to_string();
    let join = thread::spawn(move || {
        let mut engine = match Runtime::load(&dir)
            .and_then(|rt| Engine::new(rt, cfg))
            .and_then(|mut e| e.warmup().map(|_| e))
        {
            Ok(e) => {
                let _ = ready_tx.send(Ok(()));
                e
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e.to_string()));
                return;
            }
        };
        engine_loop(&mut engine, rx);
    });
    match ready_rx.recv() {
        Ok(Ok(())) => Ok(EngineHandle { tx, join }),
        Ok(Err(msg)) => Err(Error::Request(format!("engine startup failed: {msg}"))),
        Err(_) => Err(Error::Request("engine thread died during startup".into())),
    }
}

/// The engine thread: drain incoming jobs, then step until idle.
fn engine_loop(engine: &mut Engine, rx: mpsc::Receiver<EngineJob>) {
    let mut streams: Vec<(mpsc::Receiver<TokenEvent>, mpsc::Sender<TokenEvent>)> = Vec::new();
    loop {
        // Accept new jobs (block only when idle).
        loop {
            let job = if engine.is_idle() && streams.is_empty() {
                match rx.recv() {
                    Ok(j) => j,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        if engine.is_idle() && streams.is_empty() {
                            return;
                        }
                        break;
                    }
                }
            };
            match job {
                EngineJob::Stats { reply } => {
                    let _ = reply.send(engine.metrics.to_json().to_string());
                }
                EngineJob::Generate {
                    prompt,
                    max_new_tokens,
                    params,
                    reply,
                } => {
                    let toks = engine.tokenizer.encode(&prompt);
                    match engine.submit_tokens(toks, max_new_tokens, params) {
                        Ok((_, seq_rx)) => streams.push((seq_rx, reply)),
                        Err(e) => {
                            let _ = reply.send(TokenEvent::Finished {
                                reason: FinishReason::Error,
                                n_generated: 0,
                            });
                            log_warn!("submit failed: {e}");
                        }
                    }
                }
            }
        }
        if !engine.is_idle() {
            if let Err(e) = engine.step() {
                log_warn!("engine step failed: {e}");
            }
        }
        // Pump generated tokens out to the per-request reply channels.
        streams.retain(|(seq_rx, reply)| loop {
            match seq_rx.try_recv() {
                Ok(ev) => {
                    let done = matches!(ev, TokenEvent::Finished { .. });
                    if reply.send(ev).is_err() || done {
                        return false;
                    }
                }
                Err(mpsc::TryRecvError::Empty) => return true,
                Err(mpsc::TryRecvError::Disconnected) => return false,
            }
        });
    }
}

/// Run the TCP server (blocks forever).
pub fn serve(addr: &str, artifacts_dir: &str, cfg: EngineConfig) -> Result<()> {
    let vocab = {
        let manifest = crate::runtime::Manifest::load(std::path::Path::new(artifacts_dir))?;
        manifest.model.vocab_size
    };
    let handle = spawn_engine(artifacts_dir, cfg)?;
    let listener =
        TcpListener::bind(addr).map_err(|e| Error::Request(format!("bind {addr}: {e}")))?;
    log_info!("serving on {addr}");
    for sock in listener.incoming() {
        let sock = match sock {
            Ok(s) => s,
            Err(e) => {
                log_warn!("accept: {e}");
                continue;
            }
        };
        let tx = handle.tx.clone();
        thread::spawn(move || {
            if let Err(e) = handle_conn(sock, tx, vocab) {
                log_warn!("conn: {e}");
            }
        });
    }
    Ok(())
}

/// `{"stats": true}` exactly, with no prompt — a generate request that
/// happens to carry a stats field must not be hijacked.
pub fn is_stats_request(j: &Json) -> bool {
    j.get("stats").and_then(Json::as_bool) == Some(true) && j.get("prompt").is_none()
}

fn handle_conn(sock: TcpStream, engine_tx: mpsc::Sender<EngineJob>, vocab: usize) -> Result<()> {
    let mut w = sock.try_clone().map_err(Error::Io)?;
    let r = BufReader::new(sock);
    let tokenizer = ByteTokenizer::new(vocab);
    for line in r.lines() {
        let line = line.map_err(Error::Io)?;
        if line.trim().is_empty() {
            continue;
        }
        // Stats request: one JSON object back, no generation.
        if let Ok(j) = parse(&line) {
            if is_stats_request(&j) {
                let (reply_tx, reply_rx) = mpsc::channel::<String>();
                engine_tx
                    .send(EngineJob::Stats { reply: reply_tx })
                    .map_err(|_| Error::Request("engine gone".into()))?;
                match reply_rx.recv() {
                    Ok(stats) => writeln!(w, "{stats}").map_err(Error::Io)?,
                    Err(_) => writeln!(w, "{}", error_response("engine gone"))
                        .map_err(Error::Io)?,
                }
                continue;
            }
        }
        let req = match WireRequest::from_json_line(&line) {
            Ok(r) => r,
            Err(e) => {
                writeln!(w, "{}", error_response(&format!("bad request: {e}")))
                    .map_err(Error::Io)?;
                continue;
            }
        };
        let (reply_tx, reply_rx) = mpsc::channel::<TokenEvent>();
        engine_tx
            .send(EngineJob::Generate {
                prompt: req.prompt,
                max_new_tokens: req.max_new_tokens,
                params: SamplingParams {
                    temperature: req.temperature,
                    top_k: req.top_k,
                },
                reply: reply_tx,
            })
            .map_err(|_| Error::Request("engine gone".into()))?;
        while let Ok(ev) = reply_rx.recv() {
            match ev {
                TokenEvent::Token(t) => {
                    writeln!(w, "{}", token_response(t, &tokenizer.decode(&[t])))
                        .map_err(Error::Io)?;
                }
                TokenEvent::Finished { reason, n_generated } => {
                    writeln!(w, "{}", done_response(reason, n_generated)).map_err(Error::Io)?;
                    break;
                }
            }
        }
    }
    Ok(())
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    sock: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        Ok(Client {
            sock: TcpStream::connect(addr).map_err(Error::Io)?,
        })
    }

    /// Send one request and collect the full generation.
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<String> {
        let req = Json::obj(vec![
            ("prompt", Json::Str(prompt.to_string())),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
        ]);
        writeln!(self.sock, "{}", req.to_string()).map_err(Error::Io)?;
        let mut out = String::new();
        let reader = BufReader::new(self.sock.try_clone().map_err(Error::Io)?);
        for line in reader.lines() {
            let line = line.map_err(Error::Io)?;
            let j = parse(&line)?;
            if j.get("done").is_some() {
                break;
            }
            if let Ok(text) = j.req_str("text") {
                out.push_str(&text);
            }
            if j.get("error").is_some() {
                return Err(Error::Request(j.req_str("error")?));
            }
        }
        Ok(out)
    }

    /// Fetch the engine's metrics snapshot (raw JSON line).
    pub fn stats(&mut self) -> Result<String> {
        writeln!(
            self.sock,
            "{}",
            Json::obj(vec![("stats", Json::Bool(true))]).to_string()
        )
        .map_err(Error::Io)?;
        let mut reader = BufReader::new(self.sock.try_clone().map_err(Error::Io)?);
        let mut line = String::new();
        reader.read_line(&mut line).map_err(Error::Io)?;
        Ok(line.trim().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_request_defaults() {
        let r = WireRequest::from_json_line(r#"{"prompt":"hi"}"#).unwrap();
        assert_eq!(r.max_new_tokens, 32);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.top_k, 0);
    }

    #[test]
    fn wire_request_full() {
        let r = WireRequest::from_json_line(
            r#"{"prompt":"p","max_new_tokens":8,"temperature":0.7,"top_k":40}"#,
        )
        .unwrap();
        assert_eq!(r.max_new_tokens, 8);
        assert!((r.temperature - 0.7).abs() < 1e-6);
        assert_eq!(r.top_k, 40);
    }

    #[test]
    fn stats_detection_is_exact() {
        assert!(is_stats_request(&parse(r#"{"stats":true}"#).unwrap()));
        // Wrong value, wrong type, or a generate request carrying the
        // field must all fall through to the generate path.
        assert!(!is_stats_request(&parse(r#"{"stats":false}"#).unwrap()));
        assert!(!is_stats_request(&parse(r#"{"stats":1}"#).unwrap()));
        assert!(!is_stats_request(
            &parse(r#"{"prompt":"hi","stats":true}"#).unwrap()
        ));
        assert!(!is_stats_request(&parse(r#"{"prompt":"hi"}"#).unwrap()));
    }

    #[test]
    fn responses_are_valid_json() {
        for s in [
            token_response(104, "h"),
            done_response(FinishReason::Eos, 3),
            error_response("nope"),
        ] {
            parse(&s).unwrap();
        }
        assert!(token_response(104, "h").contains("\"token\":104"));
        assert!(done_response(FinishReason::MaxTokens, 2).contains("max_tokens"));
    }
}
