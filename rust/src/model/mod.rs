//! Model-level operation inventory: for a given `ModelConfig` and phase,
//! enumerate every GEMM/attention op of one transformer layer with its
//! M/N/K shape, FLOPs and bytes moved. This feeds the analytic GPU model
//! (`hwmodel`) and the heuristic-dataflow profiler (§5).

use crate::config::ModelConfig;

/// One linear (GEMM/GEMV) op instance: x[M,K] @ w[K,N].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearOp {
    pub name: &'static str,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl LinearOp {
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Minimum HBM traffic in bytes at element size `elt` (weights +
    /// activations in, activations out — weight-dominated for flat M).
    pub fn min_bytes(&self, elt: usize) -> f64 {
        ((self.k * self.n + self.m * self.k + self.m * self.n) * elt) as f64
    }

    /// Arithmetic intensity (FLOPs per byte).
    pub fn intensity(&self, elt: usize) -> f64 {
        self.flops() / self.min_bytes(elt)
    }
}

/// One attention op instance (per layer, whole batch).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttentionOp {
    pub batch: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// Query length (1 for decode).
    pub q_len: usize,
    /// KV length attended over.
    pub kv_len: usize,
}

impl AttentionOp {
    /// QK^T + PV FLOPs.
    pub fn flops(&self) -> f64 {
        4.0 * (self.batch * self.heads * self.q_len * self.kv_len * self.head_dim) as f64
    }

    /// Bytes: read K,V once, read Q, write O (f16/bf16 KV typical: elt).
    pub fn min_bytes(&self, elt: usize) -> f64 {
        let kv = 2 * self.batch * self.heads * self.kv_len * self.head_dim;
        let qo = 2 * self.batch * self.heads * self.q_len * self.head_dim;
        ((kv + qo) * elt) as f64
    }
}

/// The per-layer op list for one phase.
#[derive(Debug, Clone)]
pub struct LayerOps {
    pub linears: Vec<LinearOp>,
    pub attention: AttentionOp,
}

/// Decode phase: M = batch size, attention over kv_len.
pub fn decode_layer_ops(cfg: &ModelConfig, batch: usize, kv_len: usize) -> LayerOps {
    let ops = cfg
        .linear_shapes()
        .iter()
        .map(|&(name, n, k)| LinearOp {
            name,
            m: batch,
            n,
            k,
        })
        .collect();
    LayerOps {
        linears: ops,
        attention: AttentionOp {
            batch,
            heads: cfg.n_heads,
            head_dim: cfg.head_dim(),
            q_len: 1,
            kv_len,
        },
    }
}

/// Prefill phase: M = batch * seq_len, causal attention over seq.
pub fn prefill_layer_ops(cfg: &ModelConfig, batch: usize, seq_len: usize) -> LayerOps {
    let m = batch * seq_len;
    let ops = cfg
        .linear_shapes()
        .iter()
        .map(|&(name, n, k)| LinearOp {
            name,
            m,
            n,
            k,
        })
        .collect();
    LayerOps {
        linears: ops,
        attention: AttentionOp {
            batch,
            heads: cfg.n_heads,
            head_dim: cfg.head_dim(),
            q_len: seq_len,
            // causal: average attended length is seq/2; model as seq here
            // and let the cost model halve causal work.
            kv_len: seq_len,
        },
    }
}

/// KV-cache bytes appended per decoded token (whole model).
pub fn kv_bytes_per_token(cfg: &ModelConfig, elt: usize) -> usize {
    2 * cfg.n_layers * cfg.n_heads * cfg.head_dim() * elt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paper_model;

    #[test]
    fn decode_ops_are_flat() {
        let cfg = paper_model("llama2-7b").unwrap();
        let ops = decode_layer_ops(&cfg, 8, 1024);
        for l in &ops.linears {
            assert_eq!(l.m, 8);
            assert!(l.n >= 4096 && l.k >= 4096);
        }
        assert_eq!(ops.attention.q_len, 1);
        assert_eq!(ops.attention.kv_len, 1024);
    }

    #[test]
    fn flat_gemm_is_memory_bound_conventional_is_not() {
        let cfg = paper_model("llama2-7b").unwrap();
        // A100 bf16 roofline knee sits around 142 FLOP/byte.
        let dec = decode_layer_ops(&cfg, 1, 1024).linears[0];
        assert!(dec.intensity(2) < 10.0, "decode GEMV intensity {}", dec.intensity(2));
        let pre = prefill_layer_ops(&cfg, 1, 1024).linears[0];
        assert!(pre.intensity(2) > 100.0, "prefill intensity {}", pre.intensity(2));
    }

    #[test]
    fn kv_bytes_per_token_llama7b() {
        let cfg = paper_model("llama2-7b").unwrap();
        // 2 * 32 layers * 4096 dim * 2 bytes = 512 KiB / token
        assert_eq!(kv_bytes_per_token(&cfg, 2), 524288);
    }

    #[test]
    fn linear_flops_symmetry() {
        let op = LinearOp { name: "x", m: 8, n: 1024, k: 512 };
        assert_eq!(op.flops(), 2.0 * 8.0 * 1024.0 * 512.0);
    }
}
